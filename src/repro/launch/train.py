"""Training driver: --arch selects any of the 11 configs.

On this CPU container the reduced (smoke) configs run for real; the full
configs are exercised through dryrun.py. On a TPU pod the same driver
takes --full and the production mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch deepspeech2-wsj \
      --steps 50 --two-stage --transition 25
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 20
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan
from repro.core.schedule import TwoStageSchedule, cosine_schedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data import lm as lm_data
from repro.data import speech as speech_data
from repro.training import TrainConfig, Trainer


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
  ap.add_argument("--steps", type=int, default=30)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=64)
  ap.add_argument("--lr", type=float, default=1e-3)
  ap.add_argument("--microbatches", type=int, default=1)
  ap.add_argument("--full", action="store_true",
                  help="use the full production config (TPU pods)")
  ap.add_argument("--two-stage", action="store_true")
  ap.add_argument("--transition", type=int, default=0)
  ap.add_argument("--lambda-rec", type=float, default=1e-4)
  ap.add_argument("--lambda-nonrec", type=float, default=1e-4)
  ap.add_argument("--reg", default="trace", choices=["trace", "l2", "none"])
  ap.add_argument("--variance", type=float, default=0.9)
  ap.add_argument("--checkpoint-dir", default=None)
  ap.add_argument("--seed", type=int, default=0)
  args = ap.parse_args()

  cfg = (configs.get_config(args.arch) if args.full
         else configs.get_smoke(args.arch))

  schedule = None
  plan = FactorizationPlan(min_dim=32, exclude=("*embed*",))
  if args.two_stage:
    schedule = TwoStageSchedule(
        total_steps=args.steps,
        transition_step=args.transition or args.steps // 2,
        regularizer=RegularizerConfig(kind=args.reg,
                                      lambda_rec=args.lambda_rec,
                                      lambda_nonrec=args.lambda_nonrec),
        truncation=TruncationSpec(variance_threshold=args.variance,
                                  round_to=8),
    )

  tcfg = TrainConfig(lr=cosine_schedule(args.lr, args.steps // 10,
                                        args.steps),
                     microbatches=args.microbatches,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=max(args.steps // 4, 1)
                     if args.checkpoint_dir else 0)
  trainer = Trainer(cfg, tcfg, schedule=schedule, plan=plan,
                    rng=jax.random.PRNGKey(args.seed))

  if cfg.family == "deepspeech":
    dc = speech_data.SpeechDataConfig(vocab_size=cfg.vocab_size,
                                      feat_dim=cfg.feat_dim,
                                      global_batch=args.batch,
                                      seed=args.seed)
    gen = lambda i: speech_data.batch_at(dc, i)
  elif cfg.family == "whisper":
    dcl = lm_data.LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    def gen(i):
      b = lm_data.batch_at(dcl, i)
      frames = np.random.RandomState(i).randn(
          args.batch, args.seq, cfg.d_model).astype(np.float32)
      return {"frames": frames, "tokens": b["tokens"],
              "targets": b["targets"]}
  else:
    dcl = lm_data.LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    gen = lambda i: lm_data.batch_at(dcl, i)

  for i in range(args.steps):
    m = trainer.train_step(gen(i))
    if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
      print(f"step {m['step']:4d} stage {m['stage']} "
            f"loss {m['loss']:.4f} wall {m['wall_s']:.2f}s")

  if args.two_stage:
    print("\ntrace-norm diagnostics (first 5 GEMMs):")
    rep = trainer.tracenorm_report()
    for name in list(rep)[:5]:
      r = rep[name]
      print(f"  {name:32s} nu={r['nu']:.3f} rank90={int(r['rank90'])}")
  print(json.dumps({"final_loss": trainer.metrics_history[-1]["loss"]}))


if __name__ == "__main__":
  main()
