import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step program — train_step = fwd + bwd +
optimizer update; prefill = full-sequence forward (last-token logits);
decode = one cached serve step — with production shardings, compiles it
for the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, prints the
memory/cost analyses, and extracts roofline terms via dist.hlo_cost.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the framework. Results land in experiments/dryrun/*.json and
feed EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all                # 40 cells
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.dist import hlo_cost
from repro.dist.mesh import dp_size, make_mesh, model_size
from repro.dist.sharding import (_path_tokens, batch_shardings,
                                 make_constraint, param_shardings,
                                 state_shardings)
from repro.layers.common import ModelConfig, ShapeConfig
from repro.models import deepspeech
from repro.models.api import get_model
from repro.optim import AdamWConfig, make_optimizer

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def production_meshes(multi_pod: Optional[bool] = None) -> dict:
  devs = jax.devices()
  assert len(devs) >= 512, "dry-run needs the 512-device XLA_FLAGS header"
  meshes = {}
  if multi_pod is not True:
    meshes["single"] = make_mesh((16, 16), ("data", "model"),
                                 devices=devs[:256])
  if multi_pod is not False:
    meshes["multi"] = make_mesh((2, 16, 16), ("pod", "data", "model"),
                                devices=devs[:512])
  return meshes


def pick_optimizer(arch: str) -> str:
  # int8-state Adam is the fit strategy for the 671B config (DESIGN §5)
  return "q_adam" if arch == "deepseek-v3-671b" else "adamw"


def needs_fsdp_serving(cfg: ModelConfig, params_sds: Any, mesh) -> bool:
  """Model-parallel-only weights must fit ~8 GB/chip; else 2D-shard them."""
  total = sum(np.prod(x.shape) * x.dtype.itemsize
              for x in jax.tree.leaves(params_sds))
  return total / model_size(mesh) > 8e9


def _with_groups(cfg: ModelConfig, mesh) -> ModelConfig:
  if cfg.moe is None or cfg.moe.dispatch_groups != 1:
    return cfg          # explicit group choice wins (perf iterations)
  return cfg.with_(moe=dataclasses.replace(
      cfg.moe, dispatch_groups=dp_size(mesh)))


# ---------------------------------------------------------------------------
# Step builders: (fn, example_args_sds, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

def train_param_policy(cfg: ModelConfig, mesh) -> str:
  """'zero1': params live TP-resident P(None, model); the optimizer state
  is 2D-sharded and grads are reduce-scattered once per microbatch — the
  per-layer FSDP weight re-gathering (which multiplies with microbatch
  count) disappears. Chosen whenever the TP-resident params fit (<6 GB per
  chip) — every assigned arch except deepseek-v3-671b, which keeps full
  FSDP with per-layer all-gathers inside the scan body."""
  params_sds = configs.param_specs(cfg)
  total = sum(np.prod(x.shape) * x.dtype.itemsize
              for x in jax.tree.leaves(params_sds))
  return "zero1" if total / model_size(mesh) < 6e9 else "fsdp"


def _apply_overrides(shard_tree, overrides, mesh):
  """Perf-iteration hook: {path-substring: PartitionSpec} overrides."""
  if not overrides:
    return shard_tree
  def f(path, s):
    pstr = "/".join(_path_tokens(path))
    for frag, spec in overrides.items():
      if frag in pstr:
        return jax.sharding.NamedSharding(mesh, spec)
    return s
  return jax.tree_util.tree_map_with_path(
      f, shard_tree, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh, optimizer: str,
                microbatches: int = 8, sharding_overrides=None,
                rule_overrides=None, params_sds_override=None):
  api = get_model(cfg)
  cs = make_constraint(mesh, cfg, shape.global_batch,
                       rule_overrides=rule_overrides)
  opt_init, opt_apply = make_optimizer(optimizer)
  adam = AdamWConfig(max_grad_norm=1.0)
  k = microbatches
  while shape.global_batch % (k * dp_size(mesh)) and k > 1:
    k //= 2
  policy = train_param_policy(cfg, mesh)

  params_sds = params_sds_override or configs.param_specs(cfg)
  opt_sds = jax.eval_shape(opt_init, params_sds)
  batch_sds = configs.input_specs(cfg, shape)
  pshard = param_shardings(params_sds, mesh, fsdp=(policy == "fsdp"))
  gshard = param_shardings(params_sds, mesh, fsdp=True)  # 2D grads (ZeRO)
  oshard = param_shardings(opt_sds, mesh, fsdp=True)     # 2D moments
  bshard = batch_shardings(batch_sds, mesh, shape)
  # overrides: bare keys hit params+grads+opt; "grads:<frag>" grads only
  def _split(pref):
    out = {}
    for k, v in (sharding_overrides or {}).items():
      if ":" not in k:
        out[k] = v
      elif k.startswith(pref + ":"):
        out[k.split(":", 1)[1]] = v
    return out
  pshard = _apply_overrides(pshard, _split("params"), mesh)
  gshard = _apply_overrides(gshard, _split("grads"), mesh)
  oshard = _apply_overrides(oshard, _split("opt"), mesh)

  def constrain_grads(g):
    return jax.tree.map(jax.lax.with_sharding_constraint, g, gshard)

  def train_step(params, opt_state, batch):
    def loss_fn(p, mb):
      loss, _ = api.loss_fn(p, mb, cfg, cs)
      return loss
    if k <= 1:
      loss, grads = jax.value_and_grad(loss_fn)(params, batch)
      grads = constrain_grads(grads)
    else:
      # gradient accumulation: per-microbatch activations live 1/k as long;
      # the accumulator is 2D-sharded, so each microbatch's grads arrive
      # via reduce-scatter (ZeRO) rather than all-reduce.
      def slice_mb(x, i):
        m = x.shape[0] // k
        return jax.lax.dynamic_slice_in_dim(x, i * m, m, axis=0)
      def body(carry, i):
        acc_l, acc_g = carry
        mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        g = constrain_grads(g)
        acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             acc_g, g)
        return (acc_l + l, acc_g), None
      zero = jax.tree.map(
          lambda p, s: jax.lax.with_sharding_constraint(
              jnp.zeros(p.shape, jnp.float32), s), params, gshard)
      (loss, gsum), _ = jax.lax.scan(
          body, (jnp.zeros((), jnp.float32), zero), jnp.arange(k))
      loss = loss / k
      grads = jax.tree.map(lambda g: g / k, gsum)
    params, opt_state, _ = opt_apply(params, grads, opt_state,
                                     jnp.float32(1e-3), adam)
    return params, opt_state, loss

  in_sh = (pshard, oshard, bshard)
  out_sh = (pshard, oshard, jax.sharding.NamedSharding(
      mesh, jax.sharding.PartitionSpec()))
  args = (params_sds, opt_sds, batch_sds)
  return train_step, args, in_sh, out_sh


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh, fsdp: bool):
  api = get_model(cfg)
  cs = make_constraint(mesh, cfg, shape.global_batch)

  if cfg.family == "whisper":
    def prefill(params, batch):
      return api.encode(params, batch["frames"], cfg, cs)
  elif cfg.family == "deepspeech":
    def prefill(params, batch):
      return api.forward(params, batch["feats"], cfg, cs)
  else:
    def prefill(params, batch):
      logits, _ = api.forward(params, batch["tokens"], cfg, cs,
                              last_only=True)
      return logits

  params_sds = configs.param_specs(cfg)
  batch_sds = configs.input_specs(cfg, shape)
  pshard = param_shardings(params_sds, mesh, fsdp=fsdp)
  bshard = batch_shardings(batch_sds, mesh, shape)
  return prefill, (params_sds, batch_sds), (pshard, bshard), None


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh, fsdp: bool,
                 sharding_overrides=None, rule_overrides=None,
                 params_sds_override=None):
  api = get_model(cfg)
  cs = make_constraint(mesh, cfg, shape.global_batch, decode=True,
                       rule_overrides=rule_overrides)
  params_sds = params_sds_override or configs.param_specs(cfg)
  batch_sds = configs.input_specs(cfg, shape)
  pshard = param_shardings(params_sds, mesh, fsdp=fsdp, expert_2d=True)
  pshard = _apply_overrides(pshard, sharding_overrides, mesh)
  bshard = batch_shardings(batch_sds, mesh, shape)

  if cfg.family == "deepspeech":
    def step(params, state, batch):
      return deepspeech.decode_step(params, state, batch["x_t"], cfg, cs)
    state_sds = jax.eval_shape(
        lambda: deepspeech.init_decode_state(cfg, shape.global_batch))
  else:
    def step(params, state, batch):
      return api.decode_step(params, state, batch["token"],
                             batch["positions"], cfg, cs)
    state_sds = configs.decode_state_specs(cfg, shape)

  sshard = state_shardings(state_sds, mesh, shape)
  in_sh = (pshard, sshard, bshard)
  out_sh = (None, sshard)
  args = (params_sds, state_sds, batch_sds)
  return step, args, in_sh, out_sh


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, optimizer: str):
  if shape.kind == "train":
    return build_train(cfg, shape, mesh, optimizer)
  params_sds = configs.param_specs(cfg)
  fsdp = needs_fsdp_serving(cfg, params_sds, mesh)
  if shape.kind == "prefill":
    return build_prefill(cfg, shape, mesh, fsdp)
  return build_decode(cfg, shape, mesh, fsdp)


# ---------------------------------------------------------------------------
# Model-FLOPs estimate (6ND / 2ND with MoE-active correction).
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> tuple[float, float]:
  """(total, active) param counts from the eval_shape tree."""
  sds = configs.param_specs(cfg)
  flat = jax.tree_util.tree_flatten_with_path(sds)[0]
  total = active = 0.0
  for path, leaf in flat:
    n = float(np.prod(leaf.shape))
    toks = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    total += n
    if (cfg.moe and "moe" in "".join(str(t) for t in toks) and
        any(str(t) in ("w_gate", "w_up", "w_down") for t in toks) and
        cfg.moe.num_experts in leaf.shape):
      active += n * cfg.moe.top_k / cfg.moe.num_experts
    else:
      active += n
  return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
  total, active = param_counts(cfg)
  if shape.kind == "train":
    tokens = shape.global_batch * shape.seq_len
    if cfg.family == "whisper":
      tokens = shape.global_batch * (shape.seq_len + shape.seq_len // 4)
    return 6.0 * active * tokens
  if shape.kind == "prefill":
    return 2.0 * active * shape.global_batch * shape.seq_len
  return 2.0 * active * shape.global_batch          # one token / sequence


# ---------------------------------------------------------------------------
# Runner.
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: ShapeConfig, mesh_name: str, mesh,
             optimizer: Optional[str] = None, *, save: bool = True,
             verbose: bool = True, cfg_override=None) -> dict:
  cfg = cfg_override or configs.get_config(arch)
  cfg = _with_groups(cfg, mesh)
  opt = optimizer or pick_optimizer(arch)
  t0 = time.time()
  fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh, opt)
  with mesh:
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
  compile_s = time.time() - t0

  n_dev = int(np.prod(list(mesh.shape.values())))
  txt = compiled.as_text()
  rep = hlo_cost.analyze_module(txt, n_dev)
  mf = model_flops(cfg, shape) / n_dev        # per-device share
  roof = hlo_cost.roofline_from_report(rep, model_flops=mf)

  mem = {}
  try:
    ma = compiled.memory_analysis()
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
      v = getattr(ma, attr, None)
      if v is not None:
        mem[attr] = int(v)
  except Exception as e:          # backend may not implement it
    mem["error"] = repr(e)
  cost = {}
  try:
    ca = compiled.cost_analysis()
    cost = {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "optimal_seconds")}
  except Exception as e:
    cost["error"] = repr(e)

  result = {
      "arch": arch, "shape": shape.name, "mesh": mesh_name,
      "devices": n_dev, "optimizer": opt if shape.kind == "train" else None,
      "compile_s": round(compile_s, 1),
      "flops": rep.flops, "dot_flops": rep.dot_flops,
      "hbm_bytes": rep.hbm_bytes,
      "collective_bytes": rep.collective_bytes,
      "collective_wire_bytes": rep.collective_wire_bytes,
      "collective_by_kind": rep.collective_by_kind,
      "n_collectives": rep.n_collectives,
      "compute_s": roof.compute_s, "memory_s": roof.memory_s,
      "collective_s": roof.collective_s,
      "dominant": roof.dominant,
      "model_flops_per_dev": mf,
      "useful_flop_fraction": roof.useful_flop_fraction,
      "roofline_fraction": roof.roofline_fraction,
      "memory_analysis": mem, "cost_analysis": cost,
  }
  if verbose:
    print(f"[{arch} x {shape.name} x {mesh_name}] compile {compile_s:.0f}s "
          f"dominant={roof.dominant} compute={roof.compute_s:.4f}s "
          f"memory={roof.memory_s:.4f}s coll={roof.collective_s:.4f}s "
          f"useful={roof.useful_flop_fraction:.2f} "
          f"arg={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
          f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB")
  if save:
    os.makedirs(OUT_DIR, exist_ok=True)
    fname = f"{arch}__{shape.name}__{mesh_name}.json"
    with open(os.path.join(OUT_DIR, fname), "w") as f:
      json.dump(result, f, indent=1)
  return result


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None)
  ap.add_argument("--shape", default=None)
  ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
  ap.add_argument("--all", action="store_true")
  ap.add_argument("--optimizer", default=None)
  args = ap.parse_args()

  meshes = production_meshes()
  if args.mesh:
    meshes = {args.mesh: meshes[args.mesh]}
  archs = configs.ARCH_NAMES if (args.all or not args.arch) else [args.arch]

  failures = []
  for arch in archs:
    for shape in configs.shapes_for(arch):
      if args.shape and shape.name != args.shape:
        continue
      for mesh_name, mesh in meshes.items():
        try:
          run_cell(arch, shape, mesh_name, mesh, args.optimizer)
        except Exception as e:
          failures.append((arch, shape.name, mesh_name, repr(e)))
          print(f"FAILED [{arch} x {shape.name} x {mesh_name}]: {e}")
          traceback.print_exc()
  if failures:
    print(f"\n{len(failures)} FAILURES:")
    for f in failures:
      print(" ", f)
    raise SystemExit(1)
  print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
  main()
