"""Serving driver: --arch selects any decodable config; drives a queue of
mixed-length requests through the continuous-batching LMEngine (or streams
speech through the DS2 server). Smoke configs run on CPU; full configs
target pods.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --batch 4 --num-requests 12 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.speech import SpeechDataConfig, batch_at
from repro.models.api import get_model
from repro.serving import LMEngine, StreamingSpeechServer


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
  ap.add_argument("--batch", type=int, default=4,
                  help="engine slots (concurrent decode streams)")
  ap.add_argument("--num-requests", type=int, default=None,
                  help="requests to queue (default: --batch); extras "
                       "refill slots as earlier requests retire")
  ap.add_argument("--steps", type=int, default=16,
                  help="per-request new-token budget (requests draw "
                       "varying budgets up to this)")
  ap.add_argument("--prompt-len", type=int, default=8,
                  help="mean prompt length; requests draw varying "
                       "lengths around this")
  ap.add_argument("--max-len", type=int, default=128)
  ap.add_argument("--temperature", type=float, default=0.8)
  ap.add_argument("--eos-id", type=int, default=None,
                  help="token id retiring a request early")
  ap.add_argument("--full", action="store_true")
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp",
                  help="execution policy: 'pallas' routes the decode "
                       "regime through the shape-specialized kernels "
                       "(kernels.dispatch), 'jnp' is the reference path")
  ap.add_argument("--quantize", action="store_true",
                  help="one-shot PTQ (repro.quant) before serving: every "
                       "GEMM leaf becomes int8 + per-column scales and "
                       "decodes through the int8_gemm regime")
  ap.add_argument("--speculate", type=int, default=0, metavar="K",
                  help="self-speculative decoding: a low-rank draft of "
                       "the SAME params proposes K tokens per step, the "
                       "target verifies them in one batched window "
                       "forward. Greedy (--temperature 0) is lossless — "
                       "token-for-token vanilla greedy; temperature > 0 "
                       "rejection-samples, matching the vanilla "
                       "sampling distribution exactly")
  ap.add_argument("--draft-rank", type=int, default=None,
                  help="fixed truncated-SVD rank for the draft's GEMMs "
                       "(default: explained-variance rule at 0.9)")
  ap.add_argument("--adapt-rank", action="store_true",
                  help="online draft-rank controller: walk --draft-rank "
                       "to keep the measured accept rate inside "
                       "--rank-band (requires --draft-rank)")
  ap.add_argument("--rank-band", type=float, nargs=2, default=(0.5, 0.85),
                  metavar=("LO", "HI"),
                  help="target accept-rate band for --adapt-rank")
  ap.add_argument("--rank-step", type=int, default=16,
                  help="rank increment per --adapt-rank adjustment")
  ap.add_argument("--rank-interval", type=int, default=8,
                  help="engine iterations per --adapt-rank measurement "
                       "window")
  ap.add_argument("--prefix-cache", action="store_true",
                  help="radix-trie prefix cache: shared prompt prefixes "
                       "splice from cached decode-state snapshots and "
                       "only the uncached suffix is prefilled (greedy "
                       "output stays bit-identical to cold serving)")
  ap.add_argument("--prefix-cache-mb", type=float, default=256.0,
                  help="byte-accounted LRU capacity for --prefix-cache")
  args = ap.parse_args()
  if args.adapt_rank and args.draft_rank is None:
    ap.error("--adapt-rank needs --draft-rank (a starting rank to walk)")
  if args.adapt_rank and args.quantize:
    ap.error("--adapt-rank rebuilds the draft from the served params, "
             "which int8 leaves cannot be SVD'd from — drop one flag")

  cfg = (configs.get_config(args.arch) if args.full
         else configs.get_smoke(args.arch))
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  if args.speculate and cfg.family == "deepspeech":
    # the streaming CTC server is frame-synchronous: there is no token
    # sequence to draft, so speculation does not apply — say so instead
    # of silently ignoring the flag
    print("--speculate applies to the LM engine only; the deepspeech "
          "family streams frame-synchronously — ignoring")
    args.speculate = 0
  draft_params = None
  if args.speculate and args.quantize:
    # int8 leaves can't be SVD'd — build the draft from the float
    # weights BEFORE PTQ (quantization x speculation still composes
    # losslessly: verification is against whatever the target computes)
    from repro.serving import make_draft_params
    draft_params = make_draft_params(params, rank=args.draft_rank)
  if args.quantize:
    from repro.core.factored import iter_gemm_leaves
    from repro.quant import QuantizedLinear, quantize_params
    params = quantize_params(params)
    n_int8 = sum(l.num_params for l in iter_gemm_leaves(params)
                 if isinstance(l, QuantizedLinear))
    print(f"PTQ'd {n_int8} GEMM params to int8 "
          f"(serving from quantized storage)")

  if cfg.family == "deepspeech":
    # continuous-batching speech fleet: --num-requests utterances of
    # mixed, deliberately non-stride-multiple lengths share --batch
    # decode slots; retiring utterances refill from the queue without
    # re-tracing (server.compile_stats pins frame_step == 1)
    server = StreamingSpeechServer(cfg, params, batch_size=args.batch,
                                   kernel_policy=args.kernels)
    n_utts = args.num_requests or 2 * args.batch
    dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                          global_batch=max(args.batch, 1))
    rng = np.random.RandomState(0)
    for i in range(n_utts):
      batch = np.asarray(batch_at(dc, i)["feats"])
      row = batch[i % batch.shape[0]]
      t = int(rng.randint(17, min(64, row.shape[0]) + 1))
      server.submit(row[:t])                # arbitrary lengths by design
    t0 = time.perf_counter()
    results = server.run(chunk_frames=16)
    dt = time.perf_counter() - t0
    frames = sum(r.frames for r in results)
    stats = server.compile_stats()
    print(f"fleet served {len(results)} utterances ({frames} frames) "
          f"through {args.batch} slots in {dt:.2f}s "
          f"({len(results) / dt:.1f} streams/s, {frames / dt:.0f} "
          f"frames/s, occupancy {server.occupancy:.2f}, "
          f"frame_step signatures {stats['frame_step']})")
    for r in results[:4]:
      print(f"  utt {r.uid}: {r.frames} frames -> "
            f"{len(r.labels)} labels; sample {r.labels[:6]}")
    return

  num_requests = args.num_requests or args.batch
  rng = np.random.RandomState(0)
  lo, hi = max(1, args.prompt_len // 2), 2 * args.prompt_len
  temperature = args.temperature
  cache = None
  if args.prefix_cache:
    from repro.serving import PrefixCache
    cache = PrefixCache(capacity_mb=args.prefix_cache_mb)
  controller = None
  if args.adapt_rank:
    from repro.serving import RankController
    controller = RankController(band=tuple(args.rank_band),
                                step=args.rank_step,
                                interval=args.rank_interval)
  engine = LMEngine(cfg, params, batch_size=args.batch,
                    max_len=args.max_len, kernel_policy=args.kernels,
                    eos_id=args.eos_id, speculate=args.speculate,
                    draft_params=draft_params, draft_rank=args.draft_rank,
                    rank_controller=controller, prefix_cache=cache)
  if args.speculate:
    from repro.core.factored import count_params
    print(f"speculating {args.speculate} tokens/step with a "
          f"{count_params(engine.draft_params)}-param low-rank draft "
          f"(target {count_params(params)})")
  # with a prefix cache, model fleet traffic: most requests open with a
  # shared system-prompt template, so the cache has prefixes to hit
  shared = rng.randint(1, cfg.vocab_size, size=(max(2, args.prompt_len),))
  for _ in range(num_requests):
    prompt = rng.randint(1, cfg.vocab_size, size=(rng.randint(lo, hi + 1),))
    if cache is not None and rng.rand() < 0.8:
      prompt = np.concatenate([shared, prompt])
    engine.submit(prompt, max_new_tokens=int(rng.randint(1, args.steps + 1)))
  t0 = time.perf_counter()
  finished = engine.run(temperature=temperature)
  dt = time.perf_counter() - t0
  tokens = sum(len(f.tokens) for f in finished)
  spec = ""
  if args.speculate:
    # accept_rate is None until something was drafted — "no data", not 0
    rate = engine.accept_rate
    spec = (f", accept rate {rate:.2f}" if rate is not None
            else ", accept rate n/a")
    if args.adapt_rank:
      spec += (f", draft rank {engine.draft_rank} "
               f"({len(engine.rank_history)} adjustments)")
  ttfts = sorted(f.ttft_s for f in finished if f.ttft_s is not None)
  ttft_p50 = ttfts[len(ttfts) // 2] * 1e3 if ttfts else float("nan")
  cachestr = ""
  if cache is not None:
    cs = engine.cache_stats()
    cachestr = (f", cache hit rate {cs['hit_rate']:.2f} "
                f"({cs['entries']} entries, "
                f"{cs['bytes'] / (1 << 20):.1f} MB)")
  print(f"served {len(finished)} requests ({tokens} tokens) through "
        f"{args.batch} slots in {dt:.2f}s ({tokens / dt:.1f} tok/s, "
        f"TTFT p50 {ttft_p50:.1f} ms, "
        f"occupancy {engine.occupancy:.2f}{spec}{cachestr})")
  for f in finished[:4]:
    print(f"  req {f.uid}: prompt {len(f.prompt)} -> {len(f.tokens)} "
          f"tokens ({f.finish_reason}); sample {f.tokens[:6].tolist()}")


if __name__ == "__main__":
  main()
