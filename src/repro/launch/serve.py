"""Serving driver: --arch selects any decodable config; generates from a
batch of prompts through the LMEngine (or streams speech through the DS2
server). Smoke configs run on CPU; full configs target pods.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --batch 4 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.speech import SpeechDataConfig, batch_at
from repro.models.api import get_model
from repro.serving import LMEngine, StreamingSpeechServer


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--steps", type=int, default=16)
  ap.add_argument("--prompt-len", type=int, default=8)
  ap.add_argument("--max-len", type=int, default=128)
  ap.add_argument("--temperature", type=float, default=0.8)
  ap.add_argument("--full", action="store_true")
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp",
                  help="execution policy: 'pallas' routes the decode "
                       "regime through the shape-specialized kernels "
                       "(kernels.dispatch), 'jnp' is the reference path")
  args = ap.parse_args()

  cfg = (configs.get_config(args.arch) if args.full
         else configs.get_smoke(args.arch))
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)

  if cfg.family == "deepspeech":
    server = StreamingSpeechServer(cfg, params, batch_size=args.batch,
                                   kernel_policy=args.kernels)
    dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                          global_batch=args.batch)
    chunk = batch_at(dc, 0)["feats"][:, :32]
    t0 = time.perf_counter()
    out = server.process_chunk(chunk)
    dt = time.perf_counter() - t0
    print(f"streamed 32 frames x {args.batch} in {dt*1e3:.1f} ms; "
          f"emitted: {[len(o) for o in out]}")
    return

  rng = np.random.RandomState(0)
  prompts = rng.randint(1, cfg.vocab_size,
                        size=(args.batch, args.prompt_len))
  engine = LMEngine(cfg, params, batch_size=args.batch,
                    max_len=args.max_len, kernel_policy=args.kernels)
  t0 = time.perf_counter()
  res = engine.generate(prompts, steps=args.steps,
                        temperature=args.temperature)
  dt = time.perf_counter() - t0
  print(f"generated {args.steps} tokens x {args.batch} requests "
        f"in {dt:.2f}s ({args.steps * args.batch / dt:.1f} tok/s)")
  print("sample:", res.tokens[0].tolist())


if __name__ == "__main__":
  main()
