"""Production mesh entry point (a FUNCTION — importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

from repro.dist.mesh import (dp_axes, dp_size, make_host_mesh, make_mesh,
                             model_size)
from repro.dist.mesh import make_production_mesh  # re-export

__all__ = ["make_production_mesh", "make_mesh", "make_host_mesh",
           "dp_axes", "dp_size", "model_size"]
