"""Launchers: production mesh, multi-pod dry-run, train/serve drivers."""
from repro.launch.mesh import make_production_mesh

__all__ = ["make_production_mesh"]
