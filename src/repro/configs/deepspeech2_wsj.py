"""deepspeech2_wsj — the PAPER's own architecture (11th config).

Forward-only GRU Deep Speech 2 (Amodei et al. 2016) with the paper's
Appendix-B choices: mel-80 features (B.3), growing GRU sizes 768/1024/1280
(B.1), FC 1536, CTC over a character vocabulary, partially-joint GRU
factorization (B.2). ~29.8M params when stage-1 factored, matching the
paper's §3.2.3 scale.
"""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="deepspeech2-wsj", family="deepspeech",
    num_layers=3, d_model=1280, num_heads=1, num_kv_heads=1,
    d_ff=1536, vocab_size=32,               # blank + 26 chars + punct
    feat_dim=80, gru_dims=(768, 1024, 1280), fc_dim=1536,
    conv_channels=32, time_stride=2,
)

SMOKE = ModelConfig(
    name="deepspeech2-wsj-smoke", family="deepspeech",
    num_layers=3, d_model=96, num_heads=1, num_kv_heads=1,
    d_ff=128, vocab_size=32,
    feat_dim=80, gru_dims=(64, 80, 96), fc_dim=128,
    conv_channels=8, time_stride=2, remat="none",
)

SKIP_SHAPES = ("long_500k",)
