"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MLA (kv_lora=512, q_lora=1536), 1 shared + 256 routed
top-8, MTP [arXiv:2412.19437]. First 3 layers dense (d_ff=18432).

Fitting 671B on a 256-chip pod requires 2D (data x model) parameter
sharding + int8-state Adam (optim/q_adam.py) — see DESIGN.md §5 and the
dry-run memory analysis in EXPERIMENTS.md.
"""
from repro.layers.common import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="transformer",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280, mtp=True,
    moe=MoEConfig(num_experts=256, num_shared=1, top_k=8, d_expert=2048,
                  first_dense_layers=3),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="transformer",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, mtp=True,
    moe=MoEConfig(num_experts=8, num_shared=1, top_k=2, d_expert=64,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16,
                  qk_rope_dim=16, v_head_dim=16),
    attn_block_q=32, attn_block_kv=32, remat="none",
)

SKIP_SHAPES = ("long_500k",)
