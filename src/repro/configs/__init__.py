"""Architecture registry: 10 assigned archs + the paper's own DS2 config.

  get_config(name)  — full production config (exercised via dry-run only)
  get_smoke(name)   — reduced same-family config (CPU-runnable)
  shapes_for(name)  — the assigned ShapeConfigs minus documented skips
"""
from __future__ import annotations

from repro.configs import (chameleon_34b, deepseek_v2_lite, deepseek_v3_671b,
                           deepspeech2_wsj, glm4_9b, llama3_8b, qwen3_4b,
                           stablelm_3b, whisper_small, xlstm_350m, zamba2_7b)
from repro.configs.specs import (decode_state_specs, input_specs,
                                 param_specs)
from repro.layers.common import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "chameleon-34b": chameleon_34b,
    "llama3-8b": llama3_8b,
    "glm4-9b": glm4_9b,
    "stablelm-3b": stablelm_3b,
    "qwen3-4b": qwen3_4b,
    "zamba2-7b": zamba2_7b,
    "xlstm-350m": xlstm_350m,
    "deepseek-v2-lite": deepseek_v2_lite,
    "deepseek-v3-671b": deepseek_v3_671b,
    "whisper-small": whisper_small,
    "deepspeech2-wsj": deepspeech2_wsj,
}

ARCH_NAMES = list(_MODULES)
ASSIGNED = [n for n in ARCH_NAMES if n != "deepspeech2-wsj"]

__all__ = ["ARCH_NAMES", "ASSIGNED", "SHAPES", "ModelConfig", "ShapeConfig",
           "decode_state_specs", "input_specs", "param_specs", "get_config",
           "get_smoke", "shapes_for"]


def get_config(name: str) -> ModelConfig:
  return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
  return _MODULES[name].SMOKE


def shapes_for(name: str) -> list[ShapeConfig]:
  skips = _MODULES[name].SKIP_SHAPES
  out = []
  for sname, shape in SHAPES.items():
    if sname in skips:
      continue
    if name == "deepspeech2-wsj" and sname != "train_4k":
      # the paper's arch has its own serving benchmark (streaming frames);
      # the LM-pool prefill/decode cells don't apply to a CTC model
      if sname != "decode_32k":
        continue
    out.append(shape)
  return out
