"""zamba2-7b [hybrid] — 81L Mamba2 backbone + shared attention block,
d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242]. Sub-quadratic SSD scan -> RUNS long_500k.

The shared attention block (one weight set reused every `attn_every`
layers) is the extreme end of the paper's Appendix-B.2 weight-sharing
spectrum.
"""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="zamba",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, ssm_state=64, attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="zamba",
    num_layers=5, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, ssm_state=16, attn_every=2,
    attn_block_q=32, attn_block_kv=32, remat="none",
)

SKIP_SHAPES = ()
