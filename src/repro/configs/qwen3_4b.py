"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm, head_dim=128 [hf:Qwen/Qwen3 family]."""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="transformer",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke", family="transformer",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, qk_norm=True,
    attn_block_q=32, attn_block_kv=32, remat="none",
)

SKIP_SHAPES = ("long_500k",)
