"""chameleon-34b [vlm] — early-fusion VQ-token transformer.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818].
Image tokens are VQ codes inside the unified vocab, so the modality
frontend stub is the token stream itself (no separate patch embedder).
Full attention -> long_500k skipped (DESIGN.md §Arch-applicability).
"""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="transformer",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="chameleon-34b-smoke", family="transformer",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, attn_block_q=32, attn_block_kv=32,
    remat="none",
)

SKIP_SHAPES = ("long_500k",)  # full attention: 500k dense KV not supported
