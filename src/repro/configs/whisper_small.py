"""whisper-small [audio] — enc-dec, 12L enc + 12L dec, d_model=768 12H
d_ff=3072 vocab=51865 [arXiv:2212.04356]. Conv frontend is a stub per the
brief: input_specs() provides precomputed frame embeddings (b, t, d).
Decoder exists -> decode shapes run; full attention -> long_500k skipped.
"""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="whisper",
    num_layers=12, encoder_layers=12, d_model=768, num_heads=12,
    num_kv_heads=12, d_ff=3072, vocab_size=51865,
    max_source_positions=1500,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="whisper",
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=512,
    max_source_positions=64, attn_block_q=32, attn_block_kv=32,
    remat="none",
)

SKIP_SHAPES = ("long_500k",)
