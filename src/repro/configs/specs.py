"""Input ShapeDtypeStruct builders per (arch family x shape kind).

`input_specs(cfg, shape)` returns the exact kwargs the train/serve step is
lowered with — weak-type-correct, shardable, zero device allocation. The
modality frontends of [audio]/[vlm] archs are stubs per the brief: whisper
receives precomputed frame embeddings (b, t, d_model); chameleon's VQ image
tokens are ordinary ids inside its unified 65536 vocab, so its stub *is*
the token stream.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.layers.common import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def _lm_train(cfg: ModelConfig, shape: ShapeConfig) -> dict:
  b, s = shape.global_batch, shape.seq_len
  return {
      "tokens": SDS((b, s), jnp.int32),
      "targets": SDS((b, s), jnp.int32),
  }


def _lm_decode(cfg: ModelConfig, shape: ShapeConfig) -> dict:
  b = shape.global_batch
  return {
      "token": SDS((b, 1), jnp.int32),
      "positions": SDS((b,), jnp.int32),
  }


def _whisper_train(cfg: ModelConfig, shape: ShapeConfig) -> dict:
  b, s = shape.global_batch, shape.seq_len
  dec = max(s // 4, 64)     # text tokens per audio window
  return {
      "frames": SDS((b, s, cfg.d_model), cfg.dtype),
      "tokens": SDS((b, dec), jnp.int32),
      "targets": SDS((b, dec), jnp.int32),
  }


def _speech_train(cfg: ModelConfig, shape: ShapeConfig) -> dict:
  b, t = shape.global_batch, shape.seq_len
  lab = max(t // 16, 8)
  return {
      "feats": SDS((b, t, cfg.feat_dim), cfg.dtype),
      "feat_lengths": SDS((b,), jnp.int32),
      "labels": SDS((b, lab), jnp.int32),
      "label_lengths": SDS((b,), jnp.int32),
  }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
  """Step inputs (excluding params / decode state) as ShapeDtypeStructs."""
  fam = cfg.family
  if shape.kind == "train":
    if fam == "whisper":
      return _whisper_train(cfg, shape)
    if fam == "deepspeech":
      return _speech_train(cfg, shape)
    return _lm_train(cfg, shape)
  if shape.kind == "prefill":
    if fam == "whisper":
      b, s = shape.global_batch, shape.seq_len
      return {"frames": SDS((b, s, cfg.d_model), cfg.dtype)}
    if fam == "deepspeech":
      b, t = shape.global_batch, shape.seq_len
      return {"feats": SDS((b, t, cfg.feat_dim), cfg.dtype)}
    return {"tokens": SDS((shape.global_batch, shape.seq_len), jnp.int32)}
  if shape.kind == "decode":
    if fam == "deepspeech":
      # streaming frame step: one post-frontend feature frame
      b = shape.global_batch
      freq_after = ((cfg.feat_dim + 1) // 2 + 1) // 2
      return {"x_t": SDS((b, freq_after * cfg.conv_channels), cfg.dtype)}
    return _lm_decode(cfg, shape)
  raise ValueError(f"unknown shape kind: {shape.kind}")


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
  """Decode-state pytree as ShapeDtypeStructs (eval_shape over the init)."""
  from repro.models.api import get_model
  api = get_model(cfg)
  if api.init_decode_state is None:
    raise ValueError(f"{cfg.name} has no decode state")
  return jax.eval_shape(
      lambda: api.init_decode_state(cfg, shape.global_batch, shape.seq_len))


def param_specs(cfg: ModelConfig) -> Any:
  """Model params as ShapeDtypeStructs (eval_shape, no allocation)."""
  from repro.models.api import get_model
  api = get_model(cfg)
  return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
