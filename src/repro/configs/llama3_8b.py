"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 [arXiv:2407.21783]."""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="transformer",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke", family="transformer",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, rope_theta=500000.0,
    attn_block_q=32, attn_block_kv=32, remat="none",
)

SKIP_SHAPES = ("long_500k",)
