"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed experts top-6
[arXiv:2405.04434]. First layer dense (d_ff=10944, the released ratio).

MLA *is* a shipped instance of the paper's W = UV idea: the KV projection
is factored through a rank-512 latent and the latent is what gets cached
(DESIGN.md §4).
"""
from repro.layers.common import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite", family="transformer",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_expert=1408,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke", family="transformer",
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    moe=MoEConfig(num_experts=8, num_shared=1, top_k=2, d_expert=64,
                  first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_dim=16,
                  qk_rope_dim=16, v_head_dim=16),
    attn_block_q=32, attn_block_kv=32, remat="none",
)

SKIP_SHAPES = ("long_500k",)
