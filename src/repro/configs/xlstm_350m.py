"""xlstm-350m [ssm] — 24L (12 mLSTM/sLSTM pairs) d_model=1024 4H
vocab=50304 [arXiv:2405.04517]. d_ff=0: blocks carry their own
projections. O(1) decode state -> RUNS long_500k.

The sLSTM recurrent kernel maps directly onto the paper's `rec` group
(lambda_rec); mLSTM q/k/v projections are `nonrec` (DESIGN.md §4).
"""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="xlstm",
    num_layers=4, d_model=128, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=512, remat="none",
)

SKIP_SHAPES = ()
