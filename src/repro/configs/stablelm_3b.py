"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="transformer",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke", family="transformer",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=8,
    d_ff=256, vocab_size=512, attn_block_q=32, attn_block_kv=32,
    remat="none",
)

SKIP_SHAPES = ("long_500k",)
