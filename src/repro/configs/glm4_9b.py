"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 [hf:THUDM/glm-4-9b]. kv=2 heads replicate under TP=16
(DESIGN.md §5)."""
from repro.layers.common import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="transformer",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", family="transformer",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, attn_block_q=32, attn_block_kv=32,
    remat="none",
)

SKIP_SHAPES = ("long_500k",)
