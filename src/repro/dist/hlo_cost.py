"""Lowered-HLO cost accounting: FLOPs, HBM traffic, collective bytes.

Parses `compiled.as_text()` (post-SPMD, so shapes are per-device) and
walks the computation call graph multiplying through `while` trip counts
— XLA's own cost_analysis counts a scanned body once; this parser counts
it `known_trip_count` times, which is what makes microbatched train
steps and decode loops come out right.

Accounting model:
  flops        — dot/convolution FLOPs (2 * out_elems * contraction).
  hbm_bytes    — operand + result bytes of every materializing op
                 (fusions count their boundary, not their interior).
  collectives  — payload bytes and *wire* bytes: payload scaled by the
                 ring factor of the collective kind (all-reduce moves
                 2(n-1)/n of its payload per link, all-gather /
                 reduce-scatter (n-1)/n, permutes 1.0).

`roofline_from_report` turns a CostReport into the three roofline time
terms under the reference chip below and names the dominant one.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# Reference chip for roofline terms (a TPU-class accelerator).
PEAK_FLOPS = 197e12          # FLOP/s (bf16 systolic peak)
HBM_BANDWIDTH = 819e9        # B/s
ICI_BANDWIDTH = 45e9         # B/s per device, all links combined

# Sizes are in *bits* so sub-byte dtypes (s4/u4) stay integral: each
# array's bit volume is rounded up to whole bytes once, per array, the
# way a packed buffer is actually allocated.
_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8,
    "s16": 16, "u16": 16, "f16": 16, "bf16": 16,
    "s32": 32, "u32": 32, "f32": 32,
    "s64": 64, "u64": 64, "f64": 64, "c64": 64, "c128": 128,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3b11fnuz": 8, "f8e4m3fnuz": 8,
    "f8e5m2fnuz": 8,
    # zero-byte marker types (control-flow plumbing, not data)
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

# ops that neither move HBM bytes nor compute (bookkeeping / control flow —
# control flow is descended into instead)
_FREE_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "add-dependency", "domain", "opt-barrier",
})


def _dims(dim_str: str) -> list[int]:
  return [int(d) for d in dim_str.split(",") if d]


def _shape_bytes(shape_str: str) -> int:
  """Total bytes of every array in a (possibly tuple) shape string.

  Always integral: bit volume is accumulated per array and rounded up to
  whole bytes per array (so `s4[5]` is 3 bytes, not 2.5)."""
  total = 0
  for dtype, dim_str in _SHAPE_RE.findall(shape_str):
    bits = _DTYPE_BITS.get(dtype)
    if bits is None:
      continue
    n = 1
    for d in _dims(dim_str):
      n *= d
    total += (n * bits + 7) // 8
  return total


def _first_array_dims(shape_str: str) -> Optional[list[int]]:
  m = _SHAPE_RE.search(shape_str)
  return _dims(m.group(2)) if m else None


def _wire_factor(kind: str, group_size: int) -> float:
  """Per-device wire bytes per payload byte on a ring of `group_size`."""
  if group_size <= 1:
    return 0.0
  n = float(group_size)
  if "all-reduce" in kind:
    return 2.0 * (n - 1.0) / n
  if "all-gather" in kind or "reduce-scatter" in kind:
    return (n - 1.0) / n
  return 1.0                       # all-to-all / permutes / broadcast


def _group_size(line: str, n_devices: int) -> int:
  m = _GROUPS_BRACE_RE.search(line)
  if m:
    return len(_dims(m.group(1)))
  m = _GROUPS_IOTA_RE.search(line)
  if m:
    dims = _dims(m.group(1))
    return dims[-1] if dims else n_devices
  return n_devices


# ---------------------------------------------------------------------------
# Report dataclasses.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostReport:
  flops: float = 0.0
  dot_flops: float = 0.0
  hbm_bytes: float = 0.0
  collective_bytes: float = 0.0
  collective_wire_bytes: float = 0.0
  n_collectives: int = 0
  collective_by_kind: dict = dataclasses.field(default_factory=dict)
  hbm_by_shape: dict = dataclasses.field(default_factory=dict)
  #: {token: count} of things the parser could not fully account —
  #: "<unparsed>" for instruction lines _split_instr rejected (their
  #: bytes are still counted, as generic traffic from every shape token
  #: on the line) and "dtype:<name>" for dtypes missing from
  #: _DTYPE_BITS (whose arrays contribute zero bytes). Audit tooling
  #: (repro.analysis) surfaces this so parser gaps are visible instead
  #: of silently under-counting.
  unknown_ops: dict = dataclasses.field(default_factory=dict)

  def add(self, other: "CostReport", mult: float = 1.0) -> None:
    self.flops += other.flops * mult
    self.dot_flops += other.dot_flops * mult
    self.hbm_bytes += other.hbm_bytes * mult
    self.collective_bytes += other.collective_bytes * mult
    self.collective_wire_bytes += other.collective_wire_bytes * mult
    self.n_collectives += int(other.n_collectives * mult)
    for k, v in other.collective_by_kind.items():
      self.collective_by_kind[k] = (self.collective_by_kind.get(k, 0.0)
                                    + v * mult)
    for k, v in other.hbm_by_shape.items():
      self.hbm_by_shape[k] = self.hbm_by_shape.get(k, 0.0) + v * mult
    for k, v in other.unknown_ops.items():
      self.unknown_ops[k] = self.unknown_ops.get(k, 0) + int(v * mult)


@dataclasses.dataclass(frozen=True)
class Roofline:
  compute_s: float
  memory_s: float
  collective_s: float
  dominant: str                    # "compute" | "memory" | "collective"
  useful_flop_fraction: float
  roofline_fraction: float


def roofline_from_report(rep: CostReport,
                         model_flops: Optional[float] = None) -> Roofline:
  """The three roofline time terms under the reference chip.

  `model_flops` (the analytic 6ND/2ND estimate, per device) feeds
  useful_flop_fraction — how much of the executed FLOP volume is model
  math rather than remat/overhead."""
  compute_s = rep.flops / PEAK_FLOPS
  memory_s = rep.hbm_bytes / HBM_BANDWIDTH
  collective_s = rep.collective_wire_bytes / ICI_BANDWIDTH
  terms = {"compute": compute_s, "memory": memory_s,
           "collective": collective_s}
  dominant = max(terms, key=terms.get)
  total = compute_s + memory_s + collective_s
  useful = (model_flops / rep.flops if model_flops and rep.flops
            else (rep.dot_flops / rep.flops if rep.flops else 0.0))
  return Roofline(
      compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
      dominant=dominant,
      useful_flop_fraction=useful,
      roofline_fraction=terms[dominant] / total if total else 0.0)


# ---------------------------------------------------------------------------
# HLO text parsing.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Instr:
  opcode: str
  shape: str                       # result shape string
  operands: str                    # text inside the opcode's parens
  attrs: str                       # text after the closing paren
  line: str


#: sentinel opcode for instruction lines `_split_instr` could not parse
_UNPARSED = "<unparsed>"

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_CALLED_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "true": re.compile(r"true_computation=%?([\w.\-]+)"),
    "false": re.compile(r"false_computation=%?([\w.\-]+)"),
}


def _split_instr(line: str) -> Optional[_Instr]:
  eq = line.find(" = ")
  if eq < 0:
    return None
  rest = line[eq + 3:]
  # result shape: either "(tuple, ...)" or "dtype[dims]{layout}"
  if rest.startswith("("):
    depth, i = 0, 0
    for i, ch in enumerate(rest):
      depth += ch == "("
      depth -= ch == ")"
      if depth == 0:
        break
    shape, rest = rest[:i + 1], rest[i + 1:].lstrip()
  else:
    sp = rest.find(" ")
    if sp < 0:
      return None
    shape, rest = rest[:sp], rest[sp + 1:]
  par = rest.find("(")
  if par < 0:
    return None
  opcode = rest[:par].strip()
  depth = 0
  end = len(rest) - 1
  for j in range(par, len(rest)):
    depth += rest[j] == "("
    depth -= rest[j] == ")"
    if depth == 0:
      end = j
      break
  return _Instr(opcode=opcode, shape=shape, operands=rest[par + 1:end],
                attrs=rest[end + 1:], line=line)


def _parse_computations(text: str) -> tuple[dict, Optional[str]]:
  comps: dict[str, list[_Instr]] = {}
  entry = None
  current: Optional[list] = None
  for line in text.splitlines():
    if current is None:
      m = _HEADER_RE.match(line)
      if m:
        name = m.group(2)
        comps[name] = current = []
        if m.group(1):
          entry = name
    elif line.strip() == "}":
      current = None
    else:
      ins = _split_instr(line)
      if ins is None and " = " in line:
        # An instruction line the splitter rejected. Keep it as a sentinel
        # so the cost walk can count its shape tokens as generic traffic
        # (and report it) instead of dropping it on the floor.
        ins = _Instr(opcode=_UNPARSED, shape=line, operands="", attrs="",
                     line=line)
      if ins is not None:
        current.append(ins)
  if entry is None and comps:
    entry = next(reversed(comps))
  return comps, entry


def _dot_flops(ins: _Instr) -> float:
  out = _first_array_dims(ins.shape) or []
  lhs = _first_array_dims(ins.operands) or []
  m = _CONTRACT_RE.search(ins.attrs)
  contract = 1.0
  if m:
    for idx in _dims(m.group(1)):
      if idx < len(lhs):
        contract *= lhs[idx]
  out_elems = 1.0
  for d in out:
    out_elems *= d
  return 2.0 * out_elems * contract


def _conv_flops(ins: _Instr) -> float:
  out = _first_array_dims(ins.shape) or []
  shapes = _SHAPE_RE.findall(ins.operands)
  if len(shapes) < 2:
    return 0.0
  kernel = _dims(shapes[1][1])
  k_elems = 1.0
  for d in kernel:
    k_elems *= d
  m = _DIM_LABELS_RE.search(ins.attrs)
  o_dim = kernel[-1] if kernel else 1
  if m and kernel:
    o_idx = m.group(2).find("o")
    if 0 <= o_idx < len(kernel):
      o_dim = kernel[o_idx]
  out_elems = 1.0
  for d in out:
    out_elems *= d
  return 2.0 * out_elems * (k_elems / max(o_dim, 1))


def analyze_module(hlo_text: str, n_devices: int = 1) -> CostReport:
  """Parse a post-optimization HLO module dump into a CostReport.

  The module is already SPMD-partitioned, so all byte/FLOP figures are
  per-device; `n_devices` is the fallback collective group size when an
  instruction carries no parseable replica_groups."""
  comps, entry = _parse_computations(hlo_text)
  memo: dict[str, CostReport] = {}

  def called(ins: _Instr, key: str) -> Optional[str]:
    m = _CALLED_RE[key].search(ins.attrs)
    return m.group(1) if m else None

  def cost(name: str) -> CostReport:
    if name in memo:
      return memo[name]
    memo[name] = CostReport()      # cycle guard (HLO graphs are acyclic)
    rep = CostReport()
    for ins in comps.get(name, ()):
      op = ins.opcode
      for d, _ in _SHAPE_RE.findall(ins.shape):
        if d not in _DTYPE_BITS:
          key = f"dtype:{d}"
          rep.unknown_ops[key] = rep.unknown_ops.get(key, 0) + 1
      if op == _UNPARSED:
        rep.unknown_ops[_UNPARSED] = rep.unknown_ops.get(_UNPARSED, 0) + 1
        rep.hbm_bytes += _shape_bytes(ins.line)
        continue
      if op == "while":
        m = _TRIP_RE.search(ins.attrs)
        trip = float(m.group(1)) if m else 1.0
        body = called(ins, "body")
        cond = called(ins, "condition")
        if body:
          rep.add(cost(body), trip)
        if cond:
          rep.add(cost(cond), trip)
        continue
      if op == "conditional":
        branches = []
        m = _CALLED_RE["branches"].search(ins.attrs)
        if m:
          branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
        else:
          branches = [b for b in (called(ins, "true"), called(ins, "false"))
                      if b]
        if branches:
          costs = [cost(b) for b in branches if b in comps]
          if costs:
            rep.add(max(costs, key=lambda c: c.flops + c.hbm_bytes))
        continue
      if op == "call":
        tgt = called(ins, "to_apply")
        if tgt:
          rep.add(cost(tgt))
        continue
      if op == "fusion":
        tgt = called(ins, "calls")
        if tgt:
          inner = cost(tgt)
          rep.flops += inner.flops          # dots fused into the kernel
          rep.dot_flops += inner.dot_flops
        # fall through: the fusion boundary is the HBM traffic
      if op == "dot":
        f = _dot_flops(ins)
        rep.flops += f
        rep.dot_flops += f
      elif op == "convolution":
        rep.flops += _conv_flops(ins)
      base = op.replace("-start", "")
      if base in COLLECTIVE_OPS and not op.endswith("-done"):
        payload = max(_shape_bytes(ins.shape), _shape_bytes(ins.operands))
        g = _group_size(ins.line, n_devices)
        wire = payload * _wire_factor(base, g)
        rep.collective_bytes += payload
        rep.collective_wire_bytes += wire
        rep.n_collectives += 1
        rep.collective_by_kind[base] = (
            rep.collective_by_kind.get(base, 0.0) + wire)
      if op in _FREE_OPS or op.endswith("-done"):
        continue
      b = _shape_bytes(ins.shape) + _shape_bytes(ins.operands)
      rep.hbm_bytes += b
      out_b = _shape_bytes(ins.shape)
      if out_b:
        rep.hbm_by_shape[ins.shape] = (
            rep.hbm_by_shape.get(ins.shape, 0.0) + out_b)
    memo[name] = rep
    return rep

  if entry is None:
    return CostReport()
  return cost(entry)
