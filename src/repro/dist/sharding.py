"""Named sharding rules over the FactoredLinear logical namespace.

Sharding is declared ONCE, here, by logical name — the same `"*/rec"` /
`"*/nonrec"` / `"layers/attn_q"` namespace that `FactorizationPlan`
matches on — and consumed everywhere: the trainer, the serving engine and
the dry-run all obtain their constraint callable through the single
`make_constraint(mesh, cfg, batch, decode=...)` entry point, and their
jit boundaries through `param_shardings` / `state_shardings` /
`batch_shardings`.

Two namespaces:

* **Parameter rules** match a FactoredLinear's logical `name` with glob
  patterns (PARAM_RULES). Unfactored weights get the classic Megatron
  split: up-projections column-parallel P(None, "model"), down/out
  projections row-parallel P("model", None), expert stacks
  expert-parallel on the leading E axis. Factored nodes shard U
  column-wise (chop each length-m column across "model") and V row-wise
  (chop each length-n row across "model") so the rank axis stays local:
  the (x@U)@V contraction over r never crosses devices, and stage-2
  truncation — which only changes r — never reshards a checkpoint.

* **Activation rules** (ACTIVATION_RULES) match the short logical names
  models pass to `cs(x, name)`: "bsd", "bsv", "bsf", "bshd_q", "gecd",
  ... Each maps dimensions to mesh-axis roles; "data" expands to the
  mesh's (pod, data) axes.

Every rule is divisibility-gated against the concrete shape: an axis
whose mesh degree does not divide the dimension is dropped (to None)
rather than forcing padded/uneven layouts — decode batches of 1 and
tiny smoke dims degrade gracefully to replication.
"""
from __future__ import annotations

import fnmatch
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.factored import FactoredLinear, is_gemm_leaf
from repro.dist.mesh import MODEL_AXIS, dp_axes
from repro.quant.leaf import QuantizedLinear
# The contract types live in the leaf module model code already imports;
# re-exported here so dist.sharding stays the one public constraint surface.
from repro.layers.common import Constraint, identity_constraint

# _path_tokens is deliberately part of this module's exported surface (the
# dry-run's sharding-override hook keys on it) despite the underscore name.
__all__ = ["Constraint", "identity_constraint", "make_constraint",
           "param_shardings", "state_shardings", "batch_shardings",
           "replicated", "_path_tokens", "ACTIVATION_RULES", "PARAM_RULES",
           "RuleMesh", "rule_coverage"]


# ---------------------------------------------------------------------------
# Rule tables. "data" expands to the mesh's dp axes, "model" to MODEL_AXIS.
# ---------------------------------------------------------------------------

# activation logical name -> per-dimension axis roles
ACTIVATION_RULES: dict[str, tuple] = {
    "bsd": ("data", None, None),             # residual stream (b, s, d)
    "bsv": ("data", None, "model"),          # logits (b, s, vocab)
    "bsf": ("data", None, "model"),          # FFN hidden (b, s, d_ff)
    "bsi": ("data", None, "model"),          # mamba inner (b, s, d_inner)
    "bt3h": ("data", None, "model"),         # GRU gates (b, t, 3h)
    "bshd_q": ("data", None, "model", None),   # q heads
    "bshd_kv": ("data", None, "model", None),  # kv heads (GQA: may gate off)
    "gecd": ("data", "model", None, None),   # MoE dispatch buffer (G,E,C,D)
    "gecf": ("data", "model", None, None),   # MoE expert hidden (G,E,C,F)
}

# parameter logical-name globs -> rule kind, first match wins
PARAM_RULES: tuple[tuple[str, str], ...] = (
    ("*/expert_*", "expert"),    # stacked (E, m, n) expert weights -> EP
    ("*/attn_o", "row"),
    ("*/xattn_o", "row"),
    ("*/mla_o", "row"),
    ("*/ffn_down", "row"),
    ("*/ffn_out", "row"),
    ("*/mlstm_down", "row"),
    ("*/slstm_out", "row"),
    ("*/ssm_out", "row"),
    ("out", "row"),              # DS2 CTC output head (fc_dim, vocab) stays
                                 # row-split: vocab ~ 32 never divides TP
    ("*", "col"),                # q/k/v, gates, ups, rec/nonrec, lm_head, ...
)


def _expand(role, mesh) -> tuple[str, ...]:
  """Axis role -> concrete mesh axes (only those present on the mesh)."""
  if role is None:
    return ()
  if role == "data":
    return dp_axes(mesh)
  if role == "model":
    return (MODEL_AXIS,) if MODEL_AXIS in mesh.axis_names else ()
  return (role,) if role in mesh.axis_names else ()


def _gate(template: Sequence, shape: Sequence[int], mesh) -> Optional[P]:
  """Divisibility-gate a role template against a concrete shape.

  Returns None when the template rank does not match the array rank
  (caller replicates / passes through)."""
  if len(template) != len(shape):
    return None
  spec = []
  for role, dim in zip(template, shape):
    axes = _expand(role, mesh)
    size = math.prod(mesh.shape[a] for a in axes) if axes else 1
    if axes and size > 1 and dim % size == 0:
      spec.append(axes if len(axes) > 1 else axes[0])
    else:
      spec.append(None)
  return P(*spec)


# ---------------------------------------------------------------------------
# Parameter rules.
# ---------------------------------------------------------------------------

def _param_rule(name: str) -> str:
  for pat, kind in PARAM_RULES:
    if fnmatch.fnmatch(name, pat):
      return kind
  return "col"


def _weight_template(kind: str, ndim: int, field: str) -> tuple:
  """Role template for one FactoredLinear field (w | u | v).

  Unfactored w follows the Megatron split of its rule. Factored u/v use
  the uniform rank-local layout: u (m, r) chops m, v (r, n) chops n —
  both leave r unsharded, so the only collective in (x@U)@V is one
  all-reduce of the skinny rank-r intermediate, and stage-2 truncation
  (a change of r only) never reshards."""
  lead = (None,) * max(ndim - 2, 0)
  if kind == "expert":
    # (..., E, m, n): expert-parallel over the E axis, factors alike
    if ndim < 3:
      return _weight_template("col", ndim, field)
    return (None,) * (ndim - 3) + ("model", None, None)
  if field == "u":
    return lead + ("model", None)
  if field == "v":
    return lead + (None, "model")
  if kind == "row":
    return lead + ("model", None)
  return lead + (None, "model")                    # "col"


#: QuantizedLinear array fields, in dataclass order
_QUANT_FIELDS = ("w_q", "w_scale", "u_q", "u_scale", "v_q", "v_scale",
                 "act_scale")


def _quant_field_template(kind: str, field: str, ndim: int) -> tuple:
  """Role template for one QuantizedLinear field.

  int8 payloads (w_q/u_q/v_q) shard exactly like the float field they
  were quantized from (same rank-local u/v layout, same Megatron
  row/col split for w). Per-column scale vectors ride with their
  weight's column axis — a col-split w_q keeps its (n,) w_scale split
  the same way, so the dequantize stays device-local; a stacked
  ([L,] n) scale keeps its leading layer axes unsharded like the
  payload's. u_scale is per-rank and the rank axis is always local;
  act_scale is a scalar."""
  if field.endswith("_q"):
    return _weight_template(kind, ndim, field[0])
  if field in ("w_scale", "v_scale"):
    return ((None,) * (ndim - 1)
            + (_weight_template(kind, 2, field[0])[-1],))
  return (None,) * ndim            # u_scale (rank-local), act_scale ()


def _with_fsdp(spec: P, shape: Sequence[int], mesh) -> P:
  """Add the dp axes to the first unsharded dimension they divide.

  For stacked per-layer weights (ndim >= 3) this is the leading layer
  axis — the ZeRO/FSDP layout whose gather happens inside the remat
  region via cs(lp, "layer_params")."""
  axes = dp_axes(mesh)
  size = math.prod(mesh.shape[a] for a in axes) if axes else 1
  if size <= 1:
    return spec
  entries = list(spec) + [None] * (len(shape) - len(spec))
  for i, (e, dim) in enumerate(zip(entries, shape)):
    if e is None and dim % size == 0 and dim > 1:
      entries[i] = axes if len(axes) > 1 else axes[0]
      return P(*entries)
  return spec


def _leaf_spec(shape: Sequence[int], mesh, *, name: Optional[str] = None,
               field: str = "w", path: Sequence[str] = (),
               fsdp: bool = False, expert_2d: bool = False) -> P:
  """Spec for one array leaf — a FactoredLinear field (by logical name)
  or a raw array (by tree path)."""
  ndim = len(shape)
  if name is not None:
    kind = _param_rule(name)
    spec = _gate(_weight_template(kind, ndim, field), shape, mesh) or P()
    if expert_2d and kind == "expert" and ndim >= 3:
      spec = _with_fsdp(spec, shape, mesh)         # 2D EP for serving
  elif path and path[-1] == "table" and ndim == 2:
    # embedding table (vocab, d): vocab-sharded; gathers are tiny
    spec = _gate(("model", None), shape, mesh) or P()
  else:
    spec = P()            # router / norm scales / biases / step counters
  if fsdp:
    spec = _with_fsdp(spec, shape, mesh)
  return spec


def _path_tokens(path) -> list[str]:
  """Key path -> string tokens ("moe_layers", "attn", "wq", "u", ...)."""
  toks = []
  for k in path:
    if hasattr(k, "key"):
      toks.append(str(k.key))
    elif hasattr(k, "name"):
      toks.append(str(k.name))
    elif hasattr(k, "idx"):
      toks.append(str(k.idx))
    else:
      toks.append(str(k))
  return toks


def param_shardings(params: Any, mesh, *, fsdp: bool = False,
                    expert_2d: bool = False) -> Any:
  """NamedSharding tree matching `params` (arrays or ShapeDtypeStructs).

  FactoredLinear nodes are matched by logical name, raw leaves by tree
  path; the result preserves the tree structure (FactoredLinear nodes
  carry shardings in their w/u/v fields) so it is directly usable as jit
  in_shardings / out_shardings."""
  def on_node(path, leaf):
    if isinstance(leaf, FactoredLinear):
      def fld(field):
        arr = getattr(leaf, field)
        if arr is None:
          return None
        return NamedSharding(mesh, _leaf_spec(
            arr.shape, mesh, name=leaf.name, field=field,
            fsdp=fsdp, expert_2d=expert_2d))
      return FactoredLinear(w=fld("w"), u=fld("u"), v=fld("v"),
                            name=leaf.name, group=leaf.group)
    if isinstance(leaf, QuantizedLinear):
      # serving artifact: no FSDP axis (that is a training layout)
      kind = _param_rule(leaf.name)
      def qfld(field):
        arr = getattr(leaf, field)
        if arr is None:
          return None
        spec = _gate(_quant_field_template(kind, field, arr.ndim),
                     arr.shape, mesh) or P()
        return NamedSharding(mesh, spec)
      return QuantizedLinear(
          **{f: qfld(f) for f in _QUANT_FIELDS},
          name=leaf.name, group=leaf.group, orig_dtype=leaf.orig_dtype)
    return NamedSharding(mesh, _leaf_spec(
        leaf.shape, mesh, path=_path_tokens(path), fsdp=fsdp,
        expert_2d=expert_2d))
  return jax.tree_util.tree_map_with_path(
      on_node, params, is_leaf=is_gemm_leaf)


def batch_shardings(batch: Any, mesh, shape) -> Any:
  """Inputs shard their leading (global batch) dimension over the dp axes."""
  def f(leaf):
    if leaf.ndim and leaf.shape[0] == shape.global_batch:
      spec = _gate(("data",) + (None,) * (leaf.ndim - 1), leaf.shape, mesh)
      return NamedSharding(mesh, spec or P())
    return NamedSharding(mesh, P())
  return jax.tree.map(f, batch)


def state_shardings(state: Any, mesh, shape) -> Any:
  """Decode-state rules: batch dim -> dp axes, max_len dim -> model axis.

  Length-sharding the KV cache is what keeps 500k-token contexts on
  chip: each model shard owns 1/TP of the sequence axis and attention
  reduces across it."""
  def f(leaf):
    roles: list = [None] * leaf.ndim
    # the length axis sits AFTER the batch axis in every cache layout, so
    # match it last-first — otherwise a batch dim that happens to equal
    # max_len (batch == seq_len configs) would steal the model-axis role
    len_dim = None
    for i in range(leaf.ndim - 1, -1, -1):
      if leaf.shape[i] == shape.seq_len and leaf.shape[i] > 1:
        len_dim = i
        roles[i] = "model"
        break
    for i, dim in enumerate(leaf.shape):
      if i != len_dim and dim == shape.global_batch and dim > 1:
        roles[i] = "data"
        break
    return NamedSharding(mesh, _gate(tuple(roles), leaf.shape, mesh) or P())
  return jax.tree.map(f, state)


def replicated(mesh) -> NamedSharding:
  return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Coverage introspection (repro.analysis check 5).
# ---------------------------------------------------------------------------

class RuleMesh:
  """Axis-names/sizes-only stand-in for a jax Mesh.

  The rule logic (`_expand` / `_gate` / `dp_axes`) reads only
  `.axis_names` and `.shape`, so this is enough to answer "how WOULD
  this tree shard on a (data=2, model=4) mesh" on hosts that don't have
  the devices to build a real one — which is exactly what rule-coverage
  auditing needs. Not usable where a real Mesh is required
  (NamedSharding construction)."""

  def __init__(self, **axes: int):
    self.shape = dict(axes)

  @property
  def axis_names(self) -> tuple:
    return tuple(self.shape)


def rule_coverage(params: Any, mesh=None) -> list:
  """Per-array-leaf rule attribution over a params tree (arrays or
  ShapeDtypeStructs) — the introspection half of `param_shardings`.

  Walks the tree exactly the way `param_shardings` does (FactoredLinear
  and QuantizedLinear nodes matched by logical name against PARAM_RULES;
  every other leaf by tree path) and reports, per array leaf:

    name     logical GEMM name, or None for path-matched leaves
    field    GEMM-leaf field ("w"/"u"/"v"/"w_q"/"u_scale"/...) or last
             path token
    path     "/"-joined tree path
    rule     PARAM_RULES kind, "embedding_table", or None (replicated)
    matches  how many PARAM_RULES globs match the name (first wins;
             includes the catchall — 0 for path-matched leaves)
    shape / size / bytes / spec / sharded   the gated outcome on `mesh`
    shard_factor   how many devices split this leaf (product of the
             gated spec's mesh-axis sizes; bytes / shard_factor is the
             per-device footprint the compression ledger reports)

  `mesh` defaults to RuleMesh(data=2, model=4), a canonical small
  production topology where every intended split is representable."""
  mesh = RuleMesh(data=2, model=4) if mesh is None else mesh
  entries: list = []

  def n_matches(name: str) -> int:
    return sum(1 for pat, _ in PARAM_RULES if fnmatch.fnmatch(name, pat))

  def spec_factor(spec: P) -> int:
    f = 1
    for e in tuple(spec):
      if e is None:
        continue
      for a in (e if isinstance(e, tuple) else (e,)):
        f *= int(mesh.shape[a])
    return f

  def emit(spec: P, arr, **kw) -> None:
    shape = tuple(arr.shape)
    size = int(math.prod(shape))
    entries.append(dict(
        shape=shape, size=size, bytes=size * arr.dtype.itemsize,
        spec=str(spec), sharded=any(e is not None for e in tuple(spec)),
        shard_factor=spec_factor(spec), **kw))

  def on_node(path, leaf):
    toks = _path_tokens(path)
    if isinstance(leaf, FactoredLinear):
      kind = _param_rule(leaf.name)
      for field in ("w", "u", "v"):
        arr = getattr(leaf, field)
        if arr is None:
          continue
        spec = _gate(_weight_template(kind, arr.ndim, field),
                     tuple(arr.shape), mesh) or P()
        emit(spec, arr, name=leaf.name, field=field, path="/".join(toks),
             rule=kind, matches=n_matches(leaf.name))
      return leaf
    if isinstance(leaf, QuantizedLinear):
      kind = _param_rule(leaf.name)
      for field in _QUANT_FIELDS:
        arr = getattr(leaf, field)
        if arr is None:
          continue
        spec = _gate(_quant_field_template(kind, field, arr.ndim),
                     tuple(arr.shape), mesh) or P()
        emit(spec, arr, name=leaf.name, field=field, path="/".join(toks),
             rule=kind, matches=n_matches(leaf.name))
      return leaf
    shape = tuple(leaf.shape)
    rule = None
    if toks and toks[-1] == "table" and len(shape) == 2:
      rule = "embedding_table"
      spec = _gate(("model", None), shape, mesh) or P()
    else:
      spec = P()
    emit(spec, leaf, name=None, field=toks[-1] if toks else "",
         path="/".join(toks), rule=rule, matches=0)
    return leaf

  jax.tree_util.tree_map_with_path(on_node, params, is_leaf=is_gemm_leaf)
  return entries


# ---------------------------------------------------------------------------
# The constraint callable — the one execution surface.
# ---------------------------------------------------------------------------

def _constrain_layer_params(tree: Any, mesh) -> Any:
  """cs(lp, "layer_params"): re-constrain one scanned layer slice.

  Under FSDP/ZeRO the layer stack is sharded along its leading layer
  axis; the per-layer slice inside the scan body is constrained back to
  the TP-resident layout (weights keep their model-axis split, small
  arrays replicate), so the all-gather happens INSIDE the remat region
  and the backward pass re-gathers instead of keeping all layers live."""
  def on_node(leaf):
    if isinstance(leaf, FactoredLinear):
      def fld(field):
        arr = getattr(leaf, field)
        if arr is None:
          return None
        spec = _leaf_spec(arr.shape, mesh, name=leaf.name, field=field)
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(mesh, spec))
      return FactoredLinear(w=fld("w"), u=fld("u"), v=fld("v"),
                            name=leaf.name, group=leaf.group)
    if isinstance(leaf, QuantizedLinear):
      kind = _param_rule(leaf.name)
      def qfld(field):
        arr = getattr(leaf, field)
        if arr is None:
          return None
        spec = _gate(_quant_field_template(kind, field, arr.ndim),
                     arr.shape, mesh) or P()
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(mesh, spec))
      return QuantizedLinear(
          **{f: qfld(f) for f in _QUANT_FIELDS},
          name=leaf.name, group=leaf.group, orig_dtype=leaf.orig_dtype)
    return jax.lax.with_sharding_constraint(
        leaf, NamedSharding(mesh, P()))
  return jax.tree.map(on_node, tree, is_leaf=is_gemm_leaf)


def make_constraint(mesh, cfg, global_batch: int, *, decode: bool = False,
                    rule_overrides: Optional[dict] = None) -> Constraint:
  """Build the `cs(x, logical_name) -> x` constraint callable.

  This is the ONLY constraint entry point: the trainer, the serving
  engine and the dry-run builders all call it, so a sharding decision is
  made exactly once per logical name. With mesh=None it returns
  `identity_constraint` (single-device training / CPU smoke tests).

  Args:
    mesh: the jax Mesh (or None for single-device identity).
    cfg: the ModelConfig the step runs (part of the contract so rules
      can specialize per family without new call sites).
    global_batch: the step's global batch — decode batches of 1 and
      other non-divisible sizes gate their data axis off.
    decode: True for cached serve steps (kept for rule specialization;
      the divisibility gate already handles the batch-of-1 case).
    rule_overrides: {logical name: role-template or PartitionSpec} —
      the perf-hillclimb hook for trying alternative layouts without
      touching model code.
  """
  del cfg, global_batch, decode   # rules are name+shape driven today
  if mesh is None:
    return identity_constraint
  rules = dict(ACTIVATION_RULES)
  if rule_overrides:
    rules.update(rule_overrides)

  def _apply_rule(x, rule):
    if isinstance(rule, P):
      return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, rule))
    spec = _gate(rule, x.shape, mesh)
    if spec is None:
      return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

  def cs(x, name: str):
    if name == "layer_params":
      override = (rule_overrides or {}).get("layer_params")
      if override is None:
        return _constrain_layer_params(x, mesh)
      # an overridden layer-slice layout applies leaf-wise over the tree
      # (P() replicates everything; templates gate per leaf rank/shape)
      return jax.tree.map(lambda a: _apply_rule(a, override), x)
    rule = rules.get(name)
    if rule is None:
      return x                    # unknown logical names pass through
    return _apply_rule(x, rule)

  return cs
