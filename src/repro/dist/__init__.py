"""`repro.dist` — the distributed-execution subsystem.

One public surface for every execution mode (train / serve / dry-run):

  dist.mesh      — mesh construction (pure functions; importing this
                   package never touches jax device state).
  dist.sharding  — named sharding rules over the FactoredLinear logical
                   namespace + `make_constraint`, the single entry point
                   that produces the `cs` callable every model threads
                   through its forward/decode functions.
  dist.hlo_cost  — lowered-HLO FLOP / byte / collective accounting and
                   roofline extraction for the dry-run cost tables.
"""
from repro.dist import hlo_cost, mesh, sharding
from repro.dist.mesh import (dp_axes, dp_size, make_host_mesh, make_mesh,
                             make_production_mesh, model_size)
from repro.dist.sharding import (batch_shardings, identity_constraint,
                                 make_constraint, param_shardings,
                                 replicated, state_shardings)

__all__ = [
    "hlo_cost", "mesh", "sharding",
    "dp_axes", "dp_size", "make_host_mesh", "make_mesh",
    "make_production_mesh", "model_size",
    "batch_shardings", "identity_constraint", "make_constraint",
    "param_shardings", "replicated", "state_shardings",
]
