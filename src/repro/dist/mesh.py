"""Mesh construction — pure functions over an explicit device list.

Importing this module never touches jax device state: `jax.devices()` is
only consulted inside a function body when the caller passes no devices.
That property is load-bearing for the dry-run, which must set
`XLA_FLAGS=--xla_force_host_platform_device_count=...` before the first
device enumeration.

Axis-name conventions (shared with dist.sharding):
  "pod"   — outer data-parallel axis across pods (slow links),
  "data"  — data-parallel axis within a pod,
  "model" — tensor/expert-parallel axis.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

# Data-parallel axes, outermost first. Everything else is model-parallel.
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"


def make_mesh(shape: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None) -> Mesh:
  """Reshape `devices` (default: all local) into a named Mesh.

  Extra devices beyond prod(shape) are ignored, so callers can pass
  `jax.devices()` and carve sub-meshes (e.g. 256 of 512 for single-pod).
  """
  devices = list(jax.devices()) if devices is None else list(devices)
  n = math.prod(shape)
  if len(devices) < n:
    raise ValueError(f"mesh {tuple(shape)} needs {n} devices, "
                     f"got {len(devices)}")
  if len(shape) != len(axis_names):
    raise ValueError(f"shape {tuple(shape)} / axis_names {tuple(axis_names)}"
                     " rank mismatch")
  arr = np.asarray(devices[:n], dtype=object).reshape(tuple(shape))
  return Mesh(arr, tuple(axis_names))


def make_host_mesh(axis_names: Sequence[str] = ("data", "model"), *,
                   model: int = 1,
                   devices: Optional[Sequence] = None) -> Mesh:
  """All local devices as a (data, model) mesh with `model`-way TP.

  The CPU-test / single-host entry point: `make_host_mesh()` is pure DP
  over whatever the process sees; `make_host_mesh(model=2)` folds the
  trailing factor into a model axis.
  """
  devices = list(jax.devices()) if devices is None else list(devices)
  n = len(devices)
  if len(axis_names) == 1:
    return make_mesh((n,), axis_names, devices=devices)
  if n % model:
    raise ValueError(f"{n} devices not divisible by model={model}")
  return make_mesh((n // model, model), tuple(axis_names)[:2],
                   devices=devices)


def make_production_mesh(multi_pod: bool = False, *,
                         devices: Optional[Sequence] = None) -> Mesh:
  """The two production topologies the dry-run compiles for:

  single-pod  (16, 16)      ("data", "model")         256 chips
  multi-pod   (2, 16, 16)   ("pod", "data", "model")  512 chips
  """
  devices = list(jax.devices()) if devices is None else list(devices)
  if multi_pod:
    return make_mesh((2, 16, 16), ("pod", "data", "model"),
                     devices=devices[:512])
  return make_mesh((16, 16), ("data", "model"), devices=devices[:256])


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
  """The mesh's data-parallel axis names, outermost first."""
  return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
  """Total data-parallel degree (product over dp_axes)."""
  return math.prod(mesh.shape[a] for a in dp_axes(mesh)) if dp_axes(mesh) \
      else 1


def model_size(mesh: Mesh) -> int:
  """Model-parallel degree (1 when the mesh has no model axis)."""
  return int(mesh.shape.get(MODEL_AXIS, 1))
