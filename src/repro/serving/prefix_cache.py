"""Radix-trie prefix cache: shared-prompt serving without re-prefill.

Fleet traffic is dominated by prompt overlap — system prompts, few-shot
templates, multi-turn histories — and the paper's whole §4 economics are
about weight traffic at small batch, so recomputing an identical prefill
for every request is pure waste. This module caches *decode-state
snapshots* keyed by token prefixes so `LMEngine._admit` can splice a
cached prefix into a slot and run the bucketed fused prefill only over
the uncached suffix (same `make_prefill_program`, same bucket
signatures — the splice itself is eager slot surgery, never a new jit
program).

What a snapshot is (the per-family contract, `ModelApi.prefix_view`):

  attention KV / MLA latents   rows [0, m) sliced on the length axis —
                               the only rows a causal decode ever reads;
                               splicing writes them back into a fresh
                               max_len-shaped state (zeros elsewhere,
                               exactly what a cold prefill leaves there)
  SSM / GRU / xLSTM carries    the fixed-size carry tensor, copied whole
                               — valid at EXACTLY the snapshot length m
                               (read-modify-write state cannot be sliced
                               to a shorter prefix)
  step-invariant leaves        (whisper's encoder memory) copied whole

Because carries are only valid at their exact length, entries are never
truncated at lookup: `match_longest_prefix` returns the longest *whole
inserted entry* that prefixes the query, not an arbitrary trie position.
Splicing a hit is then bit-exact: the reconstructed batch-1 state equals
the cold prefill's state after m tokens bit-for-bit, so cached-splice
greedy serving is token-for-token identical to cold serving (pinned by
tests/test_prefix_cache.py and the `prefix_splice_stability` check in
repro.analysis).

Eviction is byte-accounted LRU: every entry's snapshot bytes (summed
over array leaves) count against `capacity_mb`; inserting past capacity
evicts least-recently-used entries first (lookup hits refresh recency).
An entry bigger than the whole capacity is rejected, not admitted.
Counters (hits / misses / evictions / inserts / bytes) surface through
`stats()` — `LMEngine.cache_stats()` re-exports them so benches, the
serve driver, and the auditor read one surface.

Deeper entries currently duplicate the KV rows of their shallower
ancestors (each snapshot is self-contained); block-sharing those rows
and host-memory offload are the disaggregated-serving follow-on
(ROADMAP item 3).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Iterable, Optional, Tuple

import jax
import numpy as np

__all__ = ["PrefixCache", "snapshot_bytes"]

#: host bookkeeping charged per cached token (trie edges + key tuples)
_TOKEN_OVERHEAD_BYTES = 8


def _as_key(tokens: Iterable) -> tuple:
  """Normalize a prompt (list / tuple / np array) to a hashable key."""
  arr = np.asarray(tokens)
  if arr.ndim != 1:
    raise ValueError(f"token key must be 1-D, got shape {arr.shape}")
  return tuple(int(t) for t in arr)


def snapshot_bytes(payload: Any) -> int:
  """Accounted size of a snapshot payload: array bytes over all leaves."""
  total = 0
  for leaf in jax.tree.leaves(payload):
    size = getattr(leaf, "size", None)
    itemsize = getattr(getattr(leaf, "dtype", None), "itemsize", None)
    if size is not None and itemsize is not None:
      total += int(size) * int(itemsize)
  return total


class _Node:
  """One radix-trie node: a compressed edge from its parent, children
  keyed by their edge's first token, and (optionally) the key of the
  entry that terminates exactly here."""
  __slots__ = ("edge", "children", "key", "parent")

  def __init__(self, edge: tuple = (), parent: Optional["_Node"] = None):
    self.edge = edge
    self.children: dict = {}
    self.key: Optional[tuple] = None
    self.parent = parent


class _Entry:
  __slots__ = ("payload", "nbytes", "node")

  def __init__(self, payload: Any, nbytes: int, node: _Node):
    self.payload = payload
    self.nbytes = nbytes
    self.node = node


class PrefixCache:
  """Byte-accounted LRU cache of decode-state snapshots keyed by token
  prefixes, with radix-trie longest-prefix matching.

  The payload is opaque to the cache (the engine stores a
  `(target_snapshot, draft_snapshot_or_None)` pair); only its array
  leaves are byte-accounted. `match_longest_prefix` is pure (no counter
  or recency mutation) — `lookup` is the serving entry point that also
  counts hits/misses and refreshes LRU recency.
  """

  def __init__(self, capacity_mb: float = 256.0, *,
               fork_min_tokens: int = 2):
    if capacity_mb <= 0:
      raise ValueError(f"capacity_mb must be > 0, got {capacity_mb}")
    if fork_min_tokens < 1:
      raise ValueError(
          f"fork_min_tokens must be >= 1, got {fork_min_tokens}")
    self.capacity_bytes = int(capacity_mb * (1 << 20))
    #: minimum uncovered shared-prefix depth worth materializing a fork
    #: snapshot for (guards against chance 1-token prompt collisions)
    self.fork_min_tokens = fork_min_tokens
    self._root = _Node()
    #: key -> _Entry, ordered oldest-recency first (LRU eviction order)
    self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
    self.bytes = 0
    self.hits = 0
    self.misses = 0
    self.evictions = 0
    self.inserts = 0
    self.rejected_oversize = 0

  def __len__(self) -> int:
    return len(self._entries)

  def __contains__(self, tokens) -> bool:
    return _as_key(tokens) in self._entries

  # -- lookup ---------------------------------------------------------------

  def match_longest_prefix(self, tokens) -> Tuple[int, Any]:
    """Longest inserted entry that is a prefix of `tokens`.

    Returns `(m, payload)` with `m` the entry's length (0 and None when
    nothing matches). Maximality: no inserted entry longer than `m`
    prefixes `tokens`. Pure — counters and recency are untouched (that
    is `lookup`'s job), so property tests can drive it as a function.
    """
    key = _as_key(tokens)
    node, depth = self._root, 0
    best_key: Optional[tuple] = None
    while True:
      if node.key is not None:
        best_key = node.key
      if depth >= len(key):
        break
      child = node.children.get(key[depth])
      if child is None:
        break
      edge = child.edge
      if (len(key) - depth < len(edge)
          or key[depth:depth + len(edge)] != edge):
        # entries live only at node boundaries; a partial edge match
        # cannot host one
        break
      depth += len(edge)
      node = child
    if best_key is None:
      return 0, None
    return len(best_key), self._entries[best_key].payload

  def common_prefix_len(self, tokens) -> int:
    """Longest common prefix between `tokens` and ANY inserted key —
    the trie walk depth, partial edge matches included.

    Always >= the `match_longest_prefix` length; the gap between the
    two is an *observed fork*: two prompts provably share that prefix
    but no snapshot exists at it (entries sit at full inserted keys).
    `LMEngine._admit` closes the gap by splitting its prefill at the
    fork and publishing the intermediate state — carries are only valid
    at exact lengths, so the fork snapshot must be materialized by a
    prefill that actually stops there, never sliced after the fact.
    Pure, like `match_longest_prefix`.
    """
    key = _as_key(tokens)
    node, depth = self._root, 0
    while depth < len(key):
      child = node.children.get(key[depth])
      if child is None:
        return depth
      edge = child.edge
      limit = min(len(edge), len(key) - depth)
      i = 0
      while i < limit and edge[i] == key[depth + i]:
        i += 1
      depth += i
      if i < len(edge):
        return depth
      node = child
    return depth

  def lookup(self, tokens) -> Tuple[int, Any]:
    """`match_longest_prefix` + hit/miss accounting + LRU touch."""
    m, payload = self.match_longest_prefix(tokens)
    if m:
      self.hits += 1
      self._entries.move_to_end(_as_key(tokens)[:m])
    else:
      self.misses += 1
    return m, payload

  # -- insert / evict -------------------------------------------------------

  def insert(self, tokens, payload: Any) -> bool:
    """Admit `(tokens -> payload)`; returns False when rejected.

    Re-inserting an existing key replaces its payload (and refreshes
    recency). Admission evicts LRU entries until the new entry fits; a
    payload larger than the whole capacity is rejected outright.
    """
    key = _as_key(tokens)
    if not key:
      raise ValueError("cannot cache an empty prefix")
    nbytes = snapshot_bytes(payload) + _TOKEN_OVERHEAD_BYTES * len(key)
    if nbytes > self.capacity_bytes:
      self.rejected_oversize += 1
      return False
    old = self._entries.get(key)
    if old is not None:
      self.bytes -= old.nbytes
      old.payload, old.nbytes = payload, nbytes
      self.bytes += nbytes
      self._entries.move_to_end(key)
      self._evict_to_fit()
      return True
    while self.bytes + nbytes > self.capacity_bytes:
      self._evict_one()
    node = self._splice_node(key)
    node.key = key
    self._entries[key] = _Entry(payload, nbytes, node)
    self.bytes += nbytes
    self.inserts += 1
    return True

  def _splice_node(self, key: tuple) -> _Node:
    """Walk/extend the trie to the node at exactly `key`, splitting
    partially matched edges on the way."""
    node, depth = self._root, 0
    while depth < len(key):
      child = node.children.get(key[depth])
      if child is None:
        new = _Node(key[depth:], parent=node)
        node.children[key[depth]] = new
        return new
      edge = child.edge
      common = 0
      limit = min(len(edge), len(key) - depth)
      while common < limit and edge[common] == key[depth + common]:
        common += 1
      if common < len(edge):
        # split: parent -> mid(edge[:common]) -> child(edge[common:])
        mid = _Node(edge[:common], parent=node)
        node.children[key[depth]] = mid
        child.edge = edge[common:]
        child.parent = mid
        mid.children[child.edge[0]] = child
        child = mid
      depth += common
      node = child
    return node

  def _evict_to_fit(self) -> None:
    while self.bytes > self.capacity_bytes:
      self._evict_one()

  def _evict_one(self) -> None:
    key, entry = self._entries.popitem(last=False)
    self.bytes -= entry.nbytes
    self.evictions += 1
    node = entry.node
    node.key = None
    # prune now-useless structure: drop childless entry-less tails, then
    # merge single-child entry-less pass-through nodes back into one edge
    while (node.parent is not None and node.key is None
           and not node.children):
      parent = node.parent
      del parent.children[node.edge[0]]
      node = parent
    if (node.parent is not None and node.key is None
        and len(node.children) == 1):
      (only,) = node.children.values()
      only.edge = node.edge + only.edge
      only.parent = node.parent
      node.parent.children[node.edge[0]] = only

  def clear(self) -> None:
    self._root = _Node()
    self._entries.clear()
    self.bytes = 0

  # -- introspection --------------------------------------------------------

  def stats(self) -> dict:
    """One stats surface for benches / serve driver / auditor."""
    lookups = self.hits + self.misses
    return {
        "hits": self.hits,
        "misses": self.misses,
        "evictions": self.evictions,
        "inserts": self.inserts,
        "rejected_oversize": self.rejected_oversize,
        "entries": len(self._entries),
        "bytes": self.bytes,
        "capacity_bytes": self.capacity_bytes,
        "hit_rate": self.hits / lookups if lookups else 0.0,
    }
