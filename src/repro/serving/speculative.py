"""Self-speculative decoding: the paper's low-rank model as a free draft.

The paper trains truncated-SVD low-rank versions of every large GEMM
because they are cheap to evaluate at small batch (§3.2, §4). That same
compressed model can accelerate the *full* model with zero quality loss:
a draft built by `make_draft_params` — the stage-2 truncated-SVD
factorization of the very params being served, no extra training —
proposes `k` tokens autoregressively; the target verifies all of them in
one fused `ModelApi.decode_window`; and because greedy verification
accepts exactly the tokens vanilla greedy would have produced,
speculative greedy decode is token-for-token identical to vanilla greedy
(the parity tests pin this bit-for-bit).

This module holds the pure, engine-independent pieces:

  make_draft_params      — params -> low-rank draft params (same tree,
                           matching GEMM leaves factored at the draft
                           rank; everything else shared by reference)
  accept_longest_prefix  — the acceptance rule: longest agreeing draft
                           prefix + exactly one bonus token per slot
  merge_rewind           — KV leaves from the post-window state, carry
                           leaves from the pre-draft snapshot (the
                           per-family rewind split, see
                           ModelApi.decode_state_carry)

The engine-side loop (draft steps, verify window, masked replay of the
accepted prefix) lives in `serving.engine.LMEngine`.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.core.compress import FactorizationPlan, to_stage2
from repro.core.factored import iter_factored_leaves
from repro.core.svd import TruncationSpec

__all__ = ["accept_longest_prefix", "make_draft_params", "merge_rewind"]


def make_draft_params(params: Any, *, rank: Optional[int] = None,
                      variance: Optional[float] = None,
                      plan: Optional[FactorizationPlan] = None) -> Any:
  """Build the self-speculative draft: a stage-2 truncated-SVD copy.

  `rank` pins every matching GEMM to one rank (the `--draft-rank` knob);
  otherwise `variance` (default 0.9) picks each rank by explained
  variance, the paper's truncation rule. A custom `plan` overrides both.
  Leaves the plan does not match — embeddings, tiny GEMMs, non-GEMM
  arrays — are shared with the target by reference, so the draft costs
  only the factored copies. Raises if nothing matched: a "draft" that is
  the target itself would silently claim a perfect accept rate.
  """
  if plan is None:
    spec = TruncationSpec(
        fixed_rank=rank,
        variance_threshold=0.9 if variance is None else variance)
    plan = FactorizationPlan(truncation=spec)
  draft = to_stage2(params, plan)
  before = {id(l) for l in iter_factored_leaves(params)}
  if all(id(l) in before for l in iter_factored_leaves(draft)):
    raise ValueError(
        "draft plan matched no GEMM leaf — the draft would be the target "
        "itself (params may be quantized, or min_dim too high; pass an "
        "explicit plan or build the draft from the float params)")
  return draft


def accept_longest_prefix(draft_toks, target_argmax
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
  """Greedy speculative acceptance: longest agreeing prefix + one bonus.

  draft_toks (b, k): the draft's proposals d_1..d_k.
  target_argmax (b, k+1): the target's greedy choices g_1..g_{k+1} from
    the verify window over [t_0, d_1..d_k].

  Returns (accept_len (b,), tokens (b, k+1), out_len (b,)):
    accept_len[i] in [0, k] — longest prefix with d_j == g_j;
    tokens[i, :out_len[i]] — the accepted drafts followed by exactly one
      bonus token g_{accept+1} (the target's own next choice), so
      out_len = accept_len + 1 in [1, k+1]. Entries past out_len are 0.

  Pure numpy, no engine state: every emitted token is, by construction,
  exactly what vanilla greedy decode would have emitted — acceptance
  can change only *how many* tokens a step yields, never their values.
  """
  draft = np.asarray(draft_toks)
  tgt = np.asarray(target_argmax)
  if draft.ndim != 2 or tgt.shape != (draft.shape[0], draft.shape[1] + 1):
    raise ValueError(
        f"draft (b, k) and target (b, k+1) required, got {draft.shape} "
        f"and {tgt.shape}")
  b, k = draft.shape
  rows = np.arange(b)
  if k:
    match = draft == tgt[:, :k]
    # np.argmin finds the first False; all-True rows accept everything
    accept = np.where(match.all(axis=1), k, np.argmin(match, axis=1))
  else:
    accept = np.zeros((b,), np.int64)
  out = np.zeros((b, k + 1), tgt.dtype)
  if k:
    keep = np.arange(k)[None, :] < accept[:, None]
    out[:, :k] = np.where(keep, draft, 0)
  out[rows, accept] = tgt[rows, accept]
  return accept.astype(np.int64), out, (accept + 1).astype(np.int64)


def merge_rewind(window_state: Any, snapshot: Any, carry: Any) -> Any:
  """Per-leaf rewind split: carry leaves (`carry` True) restore from the
  pre-draft `snapshot`; KV / step-invariant leaves keep the post-window
  value (their rewind is the position counter alone). The result is the
  state a masked replay of the accepted prefix starts from."""
  return jax.tree.map(lambda w, s, c: s if c else w,
                      window_state, snapshot, carry)
