"""Self-speculative decoding: the paper's low-rank model as a free draft.

The paper trains truncated-SVD low-rank versions of every large GEMM
because they are cheap to evaluate at small batch (§3.2, §4). That same
compressed model can accelerate the *full* model with zero quality loss:
a draft built by `make_draft_params` — the stage-2 truncated-SVD
factorization of the very params being served, no extra training —
proposes `k` tokens autoregressively; the target verifies all of them in
one fused `ModelApi.decode_window`; and because greedy verification
accepts exactly the tokens vanilla greedy would have produced,
speculative greedy decode is token-for-token identical to vanilla greedy
(the parity tests pin this bit-for-bit).

This module holds the pure, engine-independent pieces:

  make_draft_params      — params -> low-rank draft params (same tree,
                           matching GEMM leaves factored at the draft
                           rank; everything else shared by reference)
  accept_longest_prefix  — the greedy acceptance rule: longest agreeing
                           draft prefix + exactly one bonus token per slot
  accept_sampled         — the temperature > 0 acceptance rule: standard
                           speculative rejection sampling (accept d_j
                           with prob min(1, p/q), residual resample on
                           reject) — the emitted tokens are distributed
                           exactly as vanilla sampling from the target
  RankController         — online draft-rank walk against a target
                           accept-rate band (the engine rebuilds the
                           draft via make_draft_params on a change)
  merge_rewind           — KV leaves from the post-window state, carry
                           leaves from the pre-draft snapshot (the
                           per-family rewind split, see
                           ModelApi.decode_state_carry)

The engine-side loop (draft steps, verify window, masked replay of the
accepted prefix) lives in `serving.engine.LMEngine`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.core.compress import FactorizationPlan, to_stage2
from repro.core.factored import iter_factored_leaves
from repro.core.svd import TruncationSpec

__all__ = ["RankController", "accept_longest_prefix", "accept_sampled",
           "make_draft_params", "merge_rewind"]


def make_draft_params(params: Any, *, rank: Optional[int] = None,
                      variance: Optional[float] = None,
                      plan: Optional[FactorizationPlan] = None) -> Any:
  """Build the self-speculative draft: a stage-2 truncated-SVD copy.

  `rank` pins every matching GEMM to one rank (the `--draft-rank` knob);
  otherwise `variance` (default 0.9) picks each rank by explained
  variance, the paper's truncation rule. A custom `plan` overrides both.
  Leaves the plan does not match — embeddings, tiny GEMMs, non-GEMM
  arrays — are shared with the target by reference, so the draft costs
  only the factored copies. Raises if nothing matched: a "draft" that is
  the target itself would silently claim a perfect accept rate.
  """
  if plan is None:
    spec = TruncationSpec(
        fixed_rank=rank,
        variance_threshold=0.9 if variance is None else variance)
    plan = FactorizationPlan(truncation=spec)
  draft = to_stage2(params, plan)
  before = {id(l) for l in iter_factored_leaves(params)}
  if all(id(l) in before for l in iter_factored_leaves(draft)):
    raise ValueError(
        "draft plan matched no GEMM leaf — the draft would be the target "
        "itself (params may be quantized, or min_dim too high; pass an "
        "explicit plan or build the draft from the float params)")
  return draft


def accept_longest_prefix(draft_toks, target_argmax
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
  """Greedy speculative acceptance: longest agreeing prefix + one bonus.

  draft_toks (b, k): the draft's proposals d_1..d_k.
  target_argmax (b, k+1): the target's greedy choices g_1..g_{k+1} from
    the verify window over [t_0, d_1..d_k].

  Returns (accept_len (b,), tokens (b, k+1), out_len (b,)):
    accept_len[i] in [0, k] — longest prefix with d_j == g_j;
    tokens[i, :out_len[i]] — the accepted drafts followed by exactly one
      bonus token g_{accept+1} (the target's own next choice), so
      out_len = accept_len + 1 in [1, k+1]. Entries past out_len are 0.

  Pure numpy, no engine state: every emitted token is, by construction,
  exactly what vanilla greedy decode would have emitted — acceptance
  can change only *how many* tokens a step yields, never their values.
  """
  draft = np.asarray(draft_toks)
  tgt = np.asarray(target_argmax)
  if draft.ndim != 2 or tgt.shape != (draft.shape[0], draft.shape[1] + 1):
    raise ValueError(
        f"draft (b, k) and target (b, k+1) required, got {draft.shape} "
        f"and {tgt.shape}")
  b, k = draft.shape
  rows = np.arange(b)
  if k:
    match = draft == tgt[:, :k]
    # np.argmin finds the first False; all-True rows accept everything
    accept = np.where(match.all(axis=1), k, np.argmin(match, axis=1))
  else:
    accept = np.zeros((b,), np.int64)
  out = np.zeros((b, k + 1), tgt.dtype)
  if k:
    keep = np.arange(k)[None, :] < accept[:, None]
    out[:, :k] = np.where(keep, draft, 0)
  out[rows, accept] = tgt[rows, accept]
  return accept.astype(np.int64), out, (accept + 1).astype(np.int64)


def accept_sampled(draft_toks, draft_probs, target_probs,
                   rng: np.random.Generator
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
  """Speculative rejection sampling (Leviathan et al. 2022; Chen et al.
  2023) — the temperature > 0 counterpart of `accept_longest_prefix`.

  draft_toks (b, k): draft proposals d_1..d_k, each sampled from q_j.
  draft_probs (b, k, v): q_j — the draft distribution each d_j was drawn
    from (softmax of the draft logits at the serving temperature).
  target_probs (b, k+1, v): p_j — the target distribution at every
    window position (position k+1 is the bonus distribution).

  Per slot, walking j = 1..k: accept d_j with probability
  min(1, p_j(d_j) / q_j(d_j)); on the first rejection draw the
  replacement from the residual max(0, p_j - q_j) (renormalized) and
  stop. If every draft survives, draw one bonus token from p_{k+1}.

  Returns (accept_len (b,), tokens (b, k+1), out_len (b,)) — the exact
  contract of `accept_longest_prefix`: accept_len in [0, k] counts
  surviving drafts, tokens[i, :out_len[i]] is the accepted prefix plus
  exactly one sampled token (residual or bonus), out_len = accept_len+1.

  The marginal distribution of every emitted token is exactly p_j —
  vanilla sampling from the target — for ANY draft q (the classic
  rejection-sampling identity q(d)·min(1, p/q) + P(reject)·residual = p),
  so speculation at temperature > 0 changes throughput only, never the
  sampled distribution. Pure numpy + host RNG; the caller owns seeding.
  """
  draft = np.asarray(draft_toks)
  q = np.asarray(draft_probs, np.float64)
  p = np.asarray(target_probs, np.float64)
  if draft.ndim != 2:
    raise ValueError(f"draft (b, k) required, got {draft.shape}")
  b, k = draft.shape
  if q.shape[:2] != (b, k) or p.shape[:2] != (b, k + 1):
    raise ValueError(
        f"draft_probs (b, k, v) and target_probs (b, k+1, v) required, "
        f"got {q.shape} and {p.shape}")
  v = p.shape[-1]
  accept = np.zeros((b,), np.int64)
  out = np.zeros((b, k + 1), np.int32)
  for i in range(b):
    a = k
    extra = None
    for j in range(k):
      d = int(draft[i, j])
      # u*q < p <=> u < p/q without the 0/0; p >= q always accepts
      if rng.uniform() * q[i, j, d] < p[i, j, d]:
        out[i, j] = d
        continue
      res = np.maximum(p[i, j] - q[i, j], 0.0)
      z = res.sum()
      # z == 0 means p <= q everywhere, so p == q (both sum to 1) and
      # the rejection had probability 0 — numerically, fall back to p
      pr = res / z if z > 0.0 else p[i, j] / p[i, j].sum()
      a, extra = j, int(rng.choice(v, p=pr))
      break
    if extra is None:                       # full accept: bonus from p_{k+1}
      extra = int(rng.choice(v, p=p[i, k] / p[i, k].sum()))
    accept[i] = a
    out[i, a] = extra
    out[i, a + 1:] = 0
  return accept, out, (accept + 1).astype(np.int64)


@dataclasses.dataclass
class RankController:
  """Online draft-rank controller: walk the draft's truncated-SVD rank so
  the measured accept rate sits inside a target band.

  The trade it balances: a higher rank makes the draft agree with the
  target more often (higher accept rate, more tokens per verify window)
  but costs more per draft step; a lower rank drafts cheaper but gets
  rejected more. The controller watches the accept rate over windows of
  `interval` engine iterations and nudges the rank by `step`:

    rate < band[0]  ->  rank + step   (draft too weak — buy agreement)
    rate > band[1]  ->  rank - step   (draft too strong — shed FLOPs)

  clamped to [min_rank, max_rank]. The engine applies a change by
  rebuilding the draft through `make_draft_params(params, rank=...)` —
  draft-SIDE programs retrace for the new factor shapes, but the target's
  verify window is untouched (same params, same program, no re-jit), and
  the draft's decode state carries over unchanged (factoring weights
  never changes state shapes), so a swap costs accept rate transiently
  and correctness nothing. Pure decision logic; the engine owns both the
  measurement and the rebuild.
  """
  band: tuple = (0.5, 0.85)
  step: int = 16
  min_rank: int = 8
  max_rank: Optional[int] = None
  interval: int = 8       # engine iterations per measurement window

  def __post_init__(self):
    lo, hi = self.band
    if not (0.0 <= lo < hi <= 1.0):
      raise ValueError(f"band must satisfy 0 <= lo < hi <= 1, got "
                       f"{self.band}")
    if self.step < 1 or self.min_rank < 1 or self.interval < 1:
      raise ValueError("step, min_rank and interval must be >= 1")
    if self.max_rank is not None and self.max_rank < self.min_rank:
      raise ValueError(f"max_rank {self.max_rank} < min_rank "
                       f"{self.min_rank}")

  def propose(self, rank: int, accept_rate: Optional[float]) -> int:
    """Next draft rank given the current rank and the accept rate
    measured over the last window (None = nothing drafted: hold)."""
    if accept_rate is None:
      return rank
    lo, hi = self.band
    if accept_rate < lo:
      rank = rank + self.step
    elif accept_rate > hi:
      rank = rank - self.step
    rank = max(self.min_rank, rank)
    if self.max_rank is not None:
      rank = min(self.max_rank, rank)
    return rank


def merge_rewind(window_state: Any, snapshot: Any, carry: Any) -> Any:
  """Per-leaf rewind split: carry leaves (`carry` True) restore from the
  pre-draft `snapshot`; KV / step-invariant leaves keep the post-window
  value (their rewind is the position counter alone). The result is the
  state a masked replay of the accepted prefix starts from."""
  return jax.tree.map(lambda w, s, c: s if c else w,
                      window_state, snapshot, carry)
