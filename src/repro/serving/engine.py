"""Serving: batched LM decode engine + the paper's streaming speech path.

LMEngine — request-batched autoregressive decoding over a persistent KV /
SSM state. `decode_step` is one jitted program (the exact program the
decode_32k / long_500k dry-run cells lower). Prefill here replays the
prompt through the decode step (sequential prefill): correct for every
family incl. SSM hybrids, and fine at demo scale — production prefill is
the separate `prefill_32k` lowering, which computes the full-sequence
forward.

StreamingSpeechServer — the paper's embedded deployment mode: frame-
synchronous DS2 inference. The conv frontend runs on small feature chunks;
each GRU step is the low-batch recurrent GEMM that kernels/decode_matvec
and kernels/gru_cell target; CTC greedy labels stream out per frame.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import make_constraint
from repro.kernels.dispatch import resolve_policy
from repro.layers.common import ModelConfig
from repro.models import deepspeech
from repro.models.api import get_model


@dataclasses.dataclass
class GenerationResult:
  tokens: np.ndarray            # (b, steps)
  steps: int


class LMEngine:

  def __init__(self, model_cfg: ModelConfig, params: Any, *,
               batch_size: int, max_len: int, mesh=None,
               cache_dtype=None, rng=None, kernel_policy=None):
    self.cfg = model_cfg
    self.params = params
    self.api = get_model(model_cfg)
    if not self.api.decodable:
      raise ValueError(f"{model_cfg.name} has no decode path")
    self.batch = batch_size
    self.max_len = max_len
    self.cache_dtype = cache_dtype
    cs = make_constraint(mesh, model_cfg, batch_size, decode=True)
    # the decode-regime KernelPolicy is built HERE, once, like cs: the
    # jitted step closes over it, so "pallas" lowers every eligible GEMM
    # through kernels.dispatch. None keeps the exact jnp program.
    policy = resolve_policy(kernel_policy, batch_size)
    self.kernel_policy = policy
    self.state = self._init_state()
    self.positions = jnp.zeros((batch_size,), jnp.int32)
    self.rng = jax.random.PRNGKey(0) if rng is None else rng

    def step(params, state, token, positions):
      return self.api.decode_step(params, state, token, positions,
                                  model_cfg, cs, policy)
    self._step = jax.jit(step, donate_argnums=(1,))

  def _init_state(self):
    state = self.api.init_decode_state(self.cfg, self.batch, self.max_len)
    if self.cache_dtype is not None:
      state = jax.tree.map(
          lambda x: x.astype(self.cache_dtype)
          if x.dtype in (jnp.float32, jnp.bfloat16) else x, state)
    return state

  def reset(self) -> None:
    self.state = self._init_state()
    self.positions = jnp.zeros((self.batch,), jnp.int32)

  def prefill(self, prompts: np.ndarray) -> jax.Array:
    """Feed prompts (b, p) through the decode step; returns last logits."""
    prompts = jnp.asarray(prompts, jnp.int32)
    logits = None
    for t in range(prompts.shape[1]):
      logits, self.state = self._step(self.params, self.state,
                                      prompts[:, t:t + 1], self.positions)
      self.positions = self.positions + 1
    return logits

  def generate(self, prompts: np.ndarray, *, steps: int,
               temperature: float = 0.0) -> GenerationResult:
    logits = self.prefill(prompts)
    out = []
    for _ in range(steps):
      tok = self._sample(logits, temperature)
      out.append(np.asarray(tok))
      logits, self.state = self._step(self.params, self.state, tok,
                                      self.positions)
      self.positions = self.positions + 1
    return GenerationResult(tokens=np.concatenate(out, axis=1),
                            steps=steps)

  def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
    lg = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0:
      return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    self.rng, k = jax.random.split(self.rng)
    return jax.random.categorical(
        k, lg / temperature, axis=-1)[:, None].astype(jnp.int32)


class StreamingSpeechServer:
  """Frame-synchronous DS2 serving (paper §4's embedded regime)."""

  def __init__(self, model_cfg: ModelConfig, params: Any, *,
               batch_size: int = 1, kernel_policy=None):
    self.cfg = model_cfg
    self.params = params
    self.batch = batch_size
    # frame-synchronous GRU steps are the paper's decode regime; a
    # "pallas" policy routes them through gru_cell / decode_matvec
    policy = resolve_policy(kernel_policy, batch_size)
    self.kernel_policy = policy
    self.state = deepspeech.init_decode_state(model_cfg, batch_size)
    self._prev = np.full((batch_size,), -1, np.int64)

    def frame_step(params, state, x_t):
      return deepspeech.decode_step(params, state, x_t, model_cfg,
                                    policy=policy)
    self._frame_step = jax.jit(frame_step, donate_argnums=(1,))
    self._frontend = jax.jit(functools.partial(
        deepspeech._frontend, cfg=model_cfg))

  def reset(self) -> None:
    self.state = deepspeech.init_decode_state(self.cfg, self.batch)
    self._prev = np.full((self.batch,), -1, np.int64)

  def process_chunk(self, feats: np.ndarray) -> list[list[int]]:
    """feats (b, t, feat_dim) raw mel chunk -> newly emitted labels."""
    x = self._frontend(self.params, jnp.asarray(feats))
    emitted: list[list[int]] = [[] for _ in range(self.batch)]
    for t in range(x.shape[1]):
      log_probs, self.state = self._frame_step(self.params, self.state,
                                               x[:, t])
      best = np.asarray(jnp.argmax(log_probs, axis=-1))
      for i in range(self.batch):
        if best[i] != 0 and best[i] != self._prev[i]:
          emitted[i].append(int(best[i]))
        self._prev[i] = best[i]
    return emitted
