"""Serving: continuous-batching LM decode engine + the paper's streaming
speech path.

LMEngine — continuous batching over a persistent KV / SSM decode state.
The engine owns `batch_size` *slots*, each with its own request lifecycle

    admit -> prefill -> decode -> retire (EOS / token budget / max_len)

and a host-side request queue. Prefill is one jitted `jax.lax.scan` over
prompt positions (bucketed by padded prompt length, so a handful of
programs serve every prompt). Decoding is one masked jitted step for the
whole batch: retired slots keep stepping with clamped positions (their
garbage is overwritten at the next admit), so refilling a slot from the
queue never re-traces. Slot admission uses the ModelApi slot-surgery
helpers (`insert_slot` / `extract_slot` / `reset_slot`): a request is
prefilled into a fresh batch-1 state and spliced into its slot. This is
the paper's §4 regime — batch 1-4 streams amortizing each weight load —
with no slot burning idle once its request finishes.

`max_len` is a hard boundary: prefill rejects prompts that don't fit and
a slot whose cache is full retires with reason "max_len" instead of
wrapping the scatter index and corrupting the cache.

Speculative decoding (`speculate=k`): the engine runs a second, low-rank
model — the stage-2 truncated-SVD factorization of the *same* params
(serving.speculative.make_draft_params, no extra training) — against its
own decode state. Each iteration the draft proposes k tokens
autoregressively and the target verifies all of them in one fused
`ModelApi.decode_window` — per family a true batched window forward (one
causal attention pass over the KV cache, or batched GEMMs with only the
O(1) recurrent carries scanning), so verification reads the weights once
for the whole window instead of k+1 times. At temperature 0,
`accept_longest_prefix` commits the longest agreeing prefix plus one
bonus token (1..k+1 tokens per iteration instead of exactly 1) and
greedy acceptance makes this LOSSLESS: speculative greedy is
token-for-token vanilla greedy. At temperature > 0, `accept_sampled`
runs standard speculative rejection sampling (accept each draft with
prob min(1, p/q), resample the first rejection from the residual), which
keeps every emitted token distributed exactly as vanilla sampling from
the target. Rejected suffixes rewind both models' states with per-family
semantics (ModelApi.decode_state_carry): attention KV rows rewind by
moving the position counter (rows past it are dead until overwritten);
SSM / recurrent carries restore the pre-draft snapshot and replay the
accepted prefix through the masked window program prefill already uses.
An optional `rank_controller` (serving.speculative.RankController) walks
the draft rank online against a target accept-rate band, rebuilding the
draft params in place — the target's verify program never re-jits.

Prefix caching (`prefix_cache=PrefixCache(...)`): admission consults a
radix-trie cache of decode-state snapshots (serving.prefix_cache) keyed
by token prefixes. On a hit the cached snapshot is spliced into a fresh
batch-1 state (`ModelApi.splice_prefix` — eager slot surgery, no new jit
program) and the SAME bucketed fused prefill runs over only the uncached
suffix starting at the cached position; admission then publishes the
full prompt's snapshot back (`publish_on_retire=True` additionally
publishes prompt+generated prefixes at retirement, the multi-turn win).
The spliced state is bit-identical to the cold prefill's state at that
position, so cached-splice greedy serving is token-for-token cold
serving — pinned by tests and the `prefix_splice_stability` audit check.

`cache_dtype` downcasts only the attention KV-cache leaves (see
`models.api.cast_kv_cache`); SSM / recurrent carries stay full precision.

Both engines accept PTQ'd params (repro.quant's QuantizedLinear leaves)
unchanged: under the pallas policy the dispatcher routes those GEMMs to
the int8_gemm kernel consuming the stored scales directly, and under
jnp/no policy the leaf's own w8a8 oracle runs — the same arithmetic, so
quantized serving is policy-invariant token-for-token.

StreamingSpeechServer — the paper's embedded deployment mode: frame-
synchronous DS2 inference. The conv frontend streams over mel chunks
*with receptive-field context carried across chunk boundaries*, so the
streamed CTC labels match the full-utterance forward exactly; each GRU
step is the low-batch recurrent GEMM that kernels/decode_matvec and
kernels/gru_cell target.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import make_constraint
from repro.kernels.dispatch import resolve_policy
from repro.layers.common import ModelConfig
from repro.models import deepspeech
from repro.models.api import cast_kv_cache, get_model
from repro.serving.prefix_cache import PrefixCache
from repro.serving.speculative import (RankController,
                                       accept_longest_prefix,
                                       accept_sampled, make_draft_params,
                                       merge_rewind)

_INHERIT = object()   # submit(eos_id=...) sentinel: use the engine's eos_id


@dataclasses.dataclass
class GenerationResult:
  tokens: np.ndarray            # (b, steps); rows past their length are 0
  steps: int
  lengths: Optional[np.ndarray] = None   # (b,) generated tokens per row
  # speculative decoding only: accepted draft tokens / drafted tokens
  # over this call (None when the engine decodes vanilla)
  accept_rate: Optional[float] = None


@dataclasses.dataclass
class Request:
  uid: int
  prompt: np.ndarray            # (p,) int32
  max_new_tokens: Optional[int]  # None = until EOS or max_len
  eos_id: Optional[int]


@dataclasses.dataclass
class FinishedRequest:
  uid: int
  prompt: np.ndarray
  tokens: np.ndarray            # generated tokens, prompt excluded
  finish_reason: str            # "eos" | "length" | "max_len"
  # admission-to-first-token wall seconds (prefill latency; queue wait
  # excluded) — the number the prefix cache exists to shrink
  ttft_s: Optional[float] = None


@dataclasses.dataclass
class _SlotState:
  """Host-side ownership record for one decode slot: request lifecycle,
  emitted tokens, and the next token to feed. One object per slot
  (inactive slots hold a blank record) — the single place per-slot state
  hangs off now that features run several models against one decode
  state (the speculative draft here; prefix caches later). Replaces the
  former parallel lists (`_slots` / `_active` / `_next_tok`)."""
  req: Optional[Request] = None
  tokens: list = dataclasses.field(default_factory=list)
  remaining: Optional[int] = None
  active: bool = False
  next_tok: int = 0
  ttft_s: Optional[float] = None


def _next_pow2(n: int) -> int:
  return 1 << max(0, int(n - 1).bit_length())


def _jit_cache_size(fn) -> int:
  """Compiled-signature count of one jit wrapper (-1 if the runtime does
  not expose it). Each entry is one traced+compiled input signature, so
  a shape-stable serving loop holds this at 1 per program."""
  try:
    return int(fn._cache_size())
  except AttributeError:
    return -1


def make_prefill_program(api, cfg: ModelConfig, cs, policy, axes):
  """Build the fused masked-prefill program (un-jitted).

  Module-level (rather than a closure inside LMEngine.__init__) so the
  engine's two jit variants (`_prefill`, donating `_replay`) and the
  repro.analysis trace harness all audit the SAME program the engine
  serves with, not a lookalike.

  `axes` is `api.decode_state_batch_axes(cfg)` — the per-leaf batch axis
  tree the masked state-select broadcasts over.
  """

  def prefill_prog(params, state, prompts, plens, pos0):
    """Fused prefill: scan over prompt positions inside one program.

    prompts (b, P) padded to the bucket length; plens (b,) true lengths
    (>= 1); pos0 (b,) starting positions. Rows keep stepping past their
    own length with the state select masked back, so one program serves
    every mix of prompt lengths at a bucket size. Returns (last live
    logits per row (b, 1, v) float32, state after plens tokens)."""
    b, P = prompts.shape
    def masked(live, new, old):
      return jax.tree.map(
          lambda n, o, ax: jnp.where(_bcast_mask(live, n.ndim, ax), n, o),
          new, old, axes)
    logits0, state1 = api.decode_step(params, state, prompts[:, 0:1],
                                      pos0, cfg, cs, policy)
    last0 = logits0.astype(jnp.float32)
    def body(carry, t):
      st, last = carry
      tok = jax.lax.dynamic_slice_in_dim(prompts, t, 1, axis=1)
      logits, new_st = api.decode_step(params, st, tok, pos0 + t, cfg,
                                       cs, policy)
      live = t < plens
      st = masked(live, new_st, st)
      last = jnp.where(live[:, None, None], logits.astype(jnp.float32),
                       last)
      return (st, last), None
    (state2, last), _ = jax.lax.scan(body, (state1, last0),
                                     jnp.arange(1, P))
    return last, state2

  return prefill_prog


def _bcast_mask(mask: jax.Array, ndim: int, axis: int) -> jax.Array:
  shape = [1] * ndim
  shape[axis] = mask.shape[0]
  return mask.reshape(shape)


def _host_probs(logits, temperature: float) -> np.ndarray:
  """softmax(logits / temperature) on the host in float64 — the
  acceptance-side view of the distribution `_sample`'s categorical draws
  from (float32 logits over temperature)."""
  x = np.asarray(logits, np.float64) / temperature
  x -= x.max(axis=-1, keepdims=True)
  np.exp(x, out=x)
  x /= x.sum(axis=-1, keepdims=True)
  return x


class LMEngine:

  def __init__(self, model_cfg: ModelConfig, params: Any, *,
               batch_size: int, max_len: int, mesh=None,
               cache_dtype=None, rng=None, kernel_policy=None,
               eos_id: Optional[int] = None, speculate: int = 0,
               draft_params: Any = None, draft_rank: Optional[int] = None,
               rank_controller: Optional[RankController] = None,
               prefix_cache: Optional[PrefixCache] = None,
               publish_on_retire: bool = False):
    self.cfg = model_cfg
    self.params = params
    self.api = get_model(model_cfg)
    if not self.api.decodable:
      raise ValueError(f"{model_cfg.name} has no decode path")
    self.batch = batch_size
    self.max_len = max_len
    self.cache_dtype = cache_dtype
    self.eos_id = eos_id
    if speculate < 0:
      raise ValueError(f"speculate must be >= 0, got {speculate}")
    self.speculate = int(speculate)
    cs = make_constraint(mesh, model_cfg, batch_size, decode=True)
    # the decode-regime KernelPolicy is built HERE, once, like cs: the
    # jitted step closes over it, so "pallas" lowers every eligible GEMM
    # through kernels.dispatch. None keeps the exact jnp program. A
    # speculative engine widens the decode_matvec bound to cover a fused
    # (batch x window)-row verify step (never past the kernel contract).
    policy = resolve_policy(kernel_policy, batch_size,
                            window=self.speculate + 1)
    self.kernel_policy = policy
    self._axes = self.api.decode_state_batch_axes(model_cfg)
    # per-family rewind semantics: carry leaves snapshot/replay, the rest
    # (attention KV, step-invariant memory) rewind positionally for free
    self._carry = self.api.decode_state_carry(model_cfg)
    self._has_carry = any(jax.tree.leaves(self._carry))
    self.state = self._init_state(batch_size)
    self.positions = jnp.zeros((batch_size,), jnp.int32)
    self._rng0 = jax.random.PRNGKey(0) if rng is None else rng
    self.rng = self._rng0

    # the self-speculative draft: same params, matching GEMMs factored
    # at the draft rank, decoding against its own state
    if self.speculate:
      if draft_params is None:
        draft_params = make_draft_params(params, rank=draft_rank)
      self.draft_params = draft_params
      self.draft_state = self._init_state(batch_size)
    else:
      self.draft_params = None
      self.draft_state = None

    # the (optional) online rank controller: walks draft_rank against an
    # accept-rate band, rebuilding the draft in place. Only draft-side
    # programs retrace for the new factor shapes; the target's verify
    # window never re-jits (same params, same signature).
    if rank_controller is not None:
      if not self.speculate:
        raise ValueError("rank_controller requires speculate > 0")
      if draft_rank is None:
        raise ValueError(
            "rank_controller needs a starting draft_rank to walk from "
            "(the explained-variance draft has no single rank)")
    self.rank_controller = rank_controller
    self.draft_rank = draft_rank
    self.rank_history: list = []   # (decode_steps, old_rank, new_rank)
    self._ctrl_step0 = 0
    self._ctrl_drafted0 = 0
    self._ctrl_accepted0 = 0

    # the (optional, shareable) prefix cache: admission splices hits,
    # publishes full prompts, and — opted in — retired prefixes too
    self._cache = prefix_cache
    self.publish_on_retire = publish_on_retire
    self._pending_publish: list = []   # (slot, key tokens, fed length)

    # host-side per-slot lifecycle + the request queue
    self._queue: collections.deque = collections.deque()
    self._slots: list = [_SlotState() for _ in range(batch_size)]
    self._finished: dict = {}
    self._next_uid = 0
    # occupancy accounting for bench_serving: busy slot-steps / slot-steps
    self.decode_steps = 0
    self.busy_slot_steps = 0
    # speculative accounting: accept_rate = accepted / drafted
    self.drafted_tokens = 0
    self.accepted_tokens = 0

    api, cfg = self.api, model_cfg

    def step(params, state, token, positions):
      return api.decode_step(params, state, token, positions, cfg, cs,
                             policy)
    self._step = jax.jit(step, donate_argnums=(1,))
    # carry families snapshot the draft state before drafting; the FIRST
    # draft step reads that snapshot, so it must not donate its buffers
    # (later steps consume disposable intermediates and use _step)
    self._draft_step0 = jax.jit(step) if self._has_carry else self._step

    def window_step(params, state, tokens, positions):
      return api.decode_window(params, state, tokens, positions, cfg, cs,
                               policy)
    # same donation logic: the pre-window snapshot must survive the call
    self._window = jax.jit(
        window_step, donate_argnums=() if self._has_carry else (1,))

    prefill_prog = make_prefill_program(api, cfg, cs, policy, self._axes)
    # no donation: admission prefills from the cached fresh-slot template,
    # which must survive the call
    self._prefill = jax.jit(prefill_prog)
    # the same masked-window program re-advances carries after a
    # speculative rejection (replay of the accepted prefix); its inputs
    # are disposable (post-window KV + pre-draft snapshot), so donate
    self._replay = jax.jit(prefill_prog, donate_argnums=(1,))

    def insert(state, slot_state, slot):
      return api.insert_slot(cfg, state, slot_state, slot)
    self._insert = jax.jit(insert, donate_argnums=(0,))
    # one fresh single-slot decode state, reused as the admission template
    # (for the draft too: factoring weights never changes state shapes)
    self._fresh_slot = self._init_state(1)
    # every (batch, padded prompt length) bucket prefill has compiled
    # for (admission runs at batch 1, the static-batch surface at the
    # engine batch); the retrace-stability audit pins _prefill's cache
    # size to this count. _prefill_calls counts INVOCATIONS per bucket
    # (resets with the other counters) — the splice path shows up here
    # as calls landing in smaller suffix buckets, never as new ones.
    self._prefill_buckets: set = set()
    self._prefill_calls: dict = {}

  def _count_prefill(self, b: int, bucket: int) -> None:
    key = (int(b), int(bucket))
    self._prefill_buckets.add(key)
    self._prefill_calls[key] = self._prefill_calls.get(key, 0) + 1

  def compile_stats(self) -> dict:
    """Compiled-signature counts for every jitted program the engine owns,
    plus per-bucket prefill invocation counts.

    The engine's shape-stability contract — a fixed decode step, bucketed
    prefill — is observable here: after any admit/decode/retire/refill
    sequence (prefix-cache splices included), "step" must sit at exactly
    1, "prefill" at exactly len(prefill_buckets), and the auxiliary
    programs at <= 1 each. A higher count means a signature silently
    re-traced (and recompiled) mid-serve. `repro.analysis`'s
    retrace-stability and prefix-splice-stability checks assert this;
    values of -1 mean the runtime does not expose jit cache sizes.

    "prefill_calls" maps "BxL" bucket names to invocation counts since
    init/reset() — benches and the auditor read cache effectiveness
    (splices shift calls into smaller suffix buckets) from this one
    surface next to `cache_stats()`."""
    stats = {
        "step": _jit_cache_size(self._step),
        "prefill": _jit_cache_size(self._prefill),
        "replay": _jit_cache_size(self._replay),
        "window": _jit_cache_size(self._window),
        "insert": _jit_cache_size(self._insert),
        "prefill_buckets": sorted(self._prefill_buckets),
        "prefill_calls": {f"{b}x{p}": n for (b, p), n
                          in sorted(self._prefill_calls.items())},
    }
    # for carry families the draft's first step is a distinct (non-
    # donating) program; otherwise it IS _step and needs no extra key
    if self._draft_step0 is not self._step:
      stats["draft_step0"] = _jit_cache_size(self._draft_step0)
    return stats

  def cache_stats(self) -> dict:
    """Prefix-cache counters (hits / misses / evictions / inserts /
    bytes / hit_rate) — the `PrefixCache.stats()` surface re-exported so
    benches, the serve driver, and the auditor read one place. A
    cacheless engine returns the same shape, zeroed."""
    if self._cache is None:
      return {"hits": 0, "misses": 0, "evictions": 0, "inserts": 0,
              "rejected_oversize": 0, "entries": 0, "bytes": 0,
              "capacity_bytes": 0, "hit_rate": 0.0}
    return self._cache.stats()

  def _init_state(self, batch: int):
    state = self.api.init_decode_state(self.cfg, batch, self.max_len)
    # scope: KV-cache leaves only — SSM/recurrent carries are read-modify-
    # write every step and must keep their working precision
    return cast_kv_cache(state, self.cache_dtype)

  def reset(self) -> None:
    self.state = self._init_state(self.batch)
    if self.speculate:
      self.draft_state = self._init_state(self.batch)
    self.positions = jnp.zeros((self.batch,), jnp.int32)
    self.rng = self._rng0          # seeded sampling restarts with reset
    self._queue.clear()
    self._slots = [_SlotState() for _ in range(self.batch)]
    self._finished = {}
    self.decode_steps = 0
    self.busy_slot_steps = 0
    self.drafted_tokens = 0
    self.accepted_tokens = 0
    self._ctrl_step0 = 0
    self._ctrl_drafted0 = 0
    self._ctrl_accepted0 = 0
    self._prefill_calls = {}
    self._pending_publish = []
    # the prefix cache itself is NOT cleared: it may be shared across
    # engines, and its entries stay valid (snapshots are self-contained)

  # -- request lifecycle ----------------------------------------------------

  def _active_mask(self) -> np.ndarray:
    return np.array([s.active for s in self._slots], bool)

  def _next_tokens(self) -> np.ndarray:
    return np.array([[s.next_tok] for s in self._slots], np.int32)

  @property
  def num_active(self) -> int:
    return sum(s.active for s in self._slots)

  @property
  def accept_rate(self) -> Optional[float]:
    """Accepted draft tokens / drafted tokens since init or reset(), or
    None when nothing has been drafted yet — "no data" and "every draft
    rejected" are different answers, and callers (the serve driver, the
    rank controller, `GenerationResult.accept_rate`) all read None as
    the former. One semantics across every accept-rate surface."""
    return (self.accepted_tokens / self.drafted_tokens
            if self.drafted_tokens else None)

  @property
  def occupancy(self) -> float:
    """Mean fraction of slots doing useful work per engine iteration:
    busy_slot_steps / (decode_steps * batch_size) since init or reset().

    `decode_steps` counts engine ITERATIONS — one masked decode step in
    the vanilla path, one whole draft+verify+commit round in the
    speculative path (which may emit up to k+1 tokens) — and admission
    prefill work is excluded entirely, so this measures slot liveness,
    not tokens/step. 0.0 before any decoding has happened."""
    total = self.decode_steps * self.batch
    return self.busy_slot_steps / total if total else 0.0

  def submit(self, prompt, *, max_new_tokens: Optional[int] = None,
             eos_id=_INHERIT) -> int:
    """Queue one request; returns its uid. `eos_id=None` disables EOS
    retirement for this request (the engine default applies otherwise)."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if prompt.size == 0:
      raise ValueError("empty prompt")
    if prompt.size > self.max_len:
      raise ValueError(
          f"prompt length {prompt.size} exceeds max_len {self.max_len}")
    if max_new_tokens is not None and max_new_tokens < 1:
      raise ValueError("max_new_tokens must be >= 1")
    uid = self._next_uid
    self._next_uid += 1
    eos = self.eos_id if eos_id is _INHERIT else eos_id
    self._queue.append(Request(uid=uid, prompt=prompt,
                               max_new_tokens=max_new_tokens, eos_id=eos))
    return uid

  def _retire(self, slot: int, reason: str) -> None:
    s = self._slots[slot]
    self._finished[s.req.uid] = FinishedRequest(
        uid=s.req.uid, prompt=s.req.prompt,
        tokens=np.asarray(s.tokens, np.int32), finish_reason=reason,
        ttft_s=s.ttft_s)
    if self._cache is not None and self.publish_on_retire:
      # the retired conversation's fed prefix (prompt + every generated
      # token except the final, never-fed one) is a cacheable entry —
      # the multi-turn continuation hit. Deferred: the batch state may
      # still be mid-update here (speculative rewind pending), so the
      # snapshot is taken at the caller's flush point.
      fed = s.req.prompt.size + len(s.tokens) - 1
      if fed > 0:
        key = np.concatenate(
            [s.req.prompt, np.asarray(s.tokens[:-1], np.int32)])
        self._pending_publish.append((slot, key, fed))
    self._slots[slot] = _SlotState()
    # no state scrub here: the slot keeps stepping masked (positions
    # clamped to 0) and the next admit splices a fully fresh prefilled
    # state over every row of the slot

  def _flush_retire_publish(self, *, invalid_slots=()) -> None:
    """Publish the prefixes queued by `_retire`, dropping only the slots
    named in `invalid_slots`. Validity is PER SLOT: the speculative
    full-accept fast path skips the masked replay, which leaves a
    partially-accepted retired slot's carries at post-window values (not
    the committed prefix) — those publishes must drop — while a slot
    that retired having accepted its whole window holds carries that ARE
    the committed values (the window state at exactly `fed` tokens), so
    its publish is good. Vanilla decode and the replay path pass nothing
    and publish everything."""
    for slot, key, fed in self._pending_publish:
      if slot in invalid_slots:
        continue
      snap = self.api.slot_snapshot(self.cfg, self.state, slot, fed)
      # retire publishes target-only: the draft re-prefills on a hit
      self._cache.insert(key, (snap, None))
    self._pending_publish.clear()

  def _record_token(self, slot: int, tok: int, pos: int) -> bool:
    """Append a sampled token; retire the slot if the request is done.
    `pos` is the slot's cache write count. Returns True while the slot
    stays active."""
    s = self._slots[slot]
    s.tokens.append(tok)
    if s.remaining is not None:
      s.remaining -= 1
    if s.req.eos_id is not None and tok == s.req.eos_id:
      self._retire(slot, "eos")
      return False
    if s.remaining == 0:
      self._retire(slot, "length")
      return False
    if pos >= self.max_len:
      # cache full: one more step would scatter past max_len and corrupt
      # the KV cache — retire instead (the hard boundary)
      self._retire(slot, "max_len")
      return False
    return True

  def _pad_prefill(self, tokens: np.ndarray, start: int):
    """Bucket-pad a token run fed at positions [start, start+len) into
    the fused-prefill operand triple (toks, lens, pos0)."""
    n = tokens.size
    bucket = min(max(self.max_len, 1), _next_pow2(n))
    self._count_prefill(1, bucket)
    padded = np.zeros((1, bucket), np.int32)
    padded[0, :n] = tokens
    return (jnp.asarray(padded), jnp.asarray([n], jnp.int32),
            jnp.full((1,), start, jnp.int32))

  def _admit(self, req: Request, slot: int, temperature: float) -> None:
    """Prefill `req` into a fresh batch-1 state and splice it into `slot`.

    With a prefix cache, admission first looks up the longest cached
    prefix (capped at plen - 1: the suffix prefill must feed at least
    one token so there are fresh last-position logits to sample from),
    splices its snapshot into the fresh-slot template — eager slot
    surgery, bit-identical to the cold state at that position — and runs
    the SAME bucketed fused prefill over only the suffix, starting at
    the cached position. When the trie has observed a deeper shared
    prefix than any entry covers (a fork between sibling prompts), the
    suffix prefill is split at the fork and the intermediate state
    published, so the next sibling splices from the fork instead of
    re-prefilling the shared template. The full prompt's snapshot is
    then published too, so every admission deepens the cache.

    A speculative engine prefills the draft's state alongside: both
    models must have consumed the prompt before drafting can start. The
    draft splices too when the hit carries a draft snapshot; otherwise
    it cold-prefills the whole prompt (states are independent — a
    draft-side cold start costs accept-rate nothing).
    """
    t_admit = time.perf_counter()
    plen = req.prompt.size
    cached, draft_snap = 0, None
    start = self._fresh_slot
    publish_fork = 0
    if self._cache is not None and plen > 1:
      cached, payload = self._cache.lookup(req.prompt[:plen - 1])
      if cached:
        target_snap, draft_snap = payload
        start = self.api.splice_prefix(self.cfg, self._fresh_slot,
                                       target_snap)
      # fork materialization: entries live at whole inserted prompts, so
      # two prompts sharing a prefix but diverging before any entry end
      # would never hit each other. The trie has *observed* their common
      # prefix even without an entry there — when that uncovered depth is
      # deep enough to be a real template (fork_min_tokens), split the
      # prefill at the fork and publish the intermediate state, so the
      # third sibling onward splices it. Carries are only valid at exact
      # lengths, which is why the fork state must come from a prefill
      # that stops there rather than a post-hoc slice.
      fork = self._cache.common_prefix_len(req.prompt[:plen - 1])
      if fork - cached >= self._cache.fork_min_tokens:
        publish_fork = fork
    # the draft snapshot (if any) is valid at the pre-fork depth only
    draft_from = cached
    if publish_fork:
      ftoks, fplens, fpos0 = self._pad_prefill(
          req.prompt[cached:publish_fork], cached)
      _, start = self._prefill(self.params, start, ftoks, fplens, fpos0)
      self._cache.insert(
          req.prompt[:publish_fork],
          (self.api.prefix_view(self.cfg, start, publish_fork), None))
      cached = publish_fork
    toks, plens, pos0 = self._pad_prefill(req.prompt[cached:], cached)
    sl = jnp.asarray(slot, jnp.int32)
    last, slot_state = self._prefill(self.params, start, toks, plens,
                                     pos0)
    self.state = self._insert(self.state, slot_state, sl)
    self.positions = self.positions.at[slot].set(plen)
    self._slots[slot] = _SlotState(req=req, remaining=req.max_new_tokens,
                                   active=True)
    # the first token always comes from the TARGET's prefill logits —
    # identical to vanilla admission, the draft only ever proposes
    tok = int(np.asarray(self._sample(last, temperature))[0, 0])
    self._slots[slot].ttft_s = time.perf_counter() - t_admit
    draft_slot = None
    if self._record_token(slot, tok, plen):
      self._slots[slot].next_tok = tok
      if self.speculate:
        # only slots that survive admission ever draft — a request that
        # retires here (budget 1, EOS in the prefill logits, full
        # cache) would waste the whole draft prefill
        if draft_snap is not None:
          dstart = self.api.splice_prefix(self.cfg, self._fresh_slot,
                                          draft_snap)
          dtoks, dplens, dpos0 = self._pad_prefill(
              req.prompt[draft_from:], draft_from)
          _, draft_slot = self._prefill(self.draft_params, dstart, dtoks,
                                        dplens, dpos0)
        else:
          ftoks, fplens, fpos0 = self._pad_prefill(req.prompt, 0)
          _, draft_slot = self._prefill(self.draft_params,
                                        self._fresh_slot, ftoks, fplens,
                                        fpos0)
        self.draft_state = self._insert(self.draft_state, draft_slot, sl)
    if self._cache is not None:
      # publish the full prompt (admission cost already sunk); carries
      # in slot_state are exactly at plen, so the snapshot is valid
      snap = self.api.prefix_view(self.cfg, slot_state, plen)
      dsnap = (self.api.prefix_view(self.cfg, draft_slot, plen)
               if draft_slot is not None else None)
      self._cache.insert(req.prompt, (snap, dsnap))
    # a request that retired during admission queued its publish; the
    # batch state already holds this slot's rows, so flush is safe here
    self._flush_retire_publish()

  def _admit_from_queue(self, temperature: float) -> None:
    slot = 0
    while self._queue and slot < self.batch:
      if self._slots[slot].active:
        slot += 1
        continue
      # a request may finish during admission (EOS in the prefill logits,
      # budget 1, or a full cache) — then the slot is still free
      self._admit(self._queue.popleft(), slot, temperature)

  def _decode_all(self, temperature: float) -> None:
    """One masked decode step for every slot. Inactive slots step with
    positions clamped to 0 and token 0; their state rows are garbage until
    the next admit overwrites them, which keeps the step program fixed."""
    active_np = self._active_mask()
    active = jnp.asarray(active_np)
    safe_pos = jnp.where(active, self.positions, 0)
    logits, self.state = self._step(self.params, self.state,
                                    jnp.asarray(self._next_tokens()),
                                    safe_pos)
    self.positions = jnp.where(active, self.positions + 1, self.positions)
    self.decode_steps += 1
    self.busy_slot_steps += int(active_np.sum())
    toks = np.asarray(self._sample(logits, temperature))
    pos = np.asarray(self.positions)        # one host sync per step
    for i in range(self.batch):
      if self._slots[i].active and self._record_token(i, int(toks[i, 0]),
                                                      int(pos[i])):
        self._slots[i].next_tok = int(toks[i, 0])
    # vanilla path: the stepped state is final — retired prefixes publish
    self._flush_retire_publish()

  def _decode_all_speculative(self, temperature: float) -> None:
    """One speculative iteration for every slot: draft k, verify k+1 in
    one fused window, commit the accepted prefix + bonus, rewind the
    rejected suffix. Temperature 0 accepts greedily (lossless:
    token-for-token vanilla greedy); temperature > 0 rejection-samples
    against the draft distribution (accept_sampled — the emitted tokens
    are distributed exactly as vanilla sampling from the target).

    Window layout per slot: inputs [t0, d_1..d_k] fed at positions
    p..p+k (t0 = the committed-but-unfed token) produce target
    distributions p_1..p_{k+1}; after accepting `a` drafts the slot
    commits d_1..d_a plus one more token (greedy: the target argmax
    g_{a+1}; sampled: the residual resample or the bonus draw) and its
    position moves to p+a+1. Writes past max_len fall off the cache
    (JAX scatter drops out-of-bounds updates) and the commit loop
    retires the slot at the boundary first, so the hard max_len
    contract survives speculation."""
    k = self.speculate
    sampled = temperature > 0.0
    active_np = self._active_mask()
    pos_np = np.asarray(self.positions)
    active = jnp.asarray(active_np)
    pos0 = jnp.where(active, self.positions, 0)

    # -- draft: k autoregressive proposals against the draft's own state
    if self._has_carry:
      draft_snap = self.draft_state    # pre-draft carry snapshot (refs)
    cur = jnp.asarray(self._next_tokens())
    cols = [cur]
    draft_lgs = []          # sampled path: q_j, the draft distributions
    for j in range(k):
      # step 0 reads the pre-draft snapshot (must survive — no
      # donation); later steps consume disposable intermediates
      step_fn = self._draft_step0 if j == 0 else self._step
      lg, self.draft_state = step_fn(self.draft_params, self.draft_state,
                                     cur, pos0 + j)
      cur = self._sample(lg, temperature)
      cols.append(cur)
      if sampled:
        draft_lgs.append(lg[:, -1:])
    if not self._has_carry:
      # pure-KV families: one extra draft step consumes d_k so a fully
      # accepted window leaves the draft cache complete through p+k
      # (carry families cover this with the replay below instead)
      _, self.draft_state = self._step(self.draft_params,
                                       self.draft_state, cur, pos0 + k)
    window = jnp.concatenate(cols, axis=1)          # (b, k+1)

    # -- verify: all k+1 positions in one fused window step
    if self._has_carry:
      snap = self.state                # pre-window carry snapshot (refs)
    logits_w, self.state = self._window(self.params, self.state, window,
                                        pos0)
    window_np = np.asarray(window)
    if sampled:
      # rejection sampling needs the exact distributions both models
      # sample from: softmax of the float32 logits at the temperature
      q = _host_probs(jnp.concatenate(draft_lgs, axis=1), temperature)
      p = _host_probs(logits_w, temperature)
      if not active_np.all():
        # inactive slots step with garbage state rows; their (discarded)
        # acceptance math still must not see non-finite probabilities
        q[~active_np] = 1.0 / q.shape[-1]
        p[~active_np] = 1.0 / p.shape[-1]
      accept, out_toks, out_len = accept_sampled(window_np[:, 1:], q, p,
                                                 self._host_rng())
    else:
      target = np.asarray(jnp.argmax(logits_w, axis=-1), np.int32)
      accept, out_toks, out_len = accept_longest_prefix(window_np[:, 1:],
                                                        target)
    self.decode_steps += 1
    self.busy_slot_steps += int(active_np.sum())

    # -- commit: accepted prefix + bonus, via the vanilla retirement rules
    commit = np.ones((self.batch,), np.int32)  # window tokens consumed
    for i in range(self.batch):
      s = self._slots[i]
      if not s.active:
        continue
      self.drafted_tokens += k
      alive = True
      for j in range(int(out_len[i])):
        commit[i] = j + 1
        alive = self._record_token(i, int(out_toks[i, j]),
                                   int(pos_np[i]) + j + 1)
        if not alive:
          break                      # EOS / budget / max_len mid-window
      if alive:
        s.next_tok = int(out_toks[i, int(out_len[i]) - 1])   # the bonus
      # realized acceptance only: drafts the window agreed on but a
      # mid-window retirement never emitted don't inflate the rate
      self.accepted_tokens += min(int(accept[i]), int(commit[i]))
    commit_j = jnp.asarray(commit)
    self.positions = jnp.where(active, self.positions + commit_j,
                               self.positions)

    # -- rewind the rejected suffix (per-family, see decode_state_carry):
    # KV rows past the new position are dead until overwritten; carries
    # restore the snapshot and replay the accepted prefix masked. Slots
    # retired above tolerate garbage (the next admit splices a fully
    # fresh state), so only surviving slots constrain the rewind. The
    # path choice below depends on the accept pattern; that is sound
    # because every path computes the same committed state bit-for-bit
    # (window scan == masked replay scan == lone steps — the same
    # cross-program invariant losslessness rests on).
    replayed = False
    if self._has_carry:
      live = [i for i in range(self.batch) if self._slots[i].active]
      if live and any(commit[i] != k + 1 for i in live):
        replayed = True
        # a surviving slot rejected part of its window: carries come
        # from the snapshots, replayed through the accepted prefix
        restored = merge_rewind(self.state, snap, self._carry)
        _, self.state = self._replay(self.params, restored, window,
                                     commit_j, pos0)
        restored = merge_rewind(self.draft_state, draft_snap, self._carry)
        _, self.draft_state = self._replay(self.draft_params, restored,
                                           window, commit_j, pos0)
      elif live:
        # every surviving slot accepted its whole window: the target's
        # post-window carries already ARE the committed carries, and
        # the draft (one token behind — it never consumed d_k) catches
        # up with a single step instead of a (k+1)-position replay
        _, self.draft_state = self._step(self.draft_params,
                                         self.draft_state, cur, pos0 + k)
    # retired prefixes: a slot's carries are the committed values if this
    # family has none (KV rows [0, fed) are always exact), if the masked
    # replay above re-advanced every row to its own commit count, or if
    # the slot accepted its WHOLE window (post-window carries == state at
    # exactly `fed` tokens). Only partially-accepted retired slots under
    # the full-accept fast path hold post-window garbage — drop exactly
    # those, per slot, instead of the whole batch's publishes.
    invalid = ()
    if self._has_carry and not replayed:
      invalid = {s for (s, _, _) in self._pending_publish
                 if int(commit[s]) != k + 1}
    self._flush_retire_publish(invalid_slots=invalid)
    self._maybe_adapt_rank()

  def _host_rng(self) -> np.random.Generator:
    """One host-side RNG per speculative acceptance round, forked from
    the engine's JAX key chain — run(rng=...) reproduces the rejection
    draws exactly like it reproduces the categorical samples."""
    self.rng, k = jax.random.split(self.rng)
    seed = np.asarray(jax.random.randint(k, (2,), 0, np.iinfo(np.int32).max))
    return np.random.default_rng(seed.tolist())

  def _maybe_adapt_rank(self) -> None:
    """Rank-controller tick: every `interval` engine iterations, measure
    the window's accept rate and apply the controller's proposal by
    rebuilding the draft params at the new rank. The draft's decode
    state carries over (factoring weights never changes state shapes) —
    stale draft-side caches cost accept rate for a few iterations, never
    correctness (the target verifies everything). Draft-side programs
    retrace for the new factor shapes; the verify window does not."""
    rc = self.rank_controller
    if rc is None or self.decode_steps - self._ctrl_step0 < rc.interval:
      return
    d = self.drafted_tokens - self._ctrl_drafted0
    a = self.accepted_tokens - self._ctrl_accepted0
    new = rc.propose(self.draft_rank, a / d if d else None)
    if new != self.draft_rank:
      self.rank_history.append((self.decode_steps, self.draft_rank, new))
      self.draft_rank = new
      self.draft_params = make_draft_params(self.params, rank=new)
    self._ctrl_step0 = self.decode_steps
    self._ctrl_drafted0 = self.drafted_tokens
    self._ctrl_accepted0 = self.accepted_tokens

  def run(self, *, temperature: float = 0.0, rng=None) -> list:
    """Drain the queue: admit, decode, retire, refill until idle. Returns
    the requests finished since the last call, in submission order.
    `rng` seeds sampled (temperature > 0) decoding for this call — pass
    the same key to reproduce a run exactly (speculative rejection
    sampling forks its host RNG from the same chain)."""
    if rng is not None:
      self.rng = rng
    while self._queue or self.num_active:
      self._admit_from_queue(temperature)
      if self.num_active:
        if self.speculate:
          self._decode_all_speculative(temperature)
        else:
          self._decode_all(temperature)
    out = [self._finished[uid] for uid in sorted(self._finished)]
    self._finished = {}
    return out

  # -- static-batch compatibility surface -----------------------------------

  def prefill(self, prompts: np.ndarray) -> jax.Array:
    """Feed prompts (b, p) through the fused prefill scan; returns last
    logits (b, 1, v). Static-batch surface: b must equal batch_size."""
    prompts = np.asarray(prompts)
    b, p = prompts.shape
    if b != self.batch:
      raise ValueError(f"prefill batch {b} != engine batch {self.batch}")
    if p == 0:
      raise ValueError("empty prompts")
    start = np.asarray(self.positions)
    if int(start.max()) + p > self.max_len:
      raise ValueError(
          f"prefill would pass max_len={self.max_len} "
          f"(start {int(start.max())} + prompt {p})")
    bucket = min(max(self.max_len, 1), _next_pow2(p))
    self._count_prefill(b, bucket)
    padded = np.zeros((b, bucket), np.int32)
    padded[:, :p] = prompts
    logits, self.state = self._prefill(
        self.params, self.state, jnp.asarray(padded),
        jnp.full((b,), p, jnp.int32), self.positions)
    self.positions = self.positions + p
    return logits

  def generate(self, prompts: np.ndarray, *, steps: int,
               temperature: float = 0.0, rng=None) -> GenerationResult:
    """Static-batch wrapper over the continuous engine: every row becomes
    a request with a `steps` token budget and no EOS exit (legacy
    semantics). Rows retired early at the max_len boundary come back
    shorter; see `lengths`. Accepts more rows than slots — extras queue.
    A speculative engine reports the measured accept rate of the call."""
    prompts = np.asarray(prompts)
    drafted0, accepted0 = self.drafted_tokens, self.accepted_tokens
    uids = [self.submit(row, max_new_tokens=steps, eos_id=None)
            for row in prompts]
    by_uid = {f.uid: f for f in self.run(temperature=temperature, rng=rng)}
    tokens = np.zeros((len(uids), steps), np.int32)
    lengths = np.zeros((len(uids),), np.int32)
    for r, uid in enumerate(uids):
      t = by_uid[uid].tokens
      tokens[r, :t.size] = t
      lengths[r] = t.size
    drafted = self.drafted_tokens - drafted0
    rate = ((self.accepted_tokens - accepted0) / drafted
            if self.speculate and drafted else None)
    return GenerationResult(tokens=tokens, steps=steps, lengths=lengths,
                            accept_rate=rate)

  def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
    lg = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0:
      return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    self.rng, k = jax.random.split(self.rng)
    return jax.random.categorical(
        k, lg / temperature, axis=-1)[:, None].astype(jnp.int32)


# ----------------------------------------------------------------------------
# Streaming speech.
# ----------------------------------------------------------------------------


def _same_pad(size: int, kernel: int, stride: int) -> tuple[int, int]:
  """XLA/TF SAME padding split for a fixed, fully visible axis length."""
  out = -(-size // stride)
  total = max((out - 1) * stride + kernel - size, 0)
  return total // 2, total - total // 2


class _ConvStream:
  """One strided-conv stage streamed over time.

  Implements the `deepspeech.conv_time_pads` convention: a fixed left
  pad of (k - s) // 2 zeros is materialized once at stream start, pushed
  frames are buffered, and output frame j is emitted as soon as its
  receptive field [j*s - pl, j*s - pl + k) is complete. `flush` computes
  the right pad *from the actual frame count* — exactly the zeros needed
  to complete ceil(n_in / s) output frames — so chunked emission equals
  the full-utterance conv frame-for-frame for ANY utterance length, not
  just stride multiples (the old fixed right pad asserted alignment).
  """

  def __init__(self, kernel: int, stride: int, apply_fn):
    self.k, self.s = kernel, stride
    self.pad_l = (kernel - stride) // 2
    self.apply = apply_fn        # (b, t, ...) -> outputs, VALID in time
    self.buf: Optional[np.ndarray] = None
    self.n_in = 0                # frames received, padding excluded
    self.n_out = 0               # frames emitted so far
    self.flushed = False

  def _zeros(self, like: np.ndarray, t: int) -> np.ndarray:
    return np.zeros((like.shape[0], t) + like.shape[2:], like.dtype)

  def _emit(self) -> Optional[np.ndarray]:
    n = self.buf.shape[1]
    m = (n - self.k) // self.s + 1 if n >= self.k else 0
    if m <= 0:
      return None
    window = self.buf[:, :(m - 1) * self.s + self.k]
    self.buf = self.buf[:, m * self.s:]
    self.n_out += m
    return np.asarray(self.apply(window))

  def push(self, x) -> Optional[np.ndarray]:
    if self.flushed:
      raise RuntimeError("conv stream already flushed; reset() first")
    x = np.asarray(x)
    if x.shape[1] == 0:
      return None
    if self.buf is None:
      self.buf = np.concatenate([self._zeros(x, self.pad_l), x], axis=1)
    else:
      self.buf = np.concatenate([self.buf, x.astype(self.buf.dtype)],
                                axis=1)
    self.n_in += x.shape[1]
    return self._emit()

  def flush(self) -> Optional[np.ndarray]:
    # idempotent: re-flushing must not re-pad the residual buffer and
    # complete a fake window
    if self.buf is None or self.flushed:
      self.flushed = True
      return None
    self.flushed = True
    out_total = -(-self.n_in // self.s)
    pad_r = (out_total - 1) * self.s + self.k - self.pad_l - self.n_in
    if pad_r > 0:
      self.buf = np.concatenate(
          [self.buf, self._zeros(self.buf, pad_r)], axis=1)
    return self._emit()

  def reset(self) -> None:
    self.buf = None
    self.n_in = 0
    self.n_out = 0
    self.flushed = False


@dataclasses.dataclass
class SpeechResult:
  """One retired utterance from the speech fleet."""
  uid: int
  labels: list                  # collapsed greedy-CTC label sequence
  frames: int                   # raw mel frames consumed


class _SpeechSlot:
  """Host-side ownership record for one speech stream: the per-stream
  conv receptive-field context (`s1`/`s2`), the per-stream CTC collapse
  state (`prev` — reset to -1 on admit, never shared across slots), the
  post-frontend frames awaiting a decode step (`pending`), and the
  labels emitted so far. The speech sibling of `_SlotState`."""

  __slots__ = ("uid", "feats", "fed", "labels", "prev", "s1", "s2",
               "pending", "flushed")

  def __init__(self, uid, feats, s1, s2):
    self.uid = uid
    self.feats = feats            # (t, feat_dim) np, or None (lockstep)
    self.fed = 0                  # raw frames pushed into s1 so far
    self.labels: list = []
    self.prev = -1                # per-stream collapse state
    self.s1, self.s2 = s1, s2
    self.pending = collections.deque()   # (gru_in,) frames to decode
    self.flushed = False          # frontend drained (right edge padded)

  @property
  def done(self) -> bool:
    return self.flushed and not self.pending


class StreamingSpeechServer:
  """Continuous-batching frame-synchronous DS2 fleet (paper §4 regime).

  Two serving surfaces over the same masked decode program:

  * **Fleet** (`submit` + `run`): an admit/chunk/retire lifecycle over
    `batch_size` slots. Each admitted utterance owns a `_SpeechSlot`
    with its own pair of `_ConvStream` frontends (receptive-field
    context never crosses streams) and its own CTC collapse state
    (reset on admit). Every decode step is ONE masked fixed-shape
    `frame_step` over all slots — inactive or exhausted slots keep
    their state via the mask — so thousands of utterances of mixed,
    arbitrary (non-stride-multiple) lengths share one jit signature
    across retire -> refill, exactly like `LMEngine`'s decode step.
    Slot admission zeroes the slot's GRU rows through the jitted
    `ModelApi.insert_slot` surgery (traced slot index: one program).

  * **Lockstep** (`process_chunk` / `flush`): the legacy single-group
    API — all `batch_size` streams advance through the same chunk
    boundaries. Kept for frame-synchronous duplex use; internally it is
    the fleet path with every slot live.

  Chunked emission is exactly the full-utterance `deepspeech.forward`
  for ANY utterance length: the conv frontend follows the fixed-left-pad
  convention of `deepspeech.conv_time_pads`, and `_ConvStream.flush`
  right-pads to complete ceil(t / stride) frames instead of asserting
  stride alignment.
  """

  def __init__(self, model_cfg: ModelConfig, params: Any, *,
               batch_size: int = 1, kernel_policy=None):
    self.cfg = model_cfg
    self.params = params
    self.batch = batch_size
    # frame-synchronous GRU steps are the paper's decode regime; a
    # "pallas" policy routes them through gru_cell / decode_matvec
    policy = resolve_policy(kernel_policy, batch_size)
    self.kernel_policy = policy
    self._api = get_model(model_cfg)
    self.state = deepspeech.init_decode_state(model_cfg, batch_size)

    def frame_step(params, state, x_t, active):
      log_probs, new = deepspeech.decode_step(params, state, x_t,
                                              model_cfg, policy=policy)
      new = jax.tree.map(
          lambda n, o: jnp.where(_bcast_mask(active, n.ndim, 0), n, o),
          new, state)
      return log_probs, new
    self._frame_step = jax.jit(frame_step, donate_argnums=(1,))

    def insert(state, slot_state, slot):
      return self._api.insert_slot(model_cfg, state, slot_state, slot)
    self._insert = jax.jit(insert, donate_argnums=(0,))
    self._fresh_slot = deepspeech.init_decode_state(model_cfg, 1)

    cfg = model_cfg
    # geometry comes from the conv weights themselves (one source of
    # truth with deepspeech.init_model) + the shared stride constants
    k1t, k1f = params["conv1"].shape[:2]
    k2t, k2f = params["conv2"].shape[:2]
    s1t, sf = deepspeech.CONV1_TIME_STRIDE, deepspeech.CONV_FREQ_STRIDE
    f1l, f1r = _same_pad(cfg.feat_dim, k1f, sf)
    f2l, f2r = _same_pad(-(-cfg.feat_dim // sf), k2f, sf)
    self._geom = (k1t, s1t, k2t, cfg.time_stride)
    freq_after = ((cfg.feat_dim + 1) // 2 + 1) // 2
    self._gru_in = freq_after * cfg.conv_channels

    def conv1(params, x):                       # (b, t, f) raw mel
      x = jax.lax.conv_general_dilated(
          x[..., None].astype(cfg.dtype), params["conv1"],
          window_strides=(s1t, sf), padding=((0, 0), (f1l, f1r)),
          dimension_numbers=("NHWC", "HWIO", "NHWC"))
      return jax.nn.relu(x.astype(jnp.float32)).astype(cfg.dtype)

    def conv2(params, x):                       # (b, t, f', ch)
      x = jax.lax.conv_general_dilated(
          x, params["conv2"], window_strides=(cfg.time_stride, sf),
          padding=((0, 0), (f2l, f2r)),
          dimension_numbers=("NHWC", "HWIO", "NHWC"))
      x = jax.nn.relu(x.astype(jnp.float32)).astype(cfg.dtype)
      b, t, f, c = x.shape
      return x.reshape(b, t, f * c)

    self._conv1 = jax.jit(conv1)
    self._conv2 = jax.jit(conv2)
    self._buckets1: set = set()
    self._buckets2: set = set()

    self._slots: list = [None] * batch_size
    self._queue: collections.deque = collections.deque()
    self._next_uid = 0
    self._mode: Optional[str] = None     # None | "lockstep" | "fleet"
    self._finished = False               # lockstep: utterance finalized
    self.decode_steps = 0                # masked frame_step invocations
    self.busy_steps = 0                  # live (slot, frame) pairs stepped

  # -- shared machinery -----------------------------------------------------

  def _bucketed(self, fn, kernel, stride, buckets: set, window):
    """Run a VALID-in-time conv over `window`, padded on the right to a
    pow2 time bucket so a stream's varying window lengths reuse a small
    set of jit signatures; the pad only creates extra output frames past
    the real ones, which are sliced off (VALID conv is local)."""
    t = window.shape[1]
    m = (t - kernel) // stride + 1
    tp = max(_next_pow2(t), kernel)
    if tp != t:
      pad = np.zeros((window.shape[0], tp - t) + window.shape[2:],
                     window.dtype)
      window = np.concatenate([window, pad], axis=1)
    buckets.add(tp)
    return np.asarray(fn(self.params, jnp.asarray(window)))[:, :m]

  def _make_streams(self):
    s1 = _ConvStream(self._geom[0], self._geom[1],
                     lambda x: self._bucketed(self._conv1, self._geom[0],
                                              self._geom[1],
                                              self._buckets1, x))
    s2 = _ConvStream(self._geom[2], self._geom[3],
                     lambda x: self._bucketed(self._conv2, self._geom[2],
                                              self._geom[3],
                                              self._buckets2, x))
    return s1, s2

  def _feed_slot(self, slot: _SpeechSlot, feats, *, final: bool) -> None:
    """Push raw mel frames (1, t, f) through the slot's conv streams;
    queue every completed post-frontend frame for decoding."""
    outs = []
    if feats is not None and feats.shape[1]:
      y1 = slot.s1.push(feats)
      if y1 is not None and y1.shape[1]:
        outs.append(slot.s2.push(y1))
    if final and not slot.flushed:
      y1 = slot.s1.flush()
      if y1 is not None and y1.shape[1]:
        outs.append(slot.s2.push(y1))
      outs.append(slot.s2.flush())
      slot.flushed = True
    for o in outs:
      if o is not None and o.shape[1]:
        slot.pending.extend(np.asarray(o[0]))

  def _decode_pending(self) -> list:
    """Masked frame steps until no live slot has a pending frame.

    One fixed-shape `frame_step` per frame position: slots without a
    frame at this position are masked out of the state update and their
    (garbage) logits ignored — the speech analogue of LMEngine's masked
    decode. Greedy-CTC collapse runs per live slot against ITS OWN
    `prev`. Returns per-slot newly emitted labels (lockstep API)."""
    emitted = [[] for _ in range(self.batch)]
    dtype = np.dtype(self.cfg.dtype)
    while True:
      live = [i for i, s in enumerate(self._slots)
              if s is not None and s.pending]
      if not live:
        return emitted
      x = np.zeros((self.batch, self._gru_in), dtype)
      mask = np.zeros((self.batch,), bool)
      for i in live:
        x[i] = self._slots[i].pending.popleft()
        mask[i] = True
      log_probs, self.state = self._frame_step(
          self.params, self.state, jnp.asarray(x), jnp.asarray(mask))
      best = np.asarray(jnp.argmax(log_probs, axis=-1))
      for i in live:
        slot, b = self._slots[i], int(best[i])
        if b != 0 and b != slot.prev:
          slot.labels.append(b)
          emitted[i].append(b)
        slot.prev = b
      self.decode_steps += 1
      self.busy_steps += len(live)

  # -- fleet lifecycle ------------------------------------------------------

  def submit(self, feats: np.ndarray) -> int:
    """Queue one utterance (t, feat_dim) of ANY length; returns its uid."""
    if self._mode == "lockstep":
      raise RuntimeError("server is mid-lockstep-utterance; reset() first")
    self._mode = "fleet"
    feats = np.asarray(feats)
    if feats.ndim != 2 or feats.shape[-1] != self.cfg.feat_dim:
      raise ValueError(f"expected (t, {self.cfg.feat_dim}) mel features, "
                       f"got {feats.shape}")
    uid = self._next_uid
    self._next_uid += 1
    self._queue.append((uid, feats))
    return uid

  def _admit(self) -> None:
    for i in range(self.batch):
      if self._slots[i] is None and self._queue:
        uid, feats = self._queue.popleft()
        s1, s2 = self._make_streams()
        slot = _SpeechSlot(uid, feats, s1, s2)
        self._slots[i] = slot
        # zero the slot's GRU rows (jitted surgery, traced slot index:
        # one program for every slot) and reset ITS collapse state —
        # a reused slot must not inherit the previous utterance's
        # hidden state or last emitted label
        self.state = self._insert(self.state, self._fresh_slot,
                                  jnp.int32(i))

  def run(self, chunk_frames: int = 16) -> list:
    """Drain the submitted queue; returns `SpeechResult`s in retire
    order. Each iteration admits into free slots, feeds every live slot
    its next `chunk_frames` raw frames (finalizing streams that hit end
    of utterance), masked-steps all pending post-frontend frames, and
    retires finished slots so the queue refills them — no slot idles
    while work remains, and no program re-traces across refills."""
    if self._mode == "lockstep":
      raise RuntimeError("server is mid-lockstep-utterance; reset() first")
    results = []
    while self._queue or any(s is not None for s in self._slots):
      self._admit()
      for slot in self._slots:
        if slot is None or slot.flushed:
          continue
        end = min(slot.fed + chunk_frames, slot.feats.shape[0])
        chunk = slot.feats[None, slot.fed:end]
        slot.fed = end
        self._feed_slot(slot, chunk, final=end == slot.feats.shape[0])
      self._decode_pending()
      for i, slot in enumerate(self._slots):
        if slot is not None and slot.done:
          results.append(SpeechResult(uid=slot.uid, labels=slot.labels,
                                      frames=int(slot.feats.shape[0])))
          self._slots[i] = None
    self._mode = None
    return results

  @property
  def occupancy(self) -> float:
    """Live (slot, frame) pairs per decode step, over batch capacity."""
    total = self.decode_steps * self.batch
    return self.busy_steps / total if total else 0.0

  def compile_stats(self) -> dict:
    """Jit cache sizes (-1: runtime doesn't expose them). The fleet
    contract mirrors LMEngine's: `frame_step` == 1 ever — admits,
    retires, refills, mask patterns and mixed lengths never re-trace —
    and each conv stage holds one signature per pow2 window bucket."""
    return {
        "frame_step": _jit_cache_size(self._frame_step),
        "insert": _jit_cache_size(self._insert),
        "conv1": _jit_cache_size(self._conv1),
        "conv2": _jit_cache_size(self._conv2),
        "conv1_buckets": sorted(self._buckets1),
        "conv2_buckets": sorted(self._buckets2),
    }

  # -- lockstep API (legacy duplex surface) ---------------------------------

  def reset(self) -> None:
    self.state = deepspeech.init_decode_state(self.cfg, self.batch)
    self._slots = [None] * self.batch
    self._queue.clear()
    self._mode = None
    self._finished = False

  def _lockstep_slots(self) -> list:
    if self._mode == "fleet":
      raise RuntimeError("server is mid-fleet-run; reset() first")
    self._mode = "lockstep"
    if all(s is None for s in self._slots):
      for i in range(self.batch):
        s1, s2 = self._make_streams()
        self._slots[i] = _SpeechSlot(None, None, s1, s2)
    return self._slots

  def process_chunk(self, feats: np.ndarray, *,
                    final: bool = False) -> list:
    """feats (b, t, feat_dim) raw mel chunk -> newly emitted labels.

    Emission lags the chunk boundary by the frontend's receptive field —
    the context carried so chunked output equals the full forward for
    any total length. Pass final=True (or call flush()) after the last
    chunk; a redundant final/flush is a no-op, new frames after it
    require reset()."""
    feats = np.asarray(feats)
    if self._finished:
      if feats.shape[1]:
        raise RuntimeError("utterance already finalized; reset() first")
      return [[] for _ in range(self.batch)]
    slots = self._lockstep_slots()
    for i, slot in enumerate(slots):
      self._feed_slot(slot, feats[i:i + 1] if feats.shape[1] else None,
                      final=final)
    if final:
      self._finished = True
    return self._decode_pending()

  def flush(self) -> list:
    """Drain the right-edge conv context at end of utterance. The right
    pad is computed from the frames actually received, so arbitrary
    (non-stride-multiple) utterance lengths flush cleanly."""
    return self.process_chunk(
        np.zeros((self.batch, 0, self.cfg.feat_dim), np.float32),
        final=True)
