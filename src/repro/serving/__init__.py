"""Serving engines: continuous-batching LM decode (with lossless
self-speculative decoding and radix-trie prefix caching) + streaming
speech."""
from repro.serving.engine import (FinishedRequest, GenerationResult,
                                  LMEngine, Request, StreamingSpeechServer)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.speculative import (RankController,
                                       accept_longest_prefix,
                                       accept_sampled, make_draft_params)

__all__ = ["FinishedRequest", "GenerationResult", "LMEngine",
           "PrefixCache", "RankController", "Request",
           "StreamingSpeechServer", "accept_longest_prefix",
           "accept_sampled", "make_draft_params"]
