"""Serving engines: batched LM decode + streaming speech."""
from repro.serving.engine import (GenerationResult, LMEngine,
                                  StreamingSpeechServer)

__all__ = ["GenerationResult", "LMEngine", "StreamingSpeechServer"]
