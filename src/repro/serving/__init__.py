"""Serving engines: batched LM decode + streaming speech."""
from repro.serving.engine import (GenerationResult, LMEngine,
                                  StreamingSpeechServer)
