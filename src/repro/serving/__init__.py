"""Serving engines: continuous-batching LM decode (with lossless
self-speculative decoding) + streaming speech."""
from repro.serving.engine import (FinishedRequest, GenerationResult,
                                  LMEngine, Request, StreamingSpeechServer)
from repro.serving.speculative import (accept_longest_prefix,
                                       make_draft_params)

__all__ = ["FinishedRequest", "GenerationResult", "LMEngine", "Request",
           "StreamingSpeechServer", "accept_longest_prefix",
           "make_draft_params"]
