"""Serving engines: continuous-batching LM decode + streaming speech."""
from repro.serving.engine import (FinishedRequest, GenerationResult,
                                  LMEngine, Request, StreamingSpeechServer)

__all__ = ["FinishedRequest", "GenerationResult", "LMEngine", "Request",
           "StreamingSpeechServer"]
