"""repro — trace-norm regularized low-rank training & low-batch inference
(Kliegl et al. 2017) as a multi-pod JAX framework. See README.md."""

__version__ = "1.0.0"
