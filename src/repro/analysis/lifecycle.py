"""Executing lifecycle checks: jit caches after real serve cycles.

Four checks live here — `retrace_stability` (the vanilla engine
lifecycle), `prefix_splice_stability` (the prefix-cache splice path
must not add prefill signatures beyond the cold path's, and spliced
greedy output must match cold token-for-token),
`spec_window_stability` (the batched speculative verify window compiles
exactly one signature per (bucket, k) — across greedy AND sampled
cycles and across mid-serve draft-rank walks, which retrace only
draft-side programs), and `speech_fleet_stability` (the continuous-
batching speech fleet: one masked frame-step signature across
admit/retire/refill with mixed non-stride-multiple utterance lengths,
bucketed conv windows, and fleet output token-identical to serial
per-utterance decoding).

Retrace-stability: the engine's jit caches after a real serve cycle.

Unlike the other checks this one must *execute* (tiny, smoke-scale,
batch 2, a handful of tokens): jit cache sizes only exist after calls.
The scenario is chosen to exercise every lifecycle edge that could
silently re-trace:

  * two prompt lengths in different pow2 buckets (admission prefill
    compiles per bucket — that is the contract, counted not flagged),
  * more requests than slots with tiny budgets, forcing retire ->
    refill-from-queue (insert_slot + a second admission prefill), and
  * enough decode steps that any shape drift in the donated state
    signature would show up as step cache > 1.

Invariants, per `LMEngine.compile_stats`:

  step == 1                                  one decode signature, ever
  prefill == len(prefill_buckets)            bucketed, nothing beyond
  replay, window, insert each <= 1           auxiliary programs stable

A -1 from compile_stats means the runtime does not expose jit cache
sizes; the check is skipped (reported in target info), never failed.

Families: the three token-driven LMs (qwen3, zamba2, xlstm) run the
LMEngine checks; deepspeech runs the speech-fleet check. Whisper
decodes against encoder memory the engine does not synthesize and is
not audited here.
"""
from __future__ import annotations

from typing import Iterable, List, Tuple

import jax
import numpy as np

from repro import configs
from repro.analysis.report import Finding
from repro.analysis.targets import normalize_config
from repro.models.api import get_model
from repro.serving.engine import LMEngine, StreamingSpeechServer
from repro.serving.prefix_cache import PrefixCache
from repro.serving.speculative import RankController

#: configs whose family runs the full LMEngine lifecycle
LIFECYCLE_CONFIGS = ("qwen3-4b", "zamba2-7b", "xlstm-350m")

_VOCAB = 64
_BATCH = 2
_MAX_LEN = 16
#: lengths 3 and 6 pad into distinct pow2 buckets (4 and 8)
_PROMPT_LENS = (3, 6, 3)
_BUDGET = 3


def _serve_cycle(cfg, params, policy: str) -> dict:
  eng = LMEngine(cfg, params, batch_size=_BATCH, max_len=_MAX_LEN,
                 kernel_policy=None if policy == "jnp" else policy)
  rs = np.random.RandomState(0)
  for n in _PROMPT_LENS:      # 3 requests, 2 slots -> retire + refill
    eng.submit(rs.randint(1, _VOCAB, size=(n,)), max_new_tokens=_BUDGET)
  done = eng.run()
  assert len(done) == len(_PROMPT_LENS)
  return eng.compile_stats()


def check_retrace_stability(
    config_names: Iterable[str],
    policies: Iterable[str]) -> Tuple[List[Finding], List[dict]]:
  """Run the serve cycle for every requested lifecycle-capable config x
  policy; return (findings, per-run info rows)."""
  findings: List[Finding] = []
  infos: List[dict] = []
  for name in config_names:
    name = normalize_config(name)
    if name not in LIFECYCLE_CONFIGS:
      continue
    cfg = configs.get_smoke(name).with_(vocab_size=_VOCAB)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)

    for policy in policies:
      stats = _serve_cycle(cfg, params, policy)
      info = dict(config=name, policy=policy, quant="-",
                  program="lifecycle", compile_stats=stats)
      infos.append(info)

      def fail(key: str, detail: str) -> None:
        findings.append(Finding(
            check="retrace_stability", config=name, policy=policy,
            program="lifecycle", key=key, detail=detail))

      if stats["step"] < 0:
        info["skipped"] = "jit cache sizes unavailable on this runtime"
        continue
      if stats["step"] != 1:
        fail(f"step-cache:{stats['step']}",
             f"decode step compiled {stats['step']} signatures across a "
             f"serve cycle (admit/decode/retire/refill) — the donated "
             f"state shape is not stable")
      n_buckets = len(stats["prefill_buckets"])
      if stats["prefill"] != n_buckets:
        fail(f"prefill-cache:{stats['prefill']}/buckets:{n_buckets}",
             f"prefill compiled {stats['prefill']} signatures but only "
             f"{n_buckets} (batch, bucket) shapes were admitted "
             f"({stats['prefill_buckets']}): a prompt shape escaped "
             f"bucketing")
      for prog in ("replay", "window", "insert", "draft_step0"):
        n = stats.get(prog, 0)
        if n > 1:
          fail(f"{prog}-cache:{n}",
               f"auxiliary program {prog!r} compiled {n} signatures in "
               f"one serve cycle")
  return findings, infos


# ---------------------------------------------------------------------------
# prefix_splice_stability
# ---------------------------------------------------------------------------

#: two shared-prefix buckets + an unrelated prompt, chosen so the warm
#: path's pieces land in exactly the cold path's buckets:
#:   A   full len 8            -> bucket 8 (cold and warm both)
#:   B   A[:4] + new suffix    -> cold bucket 8; warm fork-splits into
#:                                4 (template, published) + 4 (suffix)
#:   C   A[:4] + other suffix  -> cold bucket 8; warm splices B's fork
#:                                entry and prefills only bucket 4
#:   D   unrelated len 4       -> bucket 4 (cold and warm both)
#: so cold and warm prefill signature sets are both {(1,4), (1,8)} and
#: any extra warm signature is the splice path leaking a new jit shape.
#: Tokens are pinned (not drawn) so the prompts provably diverge right
#: at the fork and D shares no first token with A-C.
_SPLICE_PROMPTS = (
    (1, 2, 3, 4, 5, 6, 7, 8),
    (1, 2, 3, 4, 9, 10, 11, 12),
    (1, 2, 3, 4, 13, 14, 15, 16),
    (20, 21, 22, 23),
)


def _splice_cycle(cfg, params, policy: str, cache) -> Tuple[dict, dict]:
  """Serve the splice scenario; returns (uid -> tokens, compile_stats)."""
  eng = LMEngine(cfg, params, batch_size=_BATCH, max_len=_MAX_LEN,
                 kernel_policy=None if policy == "jnp" else policy,
                 prefix_cache=cache)
  for p in _SPLICE_PROMPTS:   # 4 requests, 2 slots -> retire + refill
    eng.submit(np.asarray(p, np.int32), max_new_tokens=_BUDGET)
  done = eng.run()
  assert len(done) == len(_SPLICE_PROMPTS)
  return ({f.uid: tuple(int(t) for t in f.tokens) for f in done},
          eng.compile_stats())


def check_prefix_splice_stability(
    config_names: Iterable[str],
    policies: Iterable[str]) -> Tuple[List[Finding], List[dict]]:
  """Cold vs cached-splice serve cycles over shared-prefix traffic.

  Invariants: the warm engine keeps the cold engine's compile contract
  (step == 1, prefill == len(prefill_buckets), aux programs <= 1), its
  prefill bucket SET equals the cold set (the splice path introduces no
  new jit signatures — the acceptance bar from ISSUE 7), the cache
  actually hit (otherwise the splice path silently never ran and the
  equality is vacuous), and warm greedy tokens equal cold greedy tokens
  for every request (splice is bit-exact, not just shape-stable).
  """
  findings: List[Finding] = []
  infos: List[dict] = []
  for name in config_names:
    name = normalize_config(name)
    if name not in LIFECYCLE_CONFIGS:
      continue
    cfg = configs.get_smoke(name).with_(vocab_size=_VOCAB)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)

    for policy in policies:
      cache = PrefixCache(capacity_mb=64)
      cold_toks, cold = _splice_cycle(cfg, params, policy, None)
      warm_toks, warm = _splice_cycle(cfg, params, policy, cache)
      cs = cache.stats()
      info = dict(config=name, policy=policy, quant="-",
                  program="lifecycle", check="prefix_splice_stability",
                  compile_stats=warm, cache_stats=cs)
      infos.append(info)

      def fail(key: str, detail: str) -> None:
        findings.append(Finding(
            check="prefix_splice_stability", config=name, policy=policy,
            program="lifecycle", key=key, detail=detail))

      if warm_toks != cold_toks:
        fail("token-parity",
             f"cached-splice greedy tokens diverged from cold serving "
             f"(cold {cold_toks} vs warm {warm_toks}) — the spliced "
             f"state is not bit-identical to the cold prefill state")
      if cs["hits"] < 1:
        fail("no-hits",
             f"the shared-prefix scenario produced no cache hits "
             f"({cs}) — the splice path never ran, so its stability "
             f"was not exercised")
      if warm["step"] < 0:
        info["skipped"] = "jit cache sizes unavailable on this runtime"
        continue
      if set(warm["prefill_buckets"]) != set(cold["prefill_buckets"]):
        fail(f"prefill-signatures:{sorted(warm['prefill_buckets'])}",
             f"splice path changed the prefill signature set: cold "
             f"{sorted(cold['prefill_buckets'])} vs warm "
             f"{sorted(warm['prefill_buckets'])} — suffix/fork prefill "
             f"escaped the cold path's buckets")
      if warm["step"] != 1:
        fail(f"step-cache:{warm['step']}",
             f"decode step compiled {warm['step']} signatures in the "
             f"cached-splice cycle — splice surgery destabilized the "
             f"donated state shape")
      n_buckets = len(warm["prefill_buckets"])
      if warm["prefill"] != n_buckets:
        fail(f"prefill-cache:{warm['prefill']}/buckets:{n_buckets}",
             f"prefill compiled {warm['prefill']} signatures but only "
             f"{n_buckets} (batch, bucket) shapes were admitted "
             f"({warm['prefill_buckets']}): a spliced suffix escaped "
             f"bucketing")
      for prog in ("replay", "window", "insert", "draft_step0"):
        n = warm.get(prog, 0)
        if n > 1:
          fail(f"{prog}-cache:{n}",
               f"auxiliary program {prog!r} compiled {n} signatures in "
               f"the cached-splice cycle")
  return findings, infos


# ---------------------------------------------------------------------------
# spec_window_stability
# ---------------------------------------------------------------------------

#: speculative-cycle geometry: one k (= one window bucket per engine),
#: a low starting rank, and a deliberately unreachable accept-rate band
#: so the controller is guaranteed to walk the rank mid-serve
_SPEC_K = 2
_SPEC_RANK = 8
_SPEC_BUDGET = 4


def _spec_cycle(cfg, params, policy: str) -> Tuple[dict, int]:
  """One speculative engine through a greedy cycle then a sampled cycle,
  with a rank controller that must walk; returns (stats, rank walks)."""
  rc = RankController(band=(0.99, 1.0), step=32, interval=2,
                      min_rank=_SPEC_RANK, max_rank=_SPEC_RANK + 64)
  eng = LMEngine(cfg, params, batch_size=_BATCH, max_len=_MAX_LEN,
                 kernel_policy=None if policy == "jnp" else policy,
                 speculate=_SPEC_K, draft_rank=_SPEC_RANK,
                 rank_controller=rc)
  rs = np.random.RandomState(0)
  for temperature in (0.0, 0.7):     # verify must share ONE program
    eng.reset()
    for n in _PROMPT_LENS:           # retire + refill, two buckets
      eng.submit(rs.randint(1, _VOCAB, size=(n,)),
                 max_new_tokens=_SPEC_BUDGET)
    done = eng.run(temperature=temperature, rng=jax.random.PRNGKey(1))
    assert len(done) == len(_PROMPT_LENS)
  return eng.compile_stats(), len(eng.rank_history)


def check_spec_window_stability(
    config_names: Iterable[str],
    policies: Iterable[str]) -> Tuple[List[Finding], List[dict]]:
  """The batched verify window must compile exactly ONE signature per
  (bucket, k) engine — measured across a greedy cycle, a sampled cycle,
  retire/refill churn, and at least one controller-driven draft-rank
  walk (which may retrace draft-side programs, but never the verify
  window: `make_draft_params` changes factor shapes only on the draft's
  side of the engine)."""
  findings: List[Finding] = []
  infos: List[dict] = []
  for name in config_names:
    name = normalize_config(name)
    if name not in LIFECYCLE_CONFIGS:
      continue
    cfg = configs.get_smoke(name).with_(vocab_size=_VOCAB)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)

    for policy in policies:
      stats, walks = _spec_cycle(cfg, params, policy)
      info = dict(config=name, policy=policy, quant="-",
                  program="lifecycle", check="spec_window_stability",
                  compile_stats=stats, rank_walks=walks)
      infos.append(info)

      def fail(key: str, detail: str) -> None:
        findings.append(Finding(
            check="spec_window_stability", config=name, policy=policy,
            program="lifecycle", key=key, detail=detail))

      if stats["window"] < 0:
        info["skipped"] = "jit cache sizes unavailable on this runtime"
        continue
      if stats["window"] != 1:
        fail(f"window-cache:{stats['window']}",
             f"the batched verify window compiled {stats['window']} "
             f"signatures across greedy+sampled speculative cycles at "
             f"one (bucket, k={_SPEC_K}) — temperature or a draft-rank "
             f"walk leaked into the verify program's jit signature")
      if walks < 1:
        fail("no-rank-walk",
             f"the rank controller never adjusted the draft rank "
             f"(history empty, rank {_SPEC_RANK}) — the window pin was "
             f"not exercised across a draft rebuild and is vacuous")
  return findings, infos


# ---------------------------------------------------------------------------
# speech_fleet_stability
# ---------------------------------------------------------------------------

#: configs whose family serves through the continuous-batching speech fleet
SPEECH_FLEET_CONFIGS = ("deepspeech2-wsj",)

#: mixed, deliberately non-stride-multiple utterance lengths; 3 utterances
#: through 2 slots force a retire -> refill, and the length spread makes
#: the refill admit mid-decode of the surviving stream (staggered masks)
_UTT_LENS = (23, 9, 17)


def _fleet_cycle(cfg, params, policy: str) -> Tuple[dict, dict]:
  """Serve the fleet scenario; returns (uid -> labels, compile_stats)."""
  srv = StreamingSpeechServer(
      cfg, params, batch_size=_BATCH,
      kernel_policy=None if policy == "jnp" else policy)
  rs = np.random.RandomState(0)
  uids = [srv.submit(rs.randn(t, cfg.feat_dim).astype(np.float32))
          for t in _UTT_LENS]
  results = srv.run(chunk_frames=8)
  assert sorted(r.uid for r in results) == sorted(uids)
  return {r.uid: tuple(r.labels) for r in results}, srv.compile_stats()


def check_speech_fleet_stability(
    config_names: Iterable[str],
    policies: Iterable[str]) -> Tuple[List[Finding], List[dict]]:
  """The speech fleet's masked frame step must compile exactly ONE
  signature across admit/chunk/retire/refill with mixed non-stride-
  multiple utterance lengths, each conv stage exactly one signature per
  pow2 window bucket, and the fleet's labels must match a serial
  batch-1 server decoding each utterance alone (continuous batching is
  a scheduling change, not a numerics change)."""
  findings: List[Finding] = []
  infos: List[dict] = []
  for name in config_names:
    name = normalize_config(name)
    if name not in SPEECH_FLEET_CONFIGS:
      continue
    cfg = configs.get_smoke(name)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)

    for policy in policies:
      labels, stats = _fleet_cycle(cfg, params, policy)
      info = dict(config=name, policy=policy, quant="-",
                  program="lifecycle", check="speech_fleet_stability",
                  compile_stats=stats)
      infos.append(info)

      def fail(key: str, detail: str) -> None:
        findings.append(Finding(
            check="speech_fleet_stability", config=name, policy=policy,
            program="lifecycle", key=key, detail=detail))

      if stats["frame_step"] < 0:
        info["skipped"] = "jit cache sizes unavailable on this runtime"
        continue
      if stats["frame_step"] != 1:
        fail(f"frame-step-cache:{stats['frame_step']}",
             f"the masked speech frame step compiled "
             f"{stats['frame_step']} signatures across an "
             f"admit/retire/refill cycle with mixed utterance lengths — "
             f"the fleet's one-signature contract is broken")
      if stats["insert"] > 1:
        fail(f"insert-cache:{stats['insert']}",
             f"slot-insert surgery compiled {stats['insert']} signatures "
             f"— the slot index leaked into the jit signature")
      for stage in ("conv1", "conv2"):
        n_buckets = len(stats[f"{stage}_buckets"])
        if stats[stage] != n_buckets:
          fail(f"{stage}-cache:{stats[stage]}/buckets:{n_buckets}",
               f"{stage} compiled {stats[stage]} signatures but only "
               f"{n_buckets} window buckets ({stats[f'{stage}_buckets']}) "
               f"were streamed: a conv window shape escaped bucketing")

      # serial oracle: each utterance alone through a batch-1 fleet
      srv1 = StreamingSpeechServer(
          cfg, params, batch_size=1,
          kernel_policy=None if policy == "jnp" else policy)
      rs = np.random.RandomState(0)
      for t in _UTT_LENS:
        srv1.submit(rs.randn(t, cfg.feat_dim).astype(np.float32))
      serial = {r.uid: tuple(r.labels) for r in srv1.run(chunk_frames=8)}
      if labels != serial:
        fail("fleet-serial-divergence",
             f"continuous-batched labels differ from serial per-"
             f"utterance decoding: {labels} vs {serial}")
  return findings, infos
