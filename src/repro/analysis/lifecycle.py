"""Retrace-stability: the engine's jit caches after a real serve cycle.

Unlike the other checks this one must *execute* (tiny, smoke-scale,
batch 2, a handful of tokens): jit cache sizes only exist after calls.
The scenario is chosen to exercise every lifecycle edge that could
silently re-trace:

  * two prompt lengths in different pow2 buckets (admission prefill
    compiles per bucket — that is the contract, counted not flagged),
  * more requests than slots with tiny budgets, forcing retire ->
    refill-from-queue (insert_slot + a second admission prefill), and
  * enough decode steps that any shape drift in the donated state
    signature would show up as step cache > 1.

Invariants, per `LMEngine.compile_stats`:

  step == 1                                  one decode signature, ever
  prefill == len(prefill_buckets)            bucketed, nothing beyond
  replay, window, insert each <= 1           auxiliary programs stable

A -1 from compile_stats means the runtime does not expose jit cache
sizes; the check is skipped (reported in target info), never failed.

Families: the three token-driven LMs (qwen3, zamba2, xlstm). Whisper
decodes against encoder memory the engine does not synthesize and
deepspeech serves frame-synchronously through StreamingServer — neither
runs the engine lifecycle under audit here.
"""
from __future__ import annotations

from typing import Iterable, List, Tuple

import jax
import numpy as np

from repro import configs
from repro.analysis.report import Finding
from repro.analysis.targets import normalize_config
from repro.models.api import get_model
from repro.serving.engine import LMEngine

#: configs whose family runs the full LMEngine lifecycle
LIFECYCLE_CONFIGS = ("qwen3-4b", "zamba2-7b", "xlstm-350m")

_VOCAB = 64
_BATCH = 2
_MAX_LEN = 16
#: lengths 3 and 6 pad into distinct pow2 buckets (4 and 8)
_PROMPT_LENS = (3, 6, 3)
_BUDGET = 3


def _serve_cycle(cfg, params, policy: str) -> dict:
  eng = LMEngine(cfg, params, batch_size=_BATCH, max_len=_MAX_LEN,
                 kernel_policy=None if policy == "jnp" else policy)
  rs = np.random.RandomState(0)
  for n in _PROMPT_LENS:      # 3 requests, 2 slots -> retire + refill
    eng.submit(rs.randint(1, _VOCAB, size=(n,)), max_new_tokens=_BUDGET)
  done = eng.run()
  assert len(done) == len(_PROMPT_LENS)
  return eng.compile_stats()


def check_retrace_stability(
    config_names: Iterable[str],
    policies: Iterable[str]) -> Tuple[List[Finding], List[dict]]:
  """Run the serve cycle for every requested lifecycle-capable config x
  policy; return (findings, per-run info rows)."""
  findings: List[Finding] = []
  infos: List[dict] = []
  for name in config_names:
    name = normalize_config(name)
    if name not in LIFECYCLE_CONFIGS:
      continue
    cfg = configs.get_smoke(name).with_(vocab_size=_VOCAB)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)

    for policy in policies:
      stats = _serve_cycle(cfg, params, policy)
      info = dict(config=name, policy=policy, quant="-",
                  program="lifecycle", compile_stats=stats)
      infos.append(info)

      def fail(key: str, detail: str) -> None:
        findings.append(Finding(
            check="retrace_stability", config=name, policy=policy,
            program="lifecycle", key=key, detail=detail))

      if stats["step"] < 0:
        info["skipped"] = "jit cache sizes unavailable on this runtime"
        continue
      if stats["step"] != 1:
        fail(f"step-cache:{stats['step']}",
             f"decode step compiled {stats['step']} signatures across a "
             f"serve cycle (admit/decode/retire/refill) — the donated "
             f"state shape is not stable")
      n_buckets = len(stats["prefill_buckets"])
      if stats["prefill"] != n_buckets:
        fail(f"prefill-cache:{stats['prefill']}/buckets:{n_buckets}",
             f"prefill compiled {stats['prefill']} signatures but only "
             f"{n_buckets} (batch, bucket) shapes were admitted "
             f"({stats['prefill_buckets']}): a prompt shape escaped "
             f"bucketing")
      for prog in ("replay", "window", "insert", "draft_step0"):
        n = stats.get(prog, 0)
        if n > 1:
          fail(f"{prog}-cache:{n}",
               f"auxiliary program {prog!r} compiled {n} signatures in "
               f"one serve cycle")
  return findings, infos
