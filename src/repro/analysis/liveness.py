"""Static peak live-buffer estimate from a jaxpr: last-use liveness.

The embedded-deployment number the paper cares about is not "how many
bytes do the weights occupy" (the compression ledger answers that) but
"how many bytes must be resident to take one decode step". This pass
computes a static estimate straight from the traced jaxpr, no
execution:

* every program input (params + state + tokens) is resident for the
  whole program — `input_bytes`;
* equation outputs are allocated in program order and freed after their
  last use (jaxpr outvars are never freed — they outlive the program);
* control-flow bodies (scan/while/pjit/cond/remat, anything
  `jaxpr_walk._sub_jaxprs` yields) contribute their own transient peak
  *on top of* the buffers live at their call site — one iteration's
  worth, since carries reuse buffers across iterations while stacked
  scan outputs are allocated by the outer equation's outvars;
* donated state leaves are credited: an output that aliases a donated
  input (greedy shape+dtype match, the same contract
  `checks._donation_findings` verifies against the lowered StableHLO)
  writes into the input's buffer and allocates nothing.

The result is an *estimate* — XLA's buffer assignment can fuse away
intermediates we count and materialize copies we don't — but it is
deterministic, cheap, and moves with the program structure, which is
exactly what a budget gate needs.
"""
from __future__ import annotations

import dataclasses

from jax import core

from repro.analysis.jaxpr_walk import _sub_jaxprs


def _aval_bytes(aval) -> int:
  """Whole-byte size of one abstract value (int4 packs 2/byte)."""
  shape = getattr(aval, "shape", None)
  dtype = getattr(aval, "dtype", None)
  if shape is None or dtype is None:
    return 0
  n = 1
  for d in shape:
    n *= int(d)
  itembits = dtype.itemsize * 8
  if "int4" in dtype.name:
    itembits = 4
  return (n * itembits + 7) // 8


def _var_bytes(v) -> int:
  return _aval_bytes(getattr(v, "aval", None))


def _transient_peak(jaxpr: core.Jaxpr, credited: frozenset) -> int:
  """Peak bytes of eqn-allocated buffers, relative to the frame's inputs.

  Frame invars/constvars are the caller's problem (already resident
  there); `credited` vars allocate zero bytes (donation aliasing)."""
  never_free = set()
  for v in jaxpr.outvars:
    if isinstance(v, core.Var):
      never_free.add(v)
  last_use: dict = {}
  for i, eqn in enumerate(jaxpr.eqns):
    for a in eqn.invars:
      if isinstance(a, core.Var):
        last_use[a] = i
  frees_at: list = [[] for _ in jaxpr.eqns]
  for v, i in last_use.items():
    if v not in never_free:
      frees_at[i].append(v)

  live = 0
  peak = 0
  owned: dict = {}                 # var -> bytes this frame allocated
  for i, eqn in enumerate(jaxpr.eqns):
    inner = 0
    for sub, _ in _sub_jaxprs(eqn):
      inner = max(inner, _transient_peak(sub, credited))
    peak = max(peak, live + inner)
    for v in eqn.outvars:
      if isinstance(v, core.DropVar):
        continue
      b = 0 if v in credited else _var_bytes(v)
      owned[v] = b
      live += b
    peak = max(peak, live)
    for v in frees_at[i]:
      if v in owned:
        live -= owned.pop(v)
    # outputs never read again (and not program outputs) die immediately
    for v in eqn.outvars:
      if (v in owned and v not in last_use and v not in never_free
          and not isinstance(v, core.DropVar)):
        live -= owned.pop(v)
  return peak


@dataclasses.dataclass(frozen=True)
class LivenessReport:
  input_bytes: int                 # all program inputs, resident throughout
  donated_bytes: int               # inputs whose buffers outputs may reuse
  credited_bytes: int              # output bytes matched to donated inputs
  output_bytes: int                # program outputs (state', logits, ...)
  transient_bytes: int             # peak eqn-allocated bytes (post credit)
  peak_bytes: int                  # input_bytes + transient_bytes


def analyze_jaxpr(closed: core.ClosedJaxpr, *, n_params: int = 0,
                  n_donated: int = 0) -> LivenessReport:
  """Liveness for one traced program.

  `n_params`/`n_donated` follow the TraceTarget invar layout: flattened
  invars are params (n_params), then the donated state tree (n_donated),
  then the remaining inputs."""
  jaxpr = closed.jaxpr
  input_bytes = sum(_var_bytes(v) for v in jaxpr.invars)
  input_bytes += sum(_var_bytes(v) for v in jaxpr.constvars)

  donated = list(jaxpr.invars[n_params:n_params + n_donated])
  donated_bytes = sum(_var_bytes(v) for v in donated)

  # greedy donation credit: each donated input buffer can absorb one
  # output of identical shape+dtype
  pool: dict = {}
  for v in donated:
    aval = getattr(v, "aval", None)
    key = (tuple(getattr(aval, "shape", ())), str(getattr(aval, "dtype", "")))
    pool[key] = pool.get(key, 0) + 1
  credited = set()
  credited_bytes = 0
  for v in jaxpr.outvars:
    if not isinstance(v, core.Var) or v in credited:
      continue
    aval = getattr(v, "aval", None)
    key = (tuple(getattr(aval, "shape", ())), str(getattr(aval, "dtype", "")))
    if pool.get(key, 0) > 0:
      pool[key] -= 1
      credited.add(v)
      credited_bytes += _var_bytes(v)

  output_bytes = sum(_var_bytes(v) for v in jaxpr.outvars
                     if isinstance(v, core.Var))
  transient = _transient_peak(jaxpr, frozenset(credited))
  return LivenessReport(
      input_bytes=input_bytes,
      donated_bytes=donated_bytes,
      credited_bytes=credited_bytes,
      output_bytes=output_bytes,
      transient_bytes=transient,
      peak_bytes=input_bytes + transient)
