"""Recursive jaxpr walking: name-stack resolution + operand provenance.

The two jaxpr-level checks both need the same walk:

* **dispatch coverage** needs, for every `dot_general`, (a) the full
  name stack — including the `dispatch:{regime}:c{id}` scope
  `kernels.dispatch.gemm` wraps routed GEMMs in — and (b) whether either
  operand is derived from a *parameter* leaf. A dot with a param operand
  and no dispatch scope is a GEMM that bypassed the dispatcher.

* **quantization integrity** needs to know when a value derived from an
  int8 parameter leaf is `convert_element_type`'d to a floating dtype —
  a dequantize, the exact op PTQ exists to eliminate. Integer widening
  (int8 -> int32 accumulation inside the w8a8 oracle) is legitimate and
  tracked through.

Provenance is propagated conservatively, through *unary* structural ops
only (TRANSPARENT below): a bias-add or norm-scale involving a param
does NOT taint its activation output, so attention's activation x cache
contractions stay clean. Sub-jaxprs (scan/pjit/cond/while/custom_*) are
descended with their invars mapped to the enclosing equation's operands;
`pallas_call` is deliberately NOT descended — the kernel body belongs to
the dispatch scope its call site carries.

Name stacks inside a sub-jaxpr usually already carry the enclosing
scopes (same-trace lowering), but a *cached* inner jaxpr (a module-level
jit hit from an earlier trace) keeps its stale stacks. The walk
therefore threads the enclosing equation's resolved stack down as a
prefix, and joins it only when the inner stack does not already contain
it — so a dot inside a reused pjit still resolves to the CURRENT
dispatch scope first. Correlation parsers must accordingly take the
FIRST dispatch scope in a stack, never the last.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax.numpy as jnp
from jax import core

#: unary structural ops provenance flows through (first operand only)
TRANSPARENT = frozenset({
    "convert_element_type", "reshape", "transpose", "broadcast_in_dim",
    "squeeze", "expand_dims", "slice", "dynamic_slice", "rev", "copy",
    "reduce_precision", "stop_gradient",
})

#: primitives that imply a host round-trip / transfer inside the program
HOST_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "device_put", "copy_to_host_async",
})

DOT_PRIMS = frozenset({"dot_general"})

#: first dispatch correlation scope in a name stack (see module docstring)
DISPATCH_SCOPE_RE = re.compile(r"dispatch:([a-z0-9_]+):c(\d+)")

_NOFLAG = (False, False)         # (param_derived, int8_param_derived)


@dataclasses.dataclass(frozen=True)
class DotSite:
  """One dot_general: where it is and what feeds it."""
  name_stack: str
  shapes: tuple                  # ((lhs...), (rhs...))
  param_operands: tuple          # (lhs_from_param, rhs_from_param)

  def dispatch_scope(self) -> Optional[tuple]:
    """(regime, call_id) of the first dispatch scope, or None."""
    m = DISPATCH_SCOPE_RE.search(self.name_stack)
    return (m.group(1), int(m.group(2))) if m else None


@dataclasses.dataclass(frozen=True)
class ConvertSite:
  """An int8-param-derived value converted to a floating dtype."""
  name_stack: str
  shape: tuple
  dst_dtype: str


@dataclasses.dataclass(frozen=True)
class PrimSite:
  """A host/transfer primitive occurrence."""
  name_stack: str
  prim: str


@dataclasses.dataclass
class WalkResult:
  dots: list = dataclasses.field(default_factory=list)
  int8_converts: list = dataclasses.field(default_factory=list)
  host_prims: list = dataclasses.field(default_factory=list)
  n_eqns: int = 0


def _as_jaxpr(x):
  return x.jaxpr if isinstance(x, core.ClosedJaxpr) else x


def _sub_jaxprs(eqn) -> list:
  """[(inner Jaxpr, operand list aligned with its invars)] for one eqn.

  A None operand means "untracked" (conservative: inner values derived
  from it carry no provenance)."""
  prim = eqn.primitive.name
  if prim == "pallas_call":
    return []
  if prim == "while":
    cn = eqn.params["cond_nconsts"]
    bn = eqn.params["body_nconsts"]
    carry = list(eqn.invars[cn + bn:])
    return [
        (_as_jaxpr(eqn.params["cond_jaxpr"]),
         list(eqn.invars[:cn]) + carry),
        (_as_jaxpr(eqn.params["body_jaxpr"]),
         list(eqn.invars[cn:cn + bn]) + carry),
    ]
  if prim == "cond":
    ops = list(eqn.invars[1:])
    return [(_as_jaxpr(b), ops) for b in eqn.params.get("branches", ())]
  out = []
  for val in eqn.params.values():
    for v in (val if isinstance(val, (tuple, list)) else (val,)):
      if isinstance(v, (core.ClosedJaxpr, core.Jaxpr)):
        j = _as_jaxpr(v)
        if len(j.invars) == len(eqn.invars):
          # pjit / scan / remat / custom_* all align invars positionally
          out.append((j, list(eqn.invars)))
        else:
          out.append((j, [None] * len(j.invars)))
  return out


def walk(closed: core.ClosedJaxpr, n_params: int,
         int8_param_idx: frozenset = frozenset()) -> WalkResult:
  """Walk `closed` (and every reachable sub-jaxpr), tracking provenance
  from the first `n_params` flattened invars (the params argument) and,
  within those, the `int8_param_idx` positions (int8 weight leaves)."""
  res = WalkResult()

  def visit(jaxpr: core.Jaxpr, in_flags, prefix: str) -> None:
    env = {}
    for v, fl in zip(jaxpr.invars, in_flags):
      if fl != _NOFLAG and not isinstance(v, core.Literal):
        env[v] = fl

    def flag(atom):
      if isinstance(atom, core.Literal):
        return _NOFLAG
      return env.get(atom, _NOFLAG)

    for eqn in jaxpr.eqns:
      res.n_eqns += 1
      prim = eqn.primitive.name
      ns = str(eqn.source_info.name_stack)
      if prefix and prefix not in ns:
        full = f"{prefix}/{ns}" if ns else prefix
      else:
        full = ns
      if prim in DOT_PRIMS:
        ops = eqn.invars[:2]
        res.dots.append(DotSite(
            name_stack=full,
            shapes=tuple(tuple(getattr(a.aval, "shape", ()))
                         for a in ops),
            param_operands=tuple(flag(a)[0] for a in ops)))
      elif prim in HOST_PRIMS:
        res.host_prims.append(PrimSite(name_stack=full, prim=prim))
      if prim == "convert_element_type":
        src = flag(eqn.invars[0])
        if src != _NOFLAG:
          dst = eqn.params.get("new_dtype")
          if dst is not None and jnp.issubdtype(dst, jnp.floating) \
              and src[1]:
            res.int8_converts.append(ConvertSite(
                name_stack=full,
                shape=tuple(eqn.invars[0].aval.shape),
                dst_dtype=str(jnp.dtype(dst))))
            src = (src[0], False)    # dequantized: no longer int8-derived
          env[eqn.outvars[0]] = src
      elif prim in TRANSPARENT:
        src = flag(eqn.invars[0]) if eqn.invars else _NOFLAG
        if src != _NOFLAG and len(eqn.outvars) == 1:
          env[eqn.outvars[0]] = src
      for sub, operands in _sub_jaxprs(eqn):
        sub_flags = [_NOFLAG if a is None else flag(a) for a in operands]
        visit(sub, sub_flags, full)

  in_flags = [(i < n_params, i in int8_param_idx)
              for i in range(len(closed.jaxpr.invars))]
  visit(closed.jaxpr, in_flags, "")
  return res


def check_param_alignment(closed: core.ClosedJaxpr, flat_params) -> None:
  """Assert the first len(flat_params) invars ARE the params leaves (the
  positional assumption `walk` rests on). Raises on drift."""
  invars = closed.jaxpr.invars
  if len(invars) < len(flat_params):
    raise AssertionError(
        f"jaxpr has {len(invars)} invars < {len(flat_params)} param leaves")
  for i, leaf in enumerate(flat_params):
    aval = invars[i].aval
    if tuple(aval.shape) != tuple(leaf.shape):
      raise AssertionError(
          f"invar {i} shape {tuple(aval.shape)} != param leaf shape "
          f"{tuple(leaf.shape)}: params are not the leading invars")
