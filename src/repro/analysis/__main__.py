"""CLI: `python -m repro.analysis audit [...]`.

Exit status is the CI contract: 0 when every finding is allowlisted in
the baseline, 1 when any NEW finding appears (a hot-path regression).

  # fast CI gate (two families, both kernel policies)
  python -m repro.analysis audit --configs qwen3_4b,zamba2_7b

  # full grid + report artifact
  python -m repro.analysis audit --report audit.json

  # accept current findings as known debt (then review + commit)
  python -m repro.analysis audit --write-baseline
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import (DEFAULT_CONFIGS, POLICIES, PROGRAMS, QUANTS,
                            load_baseline, run_audit, write_baseline)
from repro.analysis.report import default_baseline_path


def _csv(text: str) -> list:
  return [t.strip() for t in text.split(",") if t.strip()]


def main(argv=None) -> int:
  parser = argparse.ArgumentParser(prog="python -m repro.analysis")
  sub = parser.add_subparsers(dest="cmd", required=True)
  audit = sub.add_parser("audit", help="trace + check the serving grid")
  audit.add_argument("--configs", type=_csv,
                     default=list(DEFAULT_CONFIGS),
                     help="comma list (underscores ok): qwen3_4b,...")
  audit.add_argument("--policies", type=_csv, default=list(POLICIES))
  audit.add_argument("--quants", type=_csv, default=list(QUANTS))
  audit.add_argument("--programs", type=_csv, default=list(PROGRAMS))
  audit.add_argument("--baseline", default=None,
                     help=f"allowlist path (default: "
                          f"{default_baseline_path()})")
  audit.add_argument("--report", default=None,
                     help="write the full JSON report here")
  audit.add_argument("--write-baseline", action="store_true",
                     help="accept all current findings as known debt")
  audit.add_argument("--deep", action="store_true",
                     help="lower+compile window/prefill/train too")
  audit.add_argument("--no-lifecycle", action="store_true",
                     help="skip the (executing) retrace-stability check")
  audit.add_argument("--no-sharding", action="store_true",
                     help="skip production-scale sharding coverage")
  args = parser.parse_args(argv)

  report = run_audit(args.configs, args.policies, args.quants,
                     args.programs, deep=args.deep,
                     run_lifecycle=not args.no_lifecycle,
                     run_sharding=not args.no_sharding)
  if args.write_baseline:
    path = args.baseline or default_baseline_path()
    base = write_baseline(report, path)
    print(f"wrote {len(base['allow'])} allowlist entries to {path}")
    return 0
  report.apply_baseline(load_baseline(args.baseline))
  if args.report:
    report.save(args.report)
  print(report.summary())
  return 0 if report.ok else 1


if __name__ == "__main__":
  sys.exit(main())
