"""CLI: `python -m repro.analysis {audit,budgets} [...]`.

Exit status is the CI contract: 0 when every finding is allowlisted in
the baseline, 1 when any NEW finding appears (a hot-path regression).

  # fast CI gate (two families, both kernel policies)
  python -m repro.analysis audit --configs qwen3_4b,zamba2_7b

  # full grid + report artifact
  python -m repro.analysis audit --report audit.json

  # accept current findings as known debt (then review + commit)
  python -m repro.analysis audit --write-baseline

  # cost/memory/compression ledgers vs committed budgets.json
  python -m repro.analysis budgets --configs qwen3_4b,zamba2_7b

  # refresh the committed numbers after an intentional change
  python -m repro.analysis budgets --update
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import (DEFAULT_CONFIGS, POLICIES, PROGRAMS, QUANTS,
                            load_baseline, run_audit, write_baseline)
from repro.analysis import budgets as budgets_mod
from repro.analysis.report import default_baseline_path
from repro.analysis.targets import iter_targets, normalize_config


def _csv(text: str) -> list:
  return [t.strip() for t in text.split(",") if t.strip()]


def main(argv=None) -> int:
  parser = argparse.ArgumentParser(prog="python -m repro.analysis")
  sub = parser.add_subparsers(dest="cmd", required=True)
  audit = sub.add_parser("audit", help="trace + check the serving grid")
  audit.add_argument("--configs", type=_csv,
                     default=list(DEFAULT_CONFIGS),
                     help="comma list (underscores ok): qwen3_4b,...")
  audit.add_argument("--policies", type=_csv, default=list(POLICIES))
  audit.add_argument("--quants", type=_csv, default=list(QUANTS))
  audit.add_argument("--programs", type=_csv, default=list(PROGRAMS))
  audit.add_argument("--baseline", default=None,
                     help=f"allowlist path (default: "
                          f"{default_baseline_path()})")
  audit.add_argument("--report", default=None,
                     help="write the full JSON report here")
  audit.add_argument("--write-baseline", action="store_true",
                     help="accept all current findings as known debt")
  audit.add_argument("--deep", action="store_true",
                     help="lower+compile window/prefill/train too")
  audit.add_argument("--no-lifecycle", action="store_true",
                     help="skip the (executing) retrace-stability check")
  audit.add_argument("--no-sharding", action="store_true",
                     help="skip production-scale sharding coverage")
  audit.add_argument("--no-budgets", action="store_true",
                     help="skip the cost/memory/compression budget gates")

  budgets = sub.add_parser(
      "budgets", help="cost/memory/compression ledgers vs budgets.json")
  budgets.add_argument("--configs", type=_csv,
                       default=list(DEFAULT_CONFIGS))
  budgets.add_argument("--policies", type=_csv, default=list(POLICIES))
  budgets.add_argument("--quants", type=_csv, default=list(QUANTS))
  budgets.add_argument("--programs", type=_csv, default=list(PROGRAMS))
  budgets.add_argument("--budgets", default=None,
                       help=f"committed numbers (default: "
                            f"{budgets_mod.default_budgets_path()})")
  budgets.add_argument("--report", default=None,
                       help="write ledgers + findings JSON here")
  budgets.add_argument("--update", action="store_true",
                       help="merge measured numbers into budgets.json")
  budgets.add_argument("--shallow", action="store_true",
                       help="skip lowering/compiling window/prefill/train "
                            "(their cost ledgers are then not refreshed)")
  args = parser.parse_args(argv)

  if args.cmd == "budgets":
    return _budgets_main(args)

  report = run_audit(args.configs, args.policies, args.quants,
                     args.programs, deep=args.deep,
                     run_lifecycle=not args.no_lifecycle,
                     run_sharding=not args.no_sharding,
                     run_budgets=not args.no_budgets)
  if args.write_baseline:
    path = args.baseline or default_baseline_path()
    base = write_baseline(report, path)
    print(f"wrote {len(base['allow'])} allowlist entries to {path}")
    return 0
  report.apply_baseline(load_baseline(args.baseline))
  if args.report:
    report.save(args.report)
  print(report.summary())
  for w in report.meta.get("budget_ratchet_stale", ()):
    print(f"  RATCHET {w['coord']} {w['metric']}: "
          f"{w['committed']} -> {w['current']} ({w['rel']:+.1%})")
  return 0 if report.ok else 1


def _budgets_main(args) -> int:
  committed = budgets_mod.load_budgets(args.budgets)
  audit = budgets_mod.BudgetAudit(committed)
  configs = [normalize_config(n) for n in args.configs]
  for target in iter_targets(configs, args.policies, args.quants,
                             args.programs, deep=not args.shallow):
    audit.add_target(target)
  for name in configs:
    audit.add_compression(name)

  if args.update:
    merged = budgets_mod.merge_budgets(committed, audit.fresh())
    budgets_mod.write_budgets(merged, args.budgets)
    path = args.budgets or budgets_mod.default_budgets_path()
    print(f"budgets: wrote {len(audit.programs)} program ledgers and "
          f"{len(audit.compression)} compression ledgers to {path}")
    return 0

  result = dict(audit.fresh(),
                findings=[f.to_dict() for f in audit.findings],
                ratchet_stale=audit.warnings,
                ok=not audit.findings)
  if args.report:
    with open(args.report, "w") as f:
      json.dump(result, f, indent=1, sort_keys=True)
      f.write("\n")
  print(f"budgets: {len(audit.programs)} programs, "
        f"{len(audit.compression)} compression ledgers, "
        f"{len(audit.findings)} findings, "
        f"{len(audit.warnings)} ratchet-stale")
  for f in audit.findings:
    print(f"  RED     {f.ident}\n          {f.detail}")
  for w in audit.warnings:
    print(f"  RATCHET {w['coord']} {w['metric']}: "
          f"{w['committed']} -> {w['current']} ({w['rel']:+.1%})")
  return 0 if not audit.findings else 1


if __name__ == "__main__":
  sys.exit(main())
