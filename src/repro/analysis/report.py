"""Structured audit findings, the report envelope, and baseline diffing.

A Finding is one invariant violation at one audited coordinate
(config, policy, quant, program, check) plus a stable `key` naming the
violation site. Stability matters because the committed baseline
(`analysis/baseline.json`) allowlists findings by their full `ident`
string: a known debt stays visible in every report but does not fail
CI, while any ident NOT in the baseline is a regression and the audit
exits non-zero. Keys therefore never embed trace-varying material —
dispatch call ids (`c<N>`) are masked to `c*` by `stable_key` before a
key is formed.

Baseline workflow:
  python -m repro.analysis audit --write-baseline   # accept current debts
  # review the diff of analysis/baseline.json, commit it with a reason
  python -m repro.analysis audit                    # green on the baseline
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Iterable, Optional

#: the check registry — every Finding.check is one of these
CHECKS = (
    "dispatch_coverage",   # every decode dot_general attributable to a regime
    "quant_integrity",     # no int8 weight dequantized in a PTQ'd trace
    "retrace_stability",   # engine lifecycle compiles each signature once
    "prefix_splice_stability",  # cached-splice serving: same prefill
                                # signatures as cold + token parity
    "spec_window_stability",    # batched speculative verify: one jit
                                # signature per (bucket, k), greedy and
                                # sampled, across draft-rank walks
    "speech_fleet_stability",   # continuous-batching speech fleet: one
                                # masked frame-step signature across
                                # admit/retire/refill, bucketed conv
                                # windows, fleet == serial labels
    "transfer_lint",       # no host callbacks/transfers; donation holds;
                           # HLO parser gaps (unknown ops) surfaced
    "sharding_coverage",   # every param leaf resolves to a sharding rule
    "cost_budget",         # HLO cost ledger within its committed band
    "memory_budget",       # jaxpr liveness peak within its committed band
    "compression_ledger",  # static param count/bytes exactly as committed,
                           # compressed trees strictly smaller
)

_CALL_ID_RE = re.compile(r":c\d+")


def stable_key(text: str) -> str:
  """Mask trace-varying dispatch call ids so keys survive re-tracing."""
  return _CALL_ID_RE.sub(":c*", text)


@dataclasses.dataclass(frozen=True)
class Finding:
  """One invariant violation at one audited coordinate."""
  check: str
  config: str
  key: str                  # stable violation-site id (see stable_key)
  detail: str = ""          # human explanation; NOT part of the ident
  policy: str = "-"         # "jnp" | "pallas" | "-" (policy-independent)
  quant: str = "-"          # "float" | "int8" | "-"
  program: str = "-"        # "decode" | "window" | "prefill" | "train" |
                            # "lifecycle" | "params"

  def __post_init__(self):
    if self.check not in CHECKS:
      raise ValueError(f"unknown check {self.check!r} (not in CHECKS)")

  @property
  def ident(self) -> str:
    return "|".join((self.config, self.policy, self.quant, self.program,
                     self.check, self.key))

  def to_dict(self) -> dict:
    d = dataclasses.asdict(self)
    d["ident"] = self.ident
    return d


@dataclasses.dataclass
class AuditReport:
  """Everything one audit run produced: per-target metadata, findings,
  and (after `apply_baseline`) the regression/allowed/stale partition."""
  findings: list = dataclasses.field(default_factory=list)
  targets: list = dataclasses.field(default_factory=list)
  meta: dict = dataclasses.field(default_factory=dict)
  new: list = dataclasses.field(default_factory=list)      # Finding
  allowed: list = dataclasses.field(default_factory=list)  # Finding
  stale: list = dataclasses.field(default_factory=list)    # ident str

  def extend(self, findings: Iterable[Finding]) -> None:
    self.findings.extend(findings)

  def apply_baseline(self, baseline: dict) -> None:
    """Partition findings into regressions vs. allowlisted debts, and
    report baseline entries the audit no longer reproduces (stale).
    Staleness only applies within the configs this run audited: a
    scoped run (e.g. the 2-config CI gate) says nothing about the
    rest of the allowlist."""
    allow = {e["ident"] for e in baseline.get("allow", ())}
    seen = {f.ident for f in self.findings}
    self.new = [f for f in self.findings if f.ident not in allow]
    self.allowed = [f for f in self.findings if f.ident in allow]
    audited = {
        "|".join((t["config"], t["policy"], t["quant"], t["program"]))
        for t in self.targets if "program" in t}
    self.stale = sorted(
        i for i in allow - seen
        if not audited or "|".join(i.split("|")[:4]) in audited)

  @property
  def ok(self) -> bool:
    return not self.new

  def to_dict(self) -> dict:
    return {
        "meta": self.meta,
        "targets": self.targets,
        "findings": [f.to_dict() for f in self.findings],
        "new": [f.ident for f in self.new],
        "allowed": [f.ident for f in self.allowed],
        "stale_baseline_entries": list(self.stale),
        "ok": self.ok,
    }

  def save(self, path: str) -> None:
    with open(path, "w") as f:
      json.dump(self.to_dict(), f, indent=2, sort_keys=True)
      f.write("\n")

  def summary(self) -> str:
    lines = [
        f"audit: {len(self.targets)} targets, {len(self.findings)} "
        f"findings ({len(self.allowed)} allowlisted, {len(self.new)} new)"
    ]
    for f in self.new:
      lines.append(f"  NEW     {f.ident}\n          {f.detail}")
    for f in self.allowed:
      lines.append(f"  allowed {f.ident}")
    for ident in self.stale:
      lines.append(f"  stale   {ident}  (baseline entry no longer seen)")
    return "\n".join(lines)


def default_baseline_path() -> str:
  return os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: Optional[str] = None) -> dict:
  """Load the allowlist; a missing file is an empty baseline (everything
  found is then a regression — the bootstrap state)."""
  path = default_baseline_path() if path is None else path
  if not os.path.exists(path):
    return {"allow": []}
  with open(path) as f:
    base = json.load(f)
  if not isinstance(base.get("allow"), list):
    raise ValueError(f"baseline {path}: expected an 'allow' list")
  for entry in base["allow"]:
    if "ident" not in entry:
      raise ValueError(f"baseline {path}: allow entry missing 'ident'")
  return base


def write_baseline(report: AuditReport, path: Optional[str] = None) -> dict:
  """Accept every current finding as a known debt. Reasons start as the
  finding detail — edit them into real justifications before committing."""
  path = default_baseline_path() if path is None else path
  # one entry per ident: a site can recur within a trace (e.g. the
  # prefill scan body + its final step hit the same unrouted dot)
  by_ident = {}
  for f in sorted(report.findings, key=lambda f: f.ident):
    by_ident.setdefault(f.ident, f.detail)
  base = {
      "note": ("Known-debt allowlist for `python -m repro.analysis audit`."
               " Each entry names one finding ident that is understood and"
               " accepted; remove entries as debts are fixed (stale ones"
               " are reported). New findings NOT listed here fail CI."),
      "allow": [{"ident": k, "reason": v} for k, v in by_ident.items()],
  }
  with open(path, "w") as f:
    json.dump(base, f, indent=2, sort_keys=True)
    f.write("\n")
  return base
