"""Static cost & memory budgets: per-program ledgers diffed against
committed numbers (`analysis/budgets.json`) with tolerance bands.

For every traced program the auditor builds two ledgers:

* a **cost ledger** from the optimized HLO (`dist.hlo_cost`): FLOPs,
  dot FLOPs, HBM bytes, collective payload/wire bytes, arithmetic
  intensity, and the roofline-dominant term — available whenever the
  target was compiled (decode always; window/prefill/train under
  `--deep`);
* a **memory ledger** from the jaxpr (`analysis.liveness`): static peak
  live-buffer bytes with donation credit — available for every target.

Each gated metric diffs against the committed number with a per-metric
relative tolerance band:

  regression beyond the band   -> a `cost_budget` / `memory_budget`
                                  Finding (red; CI fails)
  improvement beyond the band  -> a "ratchet stale" WARNING: the code
                                  got cheaper and the committed number
                                  no longer pins it — run
                                  `python -m repro.analysis budgets
                                  --update` and commit the new floor
  missing committed entry      -> an `unbudgeted` Finding (the grid
                                  grew; --update to admit it)

The **compression ledger** (`analysis.compression`) is gated exactly,
tolerance 0: parameter counts and bytes are shape arithmetic, any drift
is a real model-size change. Its strictness assertions ("the compressed
tree is strictly smaller, whole-tree and per-device") are
`compression_ledger` findings independent of the committed numbers.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, Optional

import jax

from repro.analysis import compression, liveness
from repro.analysis.report import Finding
from repro.analysis.targets import TraceTarget
from repro.dist import hlo_cost

#: relative tolerance band per gated metric; direction: higher is worse
TOLERANCES = {
    # cost_budget (optimized HLO)
    "flops": 0.05,
    "dot_flops": 0.05,
    "hbm_bytes": 0.10,
    "collective_bytes": 0.10,
    "collective_wire_bytes": 0.10,
    # memory_budget (jaxpr liveness)
    "input_bytes": 0.0,
    "peak_live_bytes": 0.05,
}

#: which check each gated metric reports under
CHECK_OF = {
    "flops": "cost_budget",
    "dot_flops": "cost_budget",
    "hbm_bytes": "cost_budget",
    "collective_bytes": "cost_budget",
    "collective_wire_bytes": "cost_budget",
    "input_bytes": "memory_budget",
    "peak_live_bytes": "memory_budget",
}

#: exact-gated compression metrics per variant
COMPRESSION_METRICS = ("param_count", "param_bytes", "device_bytes")


def default_budgets_path() -> str:
  return os.path.join(os.path.dirname(__file__), "budgets.json")


def load_budgets(path: Optional[str] = None) -> dict:
  """Committed budgets; a missing file is empty (every coordinate is
  then `unbudgeted` — the bootstrap state before the first --update)."""
  path = default_budgets_path() if path is None else path
  if not os.path.exists(path):
    return {"meta": {}, "programs": {}, "compression": {}}
  with open(path) as f:
    data = json.load(f)
  for section in ("programs", "compression"):
    if not isinstance(data.get(section), dict):
      raise ValueError(f"budgets {path}: expected a {section!r} dict")
  return data


def write_budgets(data: dict, path: Optional[str] = None) -> None:
  path = default_budgets_path() if path is None else path
  with open(path, "w") as f:
    json.dump(data, f, indent=1, sort_keys=True)
    f.write("\n")


def merge_budgets(committed: dict, fresh: dict) -> dict:
  """--update semantics: refresh what this run measured, keep the rest.

  Per-coordinate entries merge field-wise, so a shallow run (no cost
  ledger for window/prefill/train) updates the memory metrics it did
  compute without dropping the committed cost metrics."""
  out = {
      "meta": dict(committed.get("meta", {})),
      "programs": {k: dict(v)
                   for k, v in committed.get("programs", {}).items()},
      "compression": {k: v
                      for k, v in committed.get("compression", {}).items()},
  }
  out["meta"].update(fresh.get("meta", {}))
  for k, v in fresh.get("programs", {}).items():
    out["programs"][k] = {**out["programs"].get(k, {}), **v}
  out["compression"].update(fresh.get("compression", {}))
  return out


def coord_key(coord: dict) -> str:
  return "|".join((coord["config"], coord["policy"], coord["quant"],
                   coord["program"]))


# ---------------------------------------------------------------------------
# Ledger construction.
# ---------------------------------------------------------------------------

def program_ledger(target: TraceTarget) -> dict:
  """Cost + memory ledger for one traced program.

  Memory metrics always; cost metrics only when the target carries
  optimized HLO (compiled_text)."""
  live = liveness.analyze_jaxpr(target.jaxpr, n_params=target.n_params,
                                n_donated=target.n_donated)
  ledger = dict(
      input_bytes=live.input_bytes,
      donated_bytes=live.donated_bytes,
      credited_bytes=live.credited_bytes,
      output_bytes=live.output_bytes,
      transient_bytes=live.transient_bytes,
      peak_live_bytes=live.peak_bytes,
  )
  if target.compiled_text is not None:
    rep = hlo_cost.analyze_module(target.compiled_text)
    roof = hlo_cost.roofline_from_report(rep)
    ledger.update(
        flops=rep.flops,
        dot_flops=rep.dot_flops,
        hbm_bytes=rep.hbm_bytes,
        collective_bytes=rep.collective_bytes,
        collective_wire_bytes=rep.collective_wire_bytes,
        n_collectives=rep.n_collectives,
        arithmetic_intensity=round(rep.flops / rep.hbm_bytes, 4)
        if rep.hbm_bytes else 0.0,
        dominant=roof.dominant,
        roofline_fraction=round(roof.roofline_fraction, 4),
    )
  return ledger


# ---------------------------------------------------------------------------
# Diffing.
# ---------------------------------------------------------------------------

def _bf(coord: dict, check: str, key: str, detail: str) -> Finding:
  return Finding(check=check, config=coord["config"], key=key,
                 detail=detail, policy=coord["policy"],
                 quant=coord["quant"], program=coord["program"])


def diff_program(coord: dict, ledger: dict, committed_programs: dict
                 ) -> tuple:
  """(findings, ratchet_warnings) for one program vs its committed entry."""
  key = coord_key(coord)
  committed = committed_programs.get(key)
  findings: list = []
  warnings: list = []
  if committed is None:
    checks_hit = sorted({CHECK_OF[m] for m in TOLERANCES if m in ledger})
    for check in checks_hit:
      findings.append(_bf(
          coord, check, "unbudgeted",
          f"no committed budget entry for {key!r}: run "
          f"`python -m repro.analysis budgets --update` and commit "
          f"budgets.json"))
    return findings, warnings

  for metric, tol in TOLERANCES.items():
    if metric not in ledger or metric not in committed:
      continue
    old = float(committed[metric])
    new = float(ledger[metric])
    if old == new:
      continue
    if old == 0.0:
      rel = float("inf") if new > 0 else float("-inf")
    else:
      rel = (new - old) / old
    if rel > tol:
      findings.append(_bf(
          coord, CHECK_OF[metric], f"over-budget:{metric}",
          f"{metric}: committed {committed[metric]}, now {ledger[metric]} "
          f"({rel:+.1%}, band ±{tol:.0%}) — a static "
          f"{'cost' if CHECK_OF[metric] == 'cost_budget' else 'memory'} "
          f"regression; if intentional, refresh with "
          f"`python -m repro.analysis budgets --update`"))
    elif rel < -tol:
      warnings.append(dict(
          coord=key, metric=metric, committed=committed[metric],
          current=ledger[metric], rel=round(rel, 4),
          note="ratchet stale: improvement beyond the band — run "
               "`python -m repro.analysis budgets --update` to pin it"))

  if "dominant" in ledger and "dominant" in committed \
      and ledger["dominant"] != committed["dominant"]:
    findings.append(_bf(
        coord, "cost_budget",
        f"dominant-flip:{committed['dominant']}->{ledger['dominant']}",
        f"roofline-dominant term flipped from {committed['dominant']!r} "
        f"to {ledger['dominant']!r}: the program's performance regime "
        f"changed — inspect, then --update if intentional"))
  return findings, warnings


def diff_compression(config: str, ledger: dict, committed_compression: dict
                     ) -> list:
  """Findings for one config's compression ledger: strictness violations
  plus exact drift against the committed numbers."""
  coord = dict(config=config, policy="-", quant="-", program="params")
  findings = [
      _bf(coord, "compression_ledger", key, detail)
      for key, detail in compression.strictness_violations(ledger)
  ]
  committed = committed_compression.get(config)
  if committed is None:
    findings.append(_bf(
        coord, "compression_ledger", "unbudgeted",
        f"no committed compression ledger for {config!r}: run "
        f"`python -m repro.analysis budgets --update`"))
    return findings
  for variant, stats in ledger["variants"].items():
    old = committed.get("variants", {}).get(variant, {})
    for metric in COMPRESSION_METRICS:
      if metric in old and old[metric] != stats[metric]:
        findings.append(_bf(
            coord, "compression_ledger", f"drift:{variant}:{metric}",
            f"{variant} {metric}: committed {old[metric]}, now "
            f"{stats[metric]} — the model's static size changed; if "
            f"intentional, --update and commit the new ledger"))
  return findings


# ---------------------------------------------------------------------------
# The budget audit driver (shared by run_audit and the CLI).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BudgetAudit:
  """Accumulates fresh ledgers and their diff against committed budgets."""
  committed: dict
  programs: dict = dataclasses.field(default_factory=dict)
  compression: dict = dataclasses.field(default_factory=dict)
  findings: list = dataclasses.field(default_factory=list)
  warnings: list = dataclasses.field(default_factory=list)

  def add_target(self, target: TraceTarget) -> dict:
    ledger = program_ledger(target)
    self.programs[coord_key(target.coord)] = ledger
    f, w = diff_program(target.coord, ledger,
                        self.committed.get("programs", {}))
    self.findings.extend(f)
    self.warnings.extend(w)
    return ledger

  def add_compression(self, config: str) -> dict:
    ledger = compression.compression_ledger(config)
    self.compression[config] = ledger
    self.findings.extend(diff_compression(
        config, ledger, self.committed.get("compression", {})))
    return ledger

  def fresh(self) -> dict:
    """The measured numbers in budgets.json shape (for --update)."""
    return {
        "meta": dict(tolerances=TOLERANCES, jax_version=jax.__version__),
        "programs": self.programs,
        "compression": self.compression,
    }


def run_budget_audit(targets: Iterable[TraceTarget],
                     config_names: Iterable[str],
                     committed: Optional[dict] = None) -> BudgetAudit:
  """Convenience driver: ledger + diff every target and config."""
  audit = BudgetAudit(load_budgets() if committed is None else committed)
  for t in targets:
    audit.add_target(t)
  for name in config_names:
    audit.add_compression(name)
  return audit
