"""The invariant registry: per-target jaxpr/HLO checks + param coverage.

Each checker takes a TraceTarget (plus its WalkResult where relevant)
and returns Findings. `run_target_checks` is the per-trace entry point;
`check_sharding_coverage` runs once per config over the *production*
param specs (divisibility against a real topology is where rule gaps
show — smoke dims divide everything or nothing).

Check semantics:

dispatch_coverage — every dot_general in a serving trace must sit under
  a `dispatch:{regime}:c{id}` scope whose id correlates to a
  DispatchRecord of the same regime captured while tracing. A dot with
  no scope is only clean if neither operand is parameter-derived
  (activation x activation / activation x cache contractions — attention
  scores, SSM scans — are intrinsic math, not weight GEMMs).

quant_integrity — in a PTQ'd trace, no value derived from an int8
  weight leaf may be converted to a floating dtype: that is a
  dequantize, and one of them silently reverts the paper's w8a8 win to
  float math with extra traffic. int8 -> int32 accumulation is legal.

transfer_lint — (a) no host-callback/transfer primitive in the traced
  program; (b) donated buffers actually donate: the StableHLO must carry
  one `tf.aliasing_output` attribute per donated state leaf (XLA drops
  mismatched aliases silently, turning an in-place cache update into a
  full copy per step); (c) the optimized HLO contains no
  infeed/outfeed/send/recv or host-callback custom-calls; (d) any
  `CostReport.unknown_ops` the hlo_cost parser reports are surfaced.

sharding_coverage — every param leaf must resolve to an explicit
  PARAM_RULES kind (or the embedding-table path rule) that actually
  shards it on the audit mesh. Big replicated weights are findings:
  either the rule table has a gap (unruled raw leaf — QuantizedLinear
  fields land here today) or divisibility gated the split off on a
  production topology (e.g. an odd vocab).
"""
from __future__ import annotations

from typing import List

from repro.analysis import jaxpr_walk
from repro.analysis.report import Finding, stable_key
from repro.analysis.targets import TraceTarget
from repro.dist import hlo_cost
from repro.dist.sharding import rule_coverage

#: replicated param leaves at or above this many elements are findings
BIG_PARAM_ELEMS = 1 << 16

#: HLO opcodes / custom-call markers that imply a host round-trip
_HLO_HOST_OPS = ("infeed", "outfeed", "send", "recv", "send-done",
                 "recv-done")
_HLO_HOST_CALL_MARKERS = ("callback", "xla_ffi_python", "CallbackCustom")


def _f(target: TraceTarget, check: str, key: str, detail: str) -> Finding:
  return Finding(check=check, config=target.config, policy=target.policy,
                 quant=target.quant, program=target.program,
                 key=stable_key(key), detail=detail)


def check_dispatch_coverage(target: TraceTarget,
                            walk: jaxpr_walk.WalkResult) -> List[Finding]:
  if target.policy == "-":
    return []        # train traces thread no policy: nothing to correlate
  by_id = {r.call_id: r for r in target.dispatch_log}
  out = []
  for dot in walk.dots:
    scope = dot.dispatch_scope()
    if scope is not None:
      regime, cid = scope
      rec = by_id.get(cid)
      if rec is None:
        out.append(_f(
            target, "dispatch_coverage",
            f"uncorrelated:{dot.name_stack}:{dot.shapes}",
            f"dot under dispatch scope c{cid} but no DispatchRecord with "
            f"that id was captured while tracing (stale jit cache?)"))
      elif rec.regime != regime:
        out.append(_f(
            target, "dispatch_coverage",
            f"regime-mismatch:{dot.name_stack}:{dot.shapes}",
            f"scope says {regime!r} but the recorded decision for "
            f"{rec.name!r} (c{cid}) was {rec.regime!r}"))
    elif any(dot.param_operands):
      out.append(_f(
          target, "dispatch_coverage",
          f"unrouted:{dot.name_stack}:{dot.shapes}",
          f"parameter-consuming dot_general {dot.shapes} outside any "
          f"dispatch scope: this GEMM bypasses kernels.dispatch.gemm and "
          f"can never route to the paper's serving kernels"))
  return out


def check_quant_integrity(target: TraceTarget,
                          walk: jaxpr_walk.WalkResult) -> List[Finding]:
  if target.quant != "int8":
    return []
  return [
      _f(target, "quant_integrity",
         f"dequantize:{c.name_stack}:{c.shape}->{c.dst_dtype}",
         f"int8 weight leaf widened to {c.dst_dtype} (shape {c.shape}): "
         f"a dequantize in the PTQ'd hot path — the stored-scale w8a8 "
         f"contract requires weights to stay int8 until accumulation")
      for c in walk.int8_converts
  ]


def check_transfer_lint(target: TraceTarget,
                        walk: jaxpr_walk.WalkResult) -> List[Finding]:
  out = [
      _f(target, "transfer_lint",
         f"host-prim:{p.prim}:{p.name_stack}",
         f"host/transfer primitive {p.prim!r} traced into the program — "
         f"a device<->host round-trip inside the hot loop")
      for p in walk.host_prims
  ]
  if target.n_donated and target.lowered_text is not None:
    aliased = target.lowered_text.count("tf.aliasing_output")
    if aliased < target.n_donated:
      out.append(_f(
          target, "transfer_lint",
          f"donation-dropped:{aliased}/{target.n_donated}",
          f"only {aliased} of {target.n_donated} donated state leaves "
          f"carry tf.aliasing_output in the lowered module: the rest "
          f"copy instead of updating in place (dtype/shape mismatch "
          f"between a state input and its output?)"))
  if target.compiled_text is not None:
    out.extend(_hlo_findings(target))
  return out


def _hlo_findings(target: TraceTarget) -> List[Finding]:
  out = []
  comps, _ = hlo_cost._parse_computations(target.compiled_text)
  for name, instrs in comps.items():
    for ins in instrs:
      if ins.opcode in _HLO_HOST_OPS:
        out.append(_f(
            target, "transfer_lint", f"hlo-host-op:{ins.opcode}:{name}",
            f"optimized HLO contains {ins.opcode!r} in computation "
            f"{name!r}: a host transfer survived compilation"))
      elif ins.opcode == "custom-call" and any(
          m in ins.attrs or m in ins.operands
          for m in _HLO_HOST_CALL_MARKERS):
        out.append(_f(
            target, "transfer_lint", f"hlo-callback:{name}",
            f"optimized HLO custom-call in {name!r} targets a host "
            f"callback"))
  rep = hlo_cost.analyze_module(target.compiled_text)
  for token, count in sorted(rep.unknown_ops.items()):
    out.append(_f(
        target, "transfer_lint", f"hlo-unknown:{token}",
        f"hlo_cost could not fully account {count} instruction(s) "
        f"({token}): cost figures for this program under-count"))
  return out


def check_sharding_coverage(config: str, params,
                            quant: str = "float") -> List[Finding]:
  """Rule coverage over one config's (production-scale) param tree."""
  out = []
  for e in rule_coverage(params):
    big = e["size"] >= BIG_PARAM_ELEMS and len(e["shape"]) >= 2
    if e["name"] is not None:
      if big and not e["sharded"]:
        out.append(Finding(
            check="sharding_coverage", config=config, quant=quant,
            program="params",
            key=f"unsharded:{e['name']}:{e['field']}:{e['shape']}",
            detail=(f"GEMM leaf {e['name']!r} ({e['field']}, shape "
                    f"{e['shape']}, rule {e['rule']!r}) replicates on the "
                    f"audit mesh: its split was divisibility-gated off")))
    elif e["rule"] is None and big:
      out.append(Finding(
          check="sharding_coverage", config=config, quant=quant,
          program="params",
          key=f"unruled:{e['path']}:{e['shape']}",
          detail=(f"raw param leaf {e['path']!r} (shape {e['shape']}, "
                  f"{e['size']} elems) matches no PARAM_RULES glob or "
                  f"path rule and replicates everywhere")))
    elif e["rule"] is not None and big and not e["sharded"]:
      out.append(Finding(
          check="sharding_coverage", config=config, quant=quant,
          program="params",
          key=f"unsharded:{e['path']}:{e['shape']}",
          detail=(f"path-ruled leaf {e['path']!r} ({e['rule']}) "
                  f"replicates on the audit mesh (divisibility)")))
  return out


def run_target_checks(target: TraceTarget) -> tuple:
  """All per-trace checks for one target. Returns (findings, info) where
  info is the target's report metadata (coverage counts, unknown ops)."""
  walk = jaxpr_walk.walk(target.jaxpr, target.n_params,
                         target.int8_param_idx)
  findings: List[Finding] = []
  findings.extend(check_dispatch_coverage(target, walk))
  findings.extend(check_quant_integrity(target, walk))
  findings.extend(check_transfer_lint(target, walk))
  scoped = sum(1 for d in walk.dots if d.dispatch_scope() is not None)
  info = dict(target.coord)
  info.update(
      n_eqns=walk.n_eqns, n_dots=len(walk.dots), n_dots_scoped=scoped,
      n_dispatch_records=len(target.dispatch_log),
      regimes=sorted({r.regime for r in target.dispatch_log}),
      n_findings=len(findings))
  if target.compiled_text is not None:
    info["hlo_unknown_ops"] = dict(
        hlo_cost.analyze_module(target.compiled_text).unknown_ops)
  return findings, info
