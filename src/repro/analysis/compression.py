"""Static compression ledger: param count/bytes per (family, plan, PTQ).

"The compressed model is actually smaller" is the paper's §3/§4 product
claim, and it is a *static* property: parameter counts and byte sizes
are fully determined by shapes and dtypes. This module computes them at
PRODUCTION scale with `jax.eval_shape` only — no weights materialize —
for four canonical variants of every family:

  float         the full-rank float tree (`specs.param_specs`)
  int8          one-shot PTQ of it (`quant.quantize_params`)
  lowrank       a stage-2 shaped tree: every plan-matched GEMM carried
                as (m, r) x (r, n) factors at the *ledger rank* below
  lowrank_int8  PTQ of the lowrank tree (factored u/v int8 + scales)

The ledger rank is a shape-only stand-in for stage-2 truncation —
`svd.truncate_leaf` needs concrete singular values, which eval_shape
cannot provide — pinned to r = max(8, round8(min(m, n) / 4)). Since the
default plan only matches GEMMs with min(m, n) >= 128, r <= min(m, n)/4
always, so r(m + n) < mn holds *structurally*: the strict-compression
assertions below are not empirical.

Byte figures come in two flavors: `param_bytes` (whole tree) and
`device_bytes` (per device on the canonical audit mesh, via
`dist.sharding.rule_coverage`'s gated shard factors) — the PTQ ledger is
shard-aware, so a rule gap that silently replicates an int8 payload
shows up as a device_bytes regression, not just a sharding finding.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro import configs
from repro.configs import specs
from repro.core.compress import FactorizationPlan
from repro.core.factored import (FactoredLinear, count_params,
                                 is_gemm_leaf, map_factored_leaves)
from repro.dist.sharding import rule_coverage
from repro.quant.ptq import quantize_params

#: the ledger's canonical stage-2 scope: every GEMM with min dim >= 128
DEFAULT_PLAN = FactorizationPlan()

VARIANTS = ("float", "int8", "lowrank", "lowrank_int8")


def ledger_rank(m: int, n: int) -> int:
  """Shape-only stage-2 rank: min(m, n)/4, rounded down to a multiple
  of 8 (the TruncationSpec.round_to default), floored at 8."""
  return max(8, (min(m, n) // 4) // 8 * 8)


def _leaf_bits(leaf) -> int:
  dt = leaf.dtype
  bits = dt.itemsize * 8
  if "int4" in dt.name:
    bits = 4
  return int(leaf.size) * bits


def tree_bytes(tree: Any) -> int:
  """Total parameter bytes of a tree of arrays / ShapeDtypeStructs."""
  return sum((_leaf_bits(l) + 7) // 8 for l in jax.tree.leaves(tree))


def device_bytes(tree: Any, mesh=None) -> int:
  """Per-device parameter bytes on the audit mesh: each leaf's bytes
  divided by the shard factor its gated rule actually achieves."""
  total = 0
  for e in rule_coverage(tree, mesh=mesh):
    f = max(int(e["shard_factor"]), 1)
    total += (int(e["bytes"]) + f - 1) // f
  return total


def lowrank_tree(params: Any,
                 plan: Optional[FactorizationPlan] = None) -> Any:
  """Project a float tree to its stage-2 *shape*: plan-matched GEMMs
  become (m, r) x (r, n) ShapeDtypeStruct factors at the ledger rank.

  Shape-only by construction (works on eval_shape specs). Layer-stacked
  (L, m, n) leaves factor per layer to (L, m, r) x (L, r, n) — the same
  homogeneous-rank shape `svd.truncate_leaf` really produces for scanned
  stacks."""
  plan = DEFAULT_PLAN if plan is None else plan

  def f(leaf: FactoredLinear):
    arr = leaf.u if leaf.is_factored else leaf.w
    if not plan.matches(leaf):
      return leaf
    lead = arr.shape[:-2]
    m, n = leaf.in_dim, leaf.out_dim
    r = ledger_rank(m, n)
    return FactoredLinear(
        w=None,
        u=jax.ShapeDtypeStruct(lead + (m, r), arr.dtype),
        v=jax.ShapeDtypeStruct(lead + (r, n), arr.dtype),
        name=leaf.name, group=leaf.group)
  return map_factored_leaves(f, params)


def _variant_stats(tree: Any) -> dict:
  return dict(
      param_count=int(count_params(tree)),
      n_leaves=len(jax.tree.leaves(tree)),
      param_bytes=tree_bytes(tree),
      device_bytes=device_bytes(tree),
  )


def compression_ledger(config_name: str,
                       plan: Optional[FactorizationPlan] = None) -> dict:
  """The four-variant ledger for one family at production scale."""
  plan = DEFAULT_PLAN if plan is None else plan
  cfg = configs.get_config(config_name)
  float_tree = specs.param_specs(cfg)
  lr_tree = lowrank_tree(float_tree, plan)
  trees = {
      "float": float_tree,
      "int8": jax.eval_shape(quantize_params, float_tree),
      "lowrank": lr_tree,
      "lowrank_int8": jax.eval_shape(quantize_params, lr_tree),
  }
  variants = {k: _variant_stats(t) for k, t in trees.items()}
  n_factored = sum(
      1 for l in jax.tree.leaves(lr_tree, is_leaf=is_gemm_leaf)
      if isinstance(l, FactoredLinear) and l.is_factored)
  fb = variants["float"]["param_bytes"]
  lb = variants["lowrank"]["param_bytes"]
  return dict(
      variants=variants,
      n_factored_gemms=n_factored,
      ratios=dict(
          int8_vs_float=round(variants["int8"]["param_bytes"] / fb, 6),
          lowrank_vs_float=round(lb / fb, 6),
          lowrank_int8_vs_lowrank=round(
              variants["lowrank_int8"]["param_bytes"] / lb, 6),
      ),
  )


def strictness_violations(ledger: dict) -> list:
  """The acceptance-criteria assertions, as (key, detail) pairs:
  each compressed variant must be STRICTLY smaller in bytes than its
  uncompressed counterpart (whole-tree and per-device alike)."""
  v = ledger["variants"]
  pairs = (
      ("int8", "float"),
      ("lowrank", "float"),
      ("lowrank_int8", "lowrank"),
      ("lowrank_int8", "float"),
  )
  out = []
  for small, big in pairs:
    for metric in ("param_bytes", "device_bytes"):
      if not v[small][metric] < v[big][metric]:
        out.append((
            f"not-smaller:{small}-vs-{big}:{metric}",
            f"{small} {metric}={v[small][metric]} is not strictly "
            f"smaller than {big} {metric}={v[big][metric]}: the "
            f"compressed tree stopped being smaller"))
  return out
