"""repro.analysis: static auditor for the serving hot path.

`run_audit` traces the canonical jitted programs of every requested
(config x policy x quant x program) coordinate — without executing them
— and checks the invariant registry (`report.CHECKS`) against the jaxpr
and optimized HLO:

  dispatch_coverage   every weight GEMM routed through kernels.dispatch
  quant_integrity     no int8 weight dequantized in a PTQ'd trace
  retrace_stability   engine compiles each signature exactly once
  prefix_splice_stability  cached-splice serving keeps the cold path's
                      prefill signatures and token-for-token output
  spec_window_stability  the batched speculative verify window compiles
                      one signature per (bucket, k) — greedy and
                      sampled, across draft-rank walks
  transfer_lint       no host round-trips; donation actually aliases
  sharding_coverage   every production param leaf has a sharding rule
  cost_budget         HLO FLOP/byte/collective ledger within the
                      committed tolerance band (budgets.json)
  memory_budget       jaxpr liveness peak-bytes within its band
  compression_ledger  static param count/bytes exactly as committed;
                      compressed trees strictly smaller

Findings diff against the committed allowlist (`baseline.json`); any
ident not in it is a regression. Budget ledgers diff against committed
numbers (`budgets.json`) — see `python -m repro.analysis budgets`.
CLI: `python -m repro.analysis audit`.
"""
from __future__ import annotations

from typing import Iterable

import jax

from repro import configs
from repro.analysis import budgets as budgets_mod
from repro.analysis import checks, lifecycle
from repro.analysis.report import (AuditReport, CHECKS, Finding,
                                   default_baseline_path, load_baseline,
                                   stable_key, write_baseline)
from repro.analysis.targets import (DEFAULT_CONFIGS, POLICIES, PROGRAMS,
                                    QUANTS, iter_targets, normalize_config)
from repro.configs import specs
from repro.quant.ptq import quantize_params

__all__ = [
    "AuditReport", "CHECKS", "Finding", "run_audit", "iter_targets",
    "load_baseline", "write_baseline", "default_baseline_path",
    "stable_key", "DEFAULT_CONFIGS", "POLICIES", "QUANTS", "PROGRAMS",
]


def _sharding_findings(config_names, report: AuditReport) -> None:
  """sharding_coverage runs at PRODUCTION scale (configs.get_config):
  rule gaps hide at smoke dims, where nothing is divisible anyway."""
  for name in config_names:
    name = normalize_config(name)
    cfg = configs.get_config(name)
    params = specs.param_specs(cfg)
    report.extend(checks.check_sharding_coverage(name, params, "float"))
    qparams = jax.eval_shape(quantize_params, params)
    quants = ["float"]
    if any(str(l.dtype) == "int8" for l in jax.tree.leaves(qparams)):
      report.extend(checks.check_sharding_coverage(name, qparams, "int8"))
      quants.append("int8")
    for q in quants:
      report.targets.append(dict(
          config=name, policy="-", quant=q, program="params",
          n_param_leaves=len(jax.tree.leaves(params))))


def run_audit(config_names: Iterable[str] = DEFAULT_CONFIGS,
              policies: Iterable[str] = POLICIES,
              quants: Iterable[str] = QUANTS,
              programs: Iterable[str] = PROGRAMS,
              *, deep: bool = False, run_lifecycle: bool = True,
              run_sharding: bool = True,
              run_budgets: bool = True,
              budgets_path=None) -> AuditReport:
  """Trace + check the requested grid; baseline NOT applied (caller's
  job, so tests can assert on raw findings)."""
  config_names = [normalize_config(n) for n in config_names]
  report = AuditReport(meta=dict(
      configs=list(config_names), policies=list(policies),
      quants=list(quants), programs=list(programs), deep=deep,
      jax_version=jax.__version__, checks=list(CHECKS)))
  budget_audit = None
  if run_budgets:
    budget_audit = budgets_mod.BudgetAudit(
        budgets_mod.load_budgets(budgets_path))
  for target in iter_targets(config_names, policies, quants, programs,
                             deep=deep):
    findings, info = checks.run_target_checks(target)
    if budget_audit is not None:
      info["budget"] = budget_audit.add_target(target)
    report.extend(findings)
    report.targets.append(info)
  if run_lifecycle:
    lf, infos = lifecycle.check_retrace_stability(config_names, policies)
    report.extend(lf)
    report.targets.extend(infos)
    sf, sinfos = lifecycle.check_prefix_splice_stability(config_names,
                                                         policies)
    report.extend(sf)
    report.targets.extend(sinfos)
    wf, winfos = lifecycle.check_spec_window_stability(config_names,
                                                       policies)
    report.extend(wf)
    report.targets.extend(winfos)
    ff, finfos = lifecycle.check_speech_fleet_stability(config_names,
                                                        policies)
    report.extend(ff)
    report.targets.extend(finfos)
  if run_sharding:
    _sharding_findings(config_names, report)
  if budget_audit is not None:
    for name in config_names:
      budget_audit.add_compression(name)
    report.extend(budget_audit.findings)
    report.meta["budgets"] = budget_audit.fresh()
    report.meta["budget_ratchet_stale"] = budget_audit.warnings
  return report
