"""Trace harness: the canonical jitted programs, traced — never executed.

One TraceTarget = one (config, policy, quant, program) coordinate:

  program   built from                         traced as
  decode    ModelApi.decode_step               jaxpr + StableHLO +
            (donated state, the engine's step) optimized HLO
  window    ModelApi.decode_window             jaxpr
  prefill   serving.engine.make_prefill_program jaxpr
            (the engine's real fused prefill)
  train     ModelApi.loss_fn                   jaxpr (float/jnp only —
                                               loss_fn takes no policy)

Everything is abstract: params come from `configs.param_specs` (an
eval_shape over init), decode state from an eval_shape over
`init_decode_state`, quantized trees from an eval_shape over
`quant.quantize_params` — zero FLOPs, zero device allocation. Tracing
happens inside `dispatch.record_dispatch()`, so each target carries the
DispatchRecords whose call ids the jaxpr's `dispatch:...` scopes refer
to. `.lower()` / `.compile()` run OUTSIDE the recorder (they re-trace
with fresh ids); only the jaxpr from `make_jaxpr` is id-correlated.

Smoke configs (`configs.get_smoke`) keep tracing/compiling CPU-cheap;
the program *structure* under audit — dispatch routing, dtype flow,
donation — is identical to the production configs by construction (same
model code, same policy objects).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import specs
from repro.kernels import dispatch
from repro.layers.common import ShapeConfig, identity_constraint
from repro.models.api import get_model
from repro.quant.ptq import quantize_params
from repro.serving.engine import make_prefill_program

#: the five model families, one production config each
DEFAULT_CONFIGS = ("qwen3-4b", "zamba2-7b", "xlstm-350m", "whisper-small",
                   "deepspeech2-wsj")
POLICIES = ("jnp", "pallas")
QUANTS = ("float", "int8")
PROGRAMS = ("decode", "window", "prefill", "train")

#: audit trace geometry — small, pow2, CPU-trivial
BATCH = 2
MAX_LEN = 16
WINDOW = 3
PROMPT_LEN = 8
TRAIN_SEQ = 64


def normalize_config(name: str) -> str:
  """CLI convenience: qwen3_4b -> qwen3-4b."""
  hyphen = name.replace("_", "-")
  if hyphen in configs._MODULES:
    return hyphen
  return name


@dataclasses.dataclass
class TraceTarget:
  config: str
  family: str
  policy: str                    # "jnp" | "pallas" | "-"
  quant: str                     # "float" | "int8" | "-"
  program: str
  jaxpr: Any                     # ClosedJaxpr
  dispatch_log: list             # DispatchRecords captured while tracing
  n_params: int                  # flattened param-leaf count (leading invars)
  int8_param_idx: frozenset      # positions of int8 leaves within those
  n_donated: int                 # donated-arg leaf count (0: no donation)
  lowered_text: Optional[str]    # StableHLO (donation check)
  compiled_text: Optional[str]   # optimized HLO (HLO checks)

  @property
  def coord(self) -> dict:
    return dict(config=self.config, policy=self.policy, quant=self.quant,
                program=self.program)


def _flat_with_int8(tree) -> tuple:
  leaves = jax.tree.leaves(tree)
  idx = frozenset(i for i, l in enumerate(leaves)
                  if jnp.dtype(l.dtype) == jnp.int8)
  return leaves, idx


def _trace(fn, args, *, donate=(), lower=False, compile_=False):
  with dispatch.record_dispatch() as log:
    closed = jax.make_jaxpr(fn)(*args)
  lowered_text = compiled_text = None
  if lower or compile_:
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    lowered_text = lowered.as_text()
    if compile_:
      compiled_text = lowered.compile().as_text()
  return closed, list(log), lowered_text, compiled_text


def iter_targets(config_names: Iterable[str] = DEFAULT_CONFIGS,
                 policies: Iterable[str] = POLICIES,
                 quants: Iterable[str] = QUANTS,
                 programs: Iterable[str] = PROGRAMS,
                 *, deep: bool = False) -> Iterator[TraceTarget]:
  """Yield every TraceTarget of the requested grid.

  `deep` extends lowering+compilation (default: decode only — the hot
  path) to the window/prefill/train programs too."""
  for name in config_names:
    name = normalize_config(name)
    cfg = configs.get_smoke(name)
    api = get_model(cfg)
    cs = identity_constraint
    # fresh traces for every inner module-level jit (ops wrappers): a warm
    # cache would splice stale name stacks into this audit's jaxprs
    dispatch.clear_jit_caches()

    params_by_quant = {"float": specs.param_specs(cfg)}
    state_sds = jax.eval_shape(
        lambda: api.init_decode_state(cfg, BATCH, MAX_LEN))
    n_state = len(jax.tree.leaves(state_sds))
    decode_in = specs.input_specs(
        cfg, ShapeConfig("audit_decode", "decode", MAX_LEN, BATCH))
    if cfg.family == "deepspeech":
      x = decode_in["x_t"]
      tok = jax.ShapeDtypeStruct((BATCH, 1) + x.shape[1:], x.dtype)
      win_tok = jax.ShapeDtypeStruct((BATCH, WINDOW) + x.shape[1:], x.dtype)
    else:
      tok = decode_in["token"]
      win_tok = jax.ShapeDtypeStruct((BATCH, WINDOW), jnp.int32)
    pos = jax.ShapeDtypeStruct((BATCH,), jnp.int32)

    for quant in quants:
      if quant == "int8" and quant not in params_by_quant:
        params_by_quant["int8"] = jax.eval_shape(
            functools.partial(quantize_params), params_by_quant["float"])
      params = params_by_quant[quant]
      flat, int8_idx = _flat_with_int8(params)
      n_params = len(flat)
      if quant == "int8" and not int8_idx:
        continue    # nothing quantized at this scale: target is vacuous

      for policy in policies:
        pol = (dispatch.JNP_ONLY if policy == "jnp"
               else dispatch.decode_policy(BATCH))

        if "decode" in programs:
          def decode(p, s, t, ps):
            return api.decode_step(p, s, t, ps, cfg, cs, pol)
          closed, log, low, comp = _trace(
              decode, (params, state_sds, tok, pos), donate=(1,),
              lower=True, compile_=True)
          yield TraceTarget(name, cfg.family, policy, quant, "decode",
                            closed, log, n_params, int8_idx, n_state,
                            low, comp)

        if "window" in programs:
          def window(p, s, t, ps):
            return api.decode_window(p, s, t, ps, cfg, cs, pol)
          closed, log, low, comp = _trace(
              window, (params, state_sds, win_tok, pos), donate=(1,),
              lower=deep, compile_=deep)
          # donation is declared above regardless of `deep` (only the
          # lowered text is gated), so n_donated must not vary with it:
          # liveness budgets diff deep-generated numbers in shallow runs
          yield TraceTarget(name, cfg.family, policy, quant, "window",
                            closed, log, n_params, int8_idx, n_state,
                            low, comp)

        if "prefill" in programs and cfg.family != "deepspeech":
          # token-driven only: DS2 prefills frame-synchronously through
          # the streaming server, not the engine's fused prompt scan
          prefill = make_prefill_program(
              api, cfg, cs, pol, api.decode_state_batch_axes(cfg))
          prompts = jax.ShapeDtypeStruct((BATCH, PROMPT_LEN), jnp.int32)
          plens = jax.ShapeDtypeStruct((BATCH,), jnp.int32)
          closed, log, low, comp = _trace(
              prefill, (params, state_sds, prompts, plens, pos),
              lower=deep, compile_=deep)
          yield TraceTarget(name, cfg.family, policy, quant, "prefill",
                            closed, log, n_params, int8_idx, 0, low, comp)

    if "train" in programs:
      # loss_fn threads no KernelPolicy (training is the always-jnp
      # surface), so the train trace has one coordinate: float x jnp
      params = params_by_quant["float"]
      flat, int8_idx = _flat_with_int8(params)
      batch_sds = specs.input_specs(
          cfg, ShapeConfig("audit_train", "train", TRAIN_SEQ, BATCH))
      def train(p, b):
        return api.loss_fn(p, b, cfg, cs)
      closed, log, low, comp = _trace(
          train, (params, batch_sds), lower=deep, compile_=deep)
      yield TraceTarget(name, cfg.family, "-", "float", "train",
                        closed, log, len(flat), int8_idx, 0, low, comp)
