"""Runtime: fault-tolerant step supervision."""
from repro.runtime.supervisor import (FaultInjector, SimulatedDeviceFailure,
                                      Supervisor, SupervisorEvents)

__all__ = ["FaultInjector", "SimulatedDeviceFailure", "Supervisor",
           "SupervisorEvents"]
