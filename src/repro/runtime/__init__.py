"""Runtime: fault-tolerant step supervision."""
from repro.runtime.supervisor import (FaultInjector, SimulatedDeviceFailure,
                                      Supervisor, SupervisorEvents)
