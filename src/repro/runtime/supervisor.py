"""Step supervisor: failure recovery + straggler detection.

On real pods a device failure surfaces as an XlaRuntimeError (or a missing
heartbeat from a host). The supervisor's contract:

  1. every step runs under the supervisor;
  2. on failure it calls `rebuild()` — on hardware this re-enumerates
     survivors and rebuilds the mesh (elastic topologies are supported by
     dist.mesh.make_mesh + checkpoint resharding); in tests a FaultInjector
     raises at a chosen step;
  3. restores the latest checkpoint and replays — the stateless data
     stream (data/lm.py) regenerates the in-flight batches exactly.

Straggler mitigation: a per-step wall-time EWMA; steps slower than
`straggler_factor` x EWMA are recorded and the `on_straggler` hook fires
(on hardware: trigger rebalance / hot-spare swap; here: tested with
injected delays in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional


class SimulatedDeviceFailure(RuntimeError):
  """Stands in for xla_client.XlaRuntimeError on real hardware."""


@dataclasses.dataclass
class FaultInjector:
  """Deterministic fault plan for tests: {step_index: exception}."""
  fail_at: dict = dataclasses.field(default_factory=dict)
  delays: dict = dataclasses.field(default_factory=dict)
  fired: set = dataclasses.field(default_factory=set)

  def check(self, step: int) -> None:
    if step in self.delays:
      time.sleep(self.delays[step])
    if step in self.fail_at and step not in self.fired:
      self.fired.add(step)
      raise SimulatedDeviceFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class SupervisorEvents:
  failures: list = dataclasses.field(default_factory=list)
  recoveries: list = dataclasses.field(default_factory=list)
  stragglers: list = dataclasses.field(default_factory=list)


class Supervisor:

  def __init__(self, *, restore: Callable[[], None],
               rebuild: Optional[Callable[[], None]] = None,
               max_retries: int = 3,
               straggler_factor: float = 3.0,
               ewma_alpha: float = 0.2,
               on_straggler: Optional[Callable[[int, float], None]] = None,
               injector: Optional[FaultInjector] = None):
    self.restore = restore
    self.rebuild = rebuild or (lambda: None)
    self.max_retries = max_retries
    self.straggler_factor = straggler_factor
    self.ewma_alpha = ewma_alpha
    self.on_straggler = on_straggler or (lambda step, t: None)
    self.injector = injector
    self.events = SupervisorEvents()
    self._ewma: Optional[float] = None

  def run_step(self, step: int, fn: Callable[[], Any]) -> Any:
    """Execute one supervised step with recovery."""
    for attempt in range(self.max_retries + 1):
      t0 = time.perf_counter()
      try:
        if self.injector is not None:
          self.injector.check(step)
        out = fn()
        self._track_time(step, time.perf_counter() - t0)
        return out
      except (SimulatedDeviceFailure, RuntimeError) as e:  # XlaRuntimeError
        self.events.failures.append((step, repr(e)))
        if attempt >= self.max_retries:
          raise
        self.rebuild()        # re-enumerate survivors, rebuild mesh
        self.restore()        # reload last checkpoint (resharded if needed)
        self.events.recoveries.append((step, attempt + 1))
    raise RuntimeError("unreachable")

  def _track_time(self, step: int, dt: float) -> None:
    if self._ewma is None:
      self._ewma = dt
      return
    if dt > self.straggler_factor * self._ewma:
      self.events.stragglers.append((step, dt, self._ewma))
      self.on_straggler(step, dt)
    self._ewma = (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * dt
