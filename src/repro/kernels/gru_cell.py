"""Fused GRU cell: recurrent GEMM (h @ U) + gate nonlinearities in one
kernel (paper eq. 10, the sequential batch~1 GEMM the farm kernels target).

The non-recurrent projection xw = x @ W is batched across time *outside*
the cell (paper §4 / B.2 — that GEMM has no sequential dependency), so the
kernel consumes xw precomputed.

Layout trick: the three gates of output column i live at U columns
(i, H+i, 2H+i). The wrapper reshapes U (H, 3H) -> (H, 3, H) so one output
tile (B, bh) needs exactly the U block (H, 3, bh) — gate-aligned streaming
without strided reads. Grid: (H/bh,), weights visited once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xw_ref, h_full_ref, u_ref, b_ref, h_blk_ref, out_ref):
  hidden_blk = out_ref.shape[-1]
  hf = h_full_ref[...].astype(jnp.float32)            # (B, H)
  u = u_ref[...].astype(jnp.float32)                  # (H, 3, bh)
  u2 = u.reshape(u.shape[0], 3 * hidden_blk)
  hu = jnp.dot(hf, u2, preferred_element_type=jnp.float32)
  hu = hu.reshape(hf.shape[0], 3, hidden_blk)
  g = xw_ref[...].astype(jnp.float32) + hu + b_ref[...].astype(jnp.float32)
  z = jax.nn.sigmoid(g[:, 0])
  r = jax.nn.sigmoid(g[:, 1])
  hu_h = hu[:, 2]
  hcand = jnp.tanh(g[:, 2] - hu_h + r * hu_h)
  h_old = h_blk_ref[...].astype(jnp.float32)
  out_ref[...] = ((1.0 - z) * h_old + z * hcand).astype(out_ref.dtype)


def gru_cell(xw: jax.Array, h: jax.Array, u: jax.Array, bias: jax.Array, *,
             block_h: int = 256, interpret: bool = False) -> jax.Array:
  """xw: (b, 3H); h: (b, H); u: (H, 3H); bias: (3H,) -> h': (b, H)."""
  b, hidden = h.shape
  bh = min(block_h, hidden)
  assert hidden % bh == 0, (hidden, bh)
  nh = hidden // bh

  u3 = u.reshape(hidden, 3, hidden)          # (H, gate, H)
  xw3 = xw.reshape(b, 3, hidden)
  bias3 = bias.reshape(1, 3, hidden)

  return pl.pallas_call(
      _kernel,
      grid=(nh,),
      in_specs=[
          pl.BlockSpec((b, 3, bh), lambda i: (0, 0, i)),
          pl.BlockSpec((b, hidden), lambda i: (0, 0)),
          pl.BlockSpec((hidden, 3, bh), lambda i: (0, 0, i)),
          pl.BlockSpec((1, 3, bh), lambda i: (0, 0, i)),
          pl.BlockSpec((b, bh), lambda i: (0, i)),
      ],
      out_specs=pl.BlockSpec((b, bh), lambda i: (0, i)),
      out_shape=jax.ShapeDtypeStruct((b, hidden), h.dtype),
      interpret=interpret,
  )(xw3, h, u3, bias3, h)
