"""Jit'd public wrappers for the Pallas kernels.

Responsibilities: (8, 128)-align every matmul dim (pad + slice), pick
block shapes that fit VMEM, fall back to the jnp reference when a shape is
degenerate (dims < MXU tile), and expose an `interpret` flag so the CPU
container runs the kernel bodies in Python (the tests' default).

On this container interpret=True is forced automatically (no TPU), which
is also how the per-kernel allclose sweeps in tests/test_kernels.py run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_matvec import decode_matvec as _decode_matvec
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gru_cell import gru_cell as _gru_cell
from repro.kernels.int8_gemm import int8_gemm as _int8_gemm
from repro.kernels.lowrank_gemm import lowrank_gemm as _lowrank_gemm

LANE = 128
SUBLANE = 8


def _on_tpu() -> bool:
  return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
  size = x.shape[axis]
  pad = (-size) % mult
  if pad == 0:
    return x
  widths = [(0, 0)] * x.ndim
  widths[axis] = (0, pad)
  return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def lowrank_gemm(x, u, v, *, block_m: int = 512, block_n: int = 512,
                 interpret: bool | None = None):
  """y = (x @ U) @ V fused; x: (b, m), u: (m, r), v: (r, n)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x.shape
  r, n = v.shape
  if min(m, n, r) < LANE:
    return ref.lowrank_gemm(x, u, v)
  xp = _pad_to(_pad_to(x, 0, SUBLANE), 1, LANE)
  up = _pad_to(_pad_to(u, 0, LANE), 1, LANE)
  vp = _pad_to(_pad_to(v, 0, LANE), 1, LANE)
  bm = min(block_m, xp.shape[1])
  bn = min(block_n, vp.shape[1])
  while xp.shape[1] % bm:
    bm //= 2
  while vp.shape[1] % bn:
    bn //= 2
  y = _lowrank_gemm(xp, up, vp, block_m=bm, block_n=bn, interpret=interpret)
  return y[:b, :n]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def int8_gemm(x_q, w_q, x_scale, w_scale, *, block_m: int = 512,
              block_n: int = 512, interpret: bool | None = None):
  """w8a8 GEMM with fused dequant; returns f32 (b, n)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x_q.shape
  n = w_q.shape[1]
  if min(m, n) < LANE:
    return ref.int8_gemm(x_q, w_q, x_scale, w_scale)
  xp = _pad_to(_pad_to(x_q, 0, SUBLANE), 1, LANE)
  wp = _pad_to(_pad_to(w_q, 0, LANE), 1, LANE)
  xsp = _pad_to(x_scale, 0, SUBLANE)
  wsp = _pad_to(w_scale, 0, LANE)
  bm = min(block_m, xp.shape[1])
  bn = min(block_n, wp.shape[1])
  while xp.shape[1] % bm:
    bm //= 2
  while wp.shape[1] % bn:
    bn //= 2
  y = _int8_gemm(xp, wp, xsp, wsp, block_m=bm, block_n=bn,
                 interpret=interpret)
  return y[:b, :n]


def quantized_matmul(x: jax.Array, w: jax.Array,
                     interpret: bool | None = None) -> jax.Array:
  """Convenience: quantize both operands then int8_gemm (bench path)."""
  x_q, x_s = ref.quantize_rowwise(x)
  w_q, w_s = ref.quantize_colwise(w)
  return int8_gemm(x_q, w_q, x_s, w_s, interpret=interpret).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def decode_matvec(x, w, *, block_m: int = 1024, block_n: int = 256,
                  interpret: bool | None = None):
  """Low-batch y = x @ w; x: (b<=16, m), w: (m, n)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x.shape
  n = w.shape[1]
  if min(m, n) < LANE:
    return ref.decode_matvec(x, w)
  xp = _pad_to(_pad_to(x, 0, SUBLANE), 1, LANE)
  wp = _pad_to(_pad_to(w, 0, LANE), 1, LANE)
  bm = min(block_m, xp.shape[1])
  bn = min(block_n, wp.shape[1])
  while xp.shape[1] % bm:
    bm //= 2
  while wp.shape[1] % bn:
    bn //= 2
  y = _decode_matvec(xp, wp, block_m=bm, block_n=bn, interpret=interpret)
  return y[:b, :n]


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def gru_cell(xw, h, u, bias, *, block_h: int = 256,
             interpret: bool | None = None):
  """Fused GRU step; xw: (b, 3H), h: (b, H), u: (H, 3H), bias: (3H,)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, hidden = h.shape
  if hidden < LANE:
    return ref.gru_cell(xw, h, u, bias)
  bh = min(block_h, hidden)
  while hidden % bh:
    bh //= 2
  return _gru_cell(xw, h, u, bias, block_h=bh, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
  """q, k, v: (b, s, h, d); GQA callers repeat kv heads first."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, s, h, d = q.shape
  if s < SUBLANE or d < LANE:
    return ref.flash_attention(q, k, v, causal=causal)
  bq = min(block_q, s)
  bk = min(block_k, s)
  while s % bq:
    bq //= 2
  while s % bk:
    bk //= 2
  return _flash(q, k, v, causal=causal, block_q=bq, block_k=bk,
                interpret=interpret)
