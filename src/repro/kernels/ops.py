"""Jit'd public wrappers for the Pallas kernels.

Responsibilities: (8, 128)-align every matmul dim (pad + slice), pick
block shapes that fit VMEM, fall back to the jnp reference when a shape is
degenerate (dims < MXU tile), and expose an `interpret` flag so the CPU
container runs the kernel bodies in Python (the tests' default).

On this container interpret=True is forced automatically (no TPU), which
is also how the per-kernel allclose sweeps in tests/test_kernels.py run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_matvec import decode_matvec as _decode_matvec
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gru_cell import gru_cell as _gru_cell
from repro.kernels.int8_gemm import int8_gemm as _int8_gemm
from repro.kernels.lowrank_gemm import lowrank_gemm as _lowrank_gemm

LANE = 128
SUBLANE = 8

# decode_matvec's documented contract (paper §4: batch 1..16); the wrapper
# falls back to the jnp reference above this, it never silently accepts.
DECODE_BATCH_MAX = 16

# Default block shapes per kernel — THE block-size table (the wrappers'
# block args default to None, so edits here take effect everywhere). A
# caller's explicit request wins; `_fit_blocks` then clamps each block to
# its dim and halves until it divides, so every kernel shares one copy of
# the fitting logic instead of inlining it.
BLOCK_TABLE: dict[str, dict[str, int]] = {
    "lowrank_gemm": {"block_m": 512, "block_n": 512},
    "int8_gemm": {"block_m": 512, "block_n": 512},
    "decode_matvec": {"block_m": 1024, "block_n": 256},
    "gru_cell": {"block_h": 256},
    "flash_attention": {"block_q": 512, "block_k": 512},
}


def _fit_blocks(kernel: str, dims: dict[str, int],
                requested: dict[str, int] | None = None) -> dict[str, int]:
  """Pick block sizes for `kernel`: table default (or caller request),
  clamped to the padded dim, halved until it divides the dim."""
  table = BLOCK_TABLE[kernel]
  out = {}
  for key, dim in dims.items():
    blk = (requested or {}).get(key) or table[key]
    blk = min(blk, dim)
    while dim % blk:
      blk //= 2
    out[key] = blk
  return out


def _on_tpu() -> bool:
  return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
  size = x.shape[axis]
  pad = (-size) % mult
  if pad == 0:
    return x
  widths = [(0, 0)] * x.ndim
  widths[axis] = (0, pad)
  return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def lowrank_gemm(x, u, v, *, block_m: int | None = None,
                 block_n: int | None = None,
                 interpret: bool | None = None):
  """y = (x @ U) @ V fused; x: (b, m), u: (m, r), v: (r, n)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x.shape
  r, n = v.shape
  if min(m, n, r) < LANE:
    return ref.lowrank_gemm(x, u, v)
  xp = _pad_to(_pad_to(x, 0, SUBLANE), 1, LANE)
  up = _pad_to(_pad_to(u, 0, LANE), 1, LANE)
  vp = _pad_to(_pad_to(v, 0, LANE), 1, LANE)
  blocks = _fit_blocks(
      "lowrank_gemm", {"block_m": xp.shape[1], "block_n": vp.shape[1]},
      {"block_m": block_m, "block_n": block_n})
  y = _lowrank_gemm(xp, up, vp, interpret=interpret, **blocks)
  return y[:b, :n]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def int8_gemm(x_q, w_q, x_scale, w_scale, *, block_m: int | None = None,
              block_n: int | None = None, interpret: bool | None = None):
  """w8a8 GEMM with fused dequant; returns f32 (b, n)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x_q.shape
  n = w_q.shape[1]
  if min(m, n) < LANE:
    return ref.int8_gemm(x_q, w_q, x_scale, w_scale)
  xp = _pad_to(_pad_to(x_q, 0, SUBLANE), 1, LANE)
  wp = _pad_to(_pad_to(w_q, 0, LANE), 1, LANE)
  xsp = _pad_to(x_scale, 0, SUBLANE)
  wsp = _pad_to(w_scale, 0, LANE)
  blocks = _fit_blocks(
      "int8_gemm", {"block_m": xp.shape[1], "block_n": wp.shape[1]},
      {"block_m": block_m, "block_n": block_n})
  y = _int8_gemm(xp, wp, xsp, wsp, interpret=interpret, **blocks)
  return y[:b, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantized_matmul(x: jax.Array, w: jax.Array,
                     interpret: bool | None = None) -> jax.Array:
  """w8a8 entry point: quantize both operands then int8_gemm.

  This is the regime `kernels.dispatch` routes "int8_gemm" overrides on
  FLOAT leaves to. Jitted so the quantize+gemm program is traced once per
  shape instead of re-traced every call. The weight is re-quantized per
  call (O(mn) scan) — a numerics/code-path regime, not a perf one. The
  perf path is `repro.quant`: PTQ'd QuantizedLinear leaves classify into
  int8_gemm by type and consume their stored scales directly with zero
  weight quantize ops (see quant.kernel_apply)."""
  x_q, x_s = ref.quantize_rowwise(x)
  w_q, w_s = ref.quantize_colwise(w)
  return int8_gemm(x_q, w_q, x_s, w_s, interpret=interpret).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def decode_matvec(x, w, *, block_m: int | None = None,
                  block_n: int | None = None,
                  interpret: bool | None = None):
  """Low-batch y = x @ w; x: (b, m) with b <= DECODE_BATCH_MAX, w: (m, n).

  Batches above DECODE_BATCH_MAX are OUTSIDE the kernel's contract (its
  weight-streaming schedule assumes x fits one VMEM tile) and fall back to
  the jnp reference rather than being silently accepted."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, m = x.shape
  n = w.shape[1]
  if b > DECODE_BATCH_MAX or min(m, n) < LANE:
    return ref.decode_matvec(x, w)
  xp = _pad_to(_pad_to(x, 0, SUBLANE), 1, LANE)
  wp = _pad_to(_pad_to(w, 0, LANE), 1, LANE)
  blocks = _fit_blocks(
      "decode_matvec", {"block_m": xp.shape[1], "block_n": wp.shape[1]},
      {"block_m": block_m, "block_n": block_n})
  y = _decode_matvec(xp, wp, interpret=interpret, **blocks)
  return y[:b, :n]


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def gru_cell(xw, h, u, bias, *, block_h: int | None = None,
             interpret: bool | None = None):
  """Fused GRU step; xw: (b, 3H), h: (b, H), u: (H, 3H), bias: (3H,)."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, hidden = h.shape
  if hidden < LANE:
    return ref.gru_cell(xw, h, u, bias)
  blocks = _fit_blocks("gru_cell", {"block_h": hidden},
                       {"block_h": block_h})
  return _gru_cell(xw, h, u, bias, interpret=interpret, **blocks)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int | None = None, block_k: int | None = None,
                    interpret: bool | None = None):
  """q, k, v: (b, s, h, d); GQA callers repeat kv heads first."""
  interpret = (not _on_tpu()) if interpret is None else interpret
  b, s, h, d = q.shape
  if s < SUBLANE or d < LANE:
    return ref.flash_attention(q, k, v, causal=causal)
  blocks = _fit_blocks("flash_attention", {"block_q": s, "block_k": s},
                       {"block_q": block_q, "block_k": block_k})
  return _flash(q, k, v, causal=causal, interpret=interpret, **blocks)
