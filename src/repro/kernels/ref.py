"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the mathematical definition with no tiling — tests sweep
shapes/dtypes and assert the kernels match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_gemm(x: jax.Array, u: jax.Array, v: jax.Array) -> jax.Array:
  """y = (x @ u) @ v, f32 accumulate, output in x.dtype."""
  t = jnp.matmul(x.astype(jnp.float32), u.astype(jnp.float32))
  return jnp.matmul(t, v.astype(jnp.float32)).astype(x.dtype)


def int8_gemm(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
              w_scale: jax.Array) -> jax.Array:
  """y = (x_q @ w_q) * x_scale[:, None] * w_scale[None, :], f32 output.

  x_q: (b, m) int8, row-quantized with x_scale (b,);
  w_q: (m, n) int8, column-quantized with w_scale (n,).
  """
  acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)
  return acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]


def decode_matvec(x: jax.Array, w: jax.Array) -> jax.Array:
  """y = x @ w — the paper's low-batch GEMM (b in 1..16)."""
  return jnp.matmul(x.astype(jnp.float32),
                    w.astype(jnp.float32)).astype(x.dtype)


def gru_cell(xw: jax.Array, h: jax.Array, u: jax.Array,
             bias: jax.Array) -> jax.Array:
  """Fused GRU step (paper eq. 10), given precomputed xw = x @ W_nonrec.

  xw: (b, 3H); h: (b, H); u: (H, 3H) recurrent weight; bias: (3H,).
  Gate order along the 3H axis: [z, r, hcand].
  """
  hidden = h.shape[-1]
  hu = jnp.matmul(h.astype(jnp.float32), u.astype(jnp.float32))
  g = xw.astype(jnp.float32) + hu + bias.astype(jnp.float32)
  gz, gr, gh = (g[:, :hidden], g[:, hidden:2 * hidden], g[:, 2 * hidden:])
  hu_h = hu[:, 2 * hidden:]
  z = jax.nn.sigmoid(gz)
  r = jax.nn.sigmoid(gr)
  hcand = jnp.tanh(gh - hu_h + r * hu_h)
  h1 = (1.0 - z) * h.astype(jnp.float32) + z * hcand
  return h1.astype(h.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True) -> jax.Array:
  """Reference attention. q,k,v: (b, s, h, d) -> (b, s, h, d)."""
  b, s, h, d = q.shape
  sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                  k.astype(jnp.float32)) / (d ** 0.5)
  if causal:
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -jnp.inf)
  p = jax.nn.softmax(sc, axis=-1)
  o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
  return o.astype(q.dtype)


def quantize_rowwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
  """Symmetric per-row int8 quantization: returns (q, scale)."""
  amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
  scale = jnp.maximum(amax, 1e-8) / 127.0
  q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
               -127, 127).astype(jnp.int8)
  return q, scale


def quantize_colwise(w: jax.Array) -> tuple[jax.Array, jax.Array]:
  """Symmetric per-column int8 quantization: returns (q, scale).

  Columns are the last axis; reduction is over the row axis (-2), so a
  layer-stacked (L, m, n) weight quantizes per (layer, column) — each
  scan slice is then an ordinary 2-D quantized operand."""
  amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
  scale = jnp.maximum(amax, 1e-8) / 127.0
  q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[..., None, :]),
               -127, 127).astype(jnp.int8)
  return q, scale


def quantize_static(x: jax.Array, scale: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
  """Symmetric int8 quantization of x (..., m) with a fixed scalar scale
  (a calibrated activation range): returns (q, per-row scales).

  Unlike the dynamic row-wise path, values past the calibrated range
  saturate at +-127 — the standard static-activation-quantization
  trade (one less reduction per step, bounded clipping error)."""
  scale = jnp.maximum(scale.astype(jnp.float32), 1e-8 / 127.0)
  q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
               -127, 127).astype(jnp.int8)
  return q, jnp.broadcast_to(scale, x.shape[:-1])
