"""Pallas TPU kernels for the paper's compute hot-spots.

  lowrank_gemm    — fused (x @ U) @ V, rank intermediate in VMEM (paper §3)
  int8_gemm       — w8a8 + fused per-channel dequant (paper §4, gemmlowp)
  decode_matvec   — low-batch weight-streaming GEMM (paper §4, farm)
  gru_cell        — recurrent GEMM + gate fusion (paper eq. 10)
  flash_attention — blockwise online softmax (assigned archs' 32k shapes)

Validated in interpret=True mode against kernels/ref.py oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (decode_matvec, flash_attention, gru_cell,
                               int8_gemm, lowrank_gemm, quantized_matmul)

__all__ = ["ops", "ref", "decode_matvec", "flash_attention", "gru_cell",
           "int8_gemm", "lowrank_gemm", "quantized_matmul"]
