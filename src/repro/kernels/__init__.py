"""Pallas TPU kernels for the paper's compute hot-spots.

  lowrank_gemm    — fused (x @ U) @ V, rank intermediate in VMEM (paper §3)
  int8_gemm       — w8a8 + fused per-channel dequant (paper §4, gemmlowp)
  decode_matvec   — low-batch weight-streaming GEMM (paper §4, farm)
  gru_cell        — recurrent GEMM + gate fusion (paper eq. 10)
  flash_attention — blockwise online softmax (assigned archs' 32k shapes)

`dispatch.KernelPolicy` is the execution surface that routes model GEMMs
to these kernels; model/serving code threads a policy like it threads
`cs`. Validated in interpret=True mode against kernels/ref.py oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (decode_matvec, flash_attention, gru_cell,
                               int8_gemm, lowrank_gemm, quantized_matmul)
from repro.kernels import dispatch
from repro.kernels.dispatch import (KernelPolicy, decode_policy,
                                    record_dispatch)

__all__ = ["ops", "ref", "dispatch", "decode_matvec", "flash_attention",
           "gru_cell", "int8_gemm", "lowrank_gemm", "quantized_matmul",
           "KernelPolicy", "decode_policy", "record_dispatch"]
