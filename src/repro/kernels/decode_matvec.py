"""Skinny-GEMM decode kernel: y = x @ W for batch 1..16.

The direct TPU analogue of the paper's `farm` ARM kernels: low-batch GEMM
is memory-bandwidth bound (arithmetic intensity ~ batch), so the kernel's
job is to stream W from HBM exactly once at full bandwidth. The activation
x stays resident in VMEM across the whole grid; W is visited tile by tile
in (n-outer, m-inner) order; each weight tile is fetched exactly once.

Versus the paper: NEON register blocking becomes (8, 128)-aligned VMEM
blocks, and gemmlowp's u8 offset trick is unnecessary (see int8_gemm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, y_ref, acc_ref, *, nm: int):
  j = pl.program_id(1)

  @pl.when(j == 0)
  def _init():
    acc_ref[...] = jnp.zeros_like(acc_ref)

  acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                          w_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

  @pl.when(j == nm - 1)
  def _emit():
    y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def decode_matvec(x: jax.Array, w: jax.Array, *, block_m: int = 1024,
                  block_n: int = 256, interpret: bool = False) -> jax.Array:
  """x: (b, m) with small b; w: (m, n) -> y: (b, n)."""
  b, m = x.shape
  n = w.shape[1]
  bm = min(block_m, m)
  bn = min(block_n, n)
  assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
  nm, nn = m // bm, n // bn

  return pl.pallas_call(
      functools.partial(_kernel, nm=nm),
      grid=(nn, nm),
      in_specs=[
          pl.BlockSpec((b, bm), lambda i, j: (0, j)),
          pl.BlockSpec((bm, bn), lambda i, j: (j, i)),
      ],
      out_specs=pl.BlockSpec((b, bn), lambda i, j: (0, i)),
      out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
      scratch_shapes=[pltpu.VMEM((b, bn), jnp.float32)],
      interpret=interpret,
  )(x, w)
