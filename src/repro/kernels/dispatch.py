"""KernelPolicy — the execution-policy surface routing GEMMs to Pallas.

The kernel-side sibling of the `cs` sharding constraint (PR 1): model code
threads one `policy` object through its layers exactly like `cs`, and every
GEMM call site (`layers.common.gemm`, `FactoredLinear.apply`, the GRU step)
consults it. The policy classifies each matmul by *regime*:

  decode_matvec — unfactored weight, flattened batch <= decode_batch_max
                  (the paper's §4 low-batch serving regime; a speculative
                  verify window counts as batch x window rows — see
                  `decode_policy(window=...)`)
  lowrank_gemm  — factored W = UV leaf -> fused (x @ U) @ V, rank
                  intermediate in VMEM (paper §3)
  int8_gemm     — w8a8. A pre-quantized leaf (repro.quant's
                  QuantizedLinear) classifies here by type: the kernel
                  consumes its stored int8 weights + per-column scales
                  directly, zero weight quantize ops in the traced step.
                  A per-name override on a float leaf still works (via
                  `ops.quantized_matmul`, which re-quantizes per call —
                  a numerics/code-path regime, not a perf one)
  gru_cell      — recurrent step fusion (paper eq. 10), routed by
                  `maybe_gru_cell` from layers/gru
  jnp           — everything else / degenerate shapes: the exact
                  `acc_dtype`-policy matmul the framework always ran

Per-name overrides use the same logical-name namespace that
`FactorizationPlan` and `dist.sharding.PARAM_RULES` match on ("gru0/rec",
"layers/attn_q", ...), first glob wins. The default policy is `jnp_only`:
passing no policy (or `KernelPolicy()`) reproduces current numerics
bit-for-bit, so training and eval are untouched unless a caller opts in.

Classification happens at trace time (shapes are static under jit), so a
decode-regime policy makes `LMEngine.decode_step` / the DS2 frame step
*lower through* the Pallas kernels — `record_dispatch()` captures the
routing decisions of any tracing that happens inside it, which is how the
serving tests assert the kernels are actually on the hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import itertools
import math
from typing import Optional

import jax
import numpy as np

from repro.core.factored import FactoredLinear, matmul_ref
from repro.kernels import ops

#: every regime a policy (or override) may name
REGIMES = ("jnp", "decode_matvec", "lowrank_gemm", "int8_gemm", "gru_cell")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
  """Which kernel each GEMM regime lowers to. Hashable and static under jit.

  mode:
    "jnp_only" — every call site takes the plain jnp path (the default;
      exact current numerics, training untouched).
    "decode"   — shape-specialized routing: factored leaves -> lowrank_gemm,
      small-batch unfactored GEMMs -> decode_matvec, recurrent steps ->
      gru_cell; degenerate shapes (any dim < MXU lane) -> jnp.

  overrides: ((glob, regime), ...) over logical GEMM names, first match
    wins, consulted before the shape rules — e.g. (("*/rec", "jnp"),) pins
    recurrent weights to jnp, (("fc", "int8_gemm"),) serves one layer w8a8.
    Overrides still respect the degenerate-shape gate.
  decode_batch_max: largest flattened batch routed to decode_matvec
    (the kernel's documented contract; `ops.DECODE_BATCH_MAX`).
  interpret: forwarded to the Pallas wrappers (None = auto: interpret
    everywhere but TPU — the CPU test default).
  """
  mode: str = "jnp_only"
  decode_batch_max: int = ops.DECODE_BATCH_MAX
  overrides: tuple = ()
  interpret: Optional[bool] = None

  def __post_init__(self):
    if self.mode not in ("jnp_only", "decode"):
      raise ValueError(f"unknown KernelPolicy mode: {self.mode!r}")
    if not 1 <= self.decode_batch_max <= ops.DECODE_BATCH_MAX:
      # classify() promises the returned regime is the kernel that runs;
      # a bound past the kernel's own contract would make it lie
      raise ValueError(
          f"decode_batch_max must be in [1, {ops.DECODE_BATCH_MAX}], got "
          f"{self.decode_batch_max}")
    for pat, regime in self.overrides:
      if regime not in REGIMES:
        raise ValueError(f"override {pat!r} names unknown regime {regime!r}")

  def override_for(self, name: Optional[str]) -> Optional[str]:
    if name is None:
      return None
    for pat, regime in self.overrides:
      if fnmatch.fnmatch(name, pat):
        return regime
    return None


JNP_ONLY = KernelPolicy()


def decode_policy(batch_size: Optional[int] = None, *, window: int = 1,
                  overrides: tuple = (),
                  interpret: Optional[bool] = None) -> KernelPolicy:
  """The serving-engine policy: route the decode regime through Pallas.

  `batch_size` (the engine's request batch) NARROWS decode_matvec's batch
  bound to min(16, batch_size): a per-step decode GEMM has flattened
  batch == batch_size, so anything wider (e.g. a projection batched
  across time) is not the decode regime and stays on jnp.

  `window` (speculative verification) widens the bound to cover a fused
  window step: verifying w = k+1 draft positions may flatten batch x w
  rows into one GEMM, which is still the paper's low-batch serving
  regime as long as b*w fits the kernel's contract. The bound therefore
  becomes min(16, batch_size * window) — the kernel's 16-row contract is
  never widened, so an oversized b*w window simply stays on jnp. This is
  live routing: ModelApi.decode_window now runs each family's batched
  window forward, whose non-recurrent GEMMs flatten b*w rows and
  classify here (pinned by the parity grid in
  tests/test_spec_window_parity.py, which runs both policies).
  """
  bmax = ops.DECODE_BATCH_MAX
  if batch_size is not None:
    bmax = min(bmax, max(1, batch_size) * max(1, window))
  return KernelPolicy(mode="decode", decode_batch_max=bmax,
                      overrides=tuple(overrides), interpret=interpret)


def resolve_policy(policy, batch_size: Optional[int] = None, *,
                   window: int = 1) -> Optional[KernelPolicy]:
  """Accept a KernelPolicy, a mode string, or None (engine convenience)."""
  if policy is None or isinstance(policy, KernelPolicy):
    return policy
  if policy in ("jnp", "jnp_only"):
    return JNP_ONLY
  if policy in ("pallas", "decode"):
    return decode_policy(batch_size, window=window)
  raise ValueError(f"unknown kernel policy: {policy!r}")


# ---------------------------------------------------------------------------
# Trace-time instrumentation.
# ---------------------------------------------------------------------------

class DispatchRecord(tuple):
  """A recorded dispatch decision. Equals (and unpacks as) the historical
  `(logical_name, regime)` pair, with one extra attribute: `call_id`, the
  serial of the `dispatch:{regime}:c{call_id}` named scope that `gemm()`
  wrapped the routed computation in. The analysis auditor joins traced
  `dot_general` name stacks back to these records through that id."""

  def __new__(cls, name: str, regime: str, call_id: int):
    self = tuple.__new__(cls, (name, regime))
    self.call_id = call_id
    return self

  @property
  def name(self) -> str:
    return self[0]

  @property
  def regime(self) -> str:
    return self[1]


_RECORDERS: list = []
_CALL_IDS = itertools.count()


def _remove_by_identity(stack: list, item) -> None:
  # list.remove compares by ==; two independent empty logs are equal, so a
  # nested context could pop its *parent's* log. Scan for identity instead.
  for i in range(len(stack) - 1, -1, -1):
    if stack[i] is item:
      del stack[i]
      return


@contextlib.contextmanager
def record_dispatch():
  """Capture a DispatchRecord — `(logical_name, regime)` plus a
  `.call_id` correlating it to the `dispatch:...` named scope in the
  traced program — for every dispatch decision traced inside the context.
  Decisions happen at trace time, so build/trace the jitted step *inside*
  the context (jit caches skip re-tracing; see `clear_jit_caches`).
  Reentrant: contexts nest and unwind correctly under exceptions."""
  log: list = []
  _RECORDERS.append(log)
  try:
    yield log
  finally:
    _remove_by_identity(_RECORDERS, log)


def _record(name: Optional[str], regime: str) -> int:
  cid = next(_CALL_IDS)
  for log in _RECORDERS:
    log.append(DispatchRecord(name or "<unnamed>", regime, cid))
  return cid


_OBSERVERS: list = []
_MOMENT_OBSERVERS: list = []
_CAL_LAYER: list = []


@contextlib.contextmanager
def observe_gemm_inputs():
  """Capture {logical name: max |x| seen} for every GEMM routed through
  `gemm()` inside the context — the activation-range tap
  `repro.quant.calibrate_activation_ranges` builds on. Eager-only:
  traced activations (inside jit / lax.scan) are skipped, since their
  values don't exist at trace time.

  Under a `calibration_layer(i)` context the key becomes "name@L{i}":
  scan-stacked (L, m, n) leaves share one logical name across layers,
  and without the tag their per-layer statistics would silently
  aggregate (the PR 4 tap's blind spot)."""
  log: dict = {}
  _OBSERVERS.append(log)
  try:
    yield log
  finally:
    _remove_by_identity(_OBSERVERS, log)


@contextlib.contextmanager
def calibration_layer(index: int):
  """Tag every GEMM observed inside as belonging to scan layer `index`.

  Whisper's encoder layers are vmap-initialized into stacked (L, m, n)
  leaves that all carry the same logical name; an eager layer-unrolled
  forward (models.whisper.encode_unrolled) wraps each layer's block in
  this context so observers key its activations "name@L{index}" instead
  of collapsing all layers onto one entry. Nesting keeps the innermost
  index (a layer inside a layer names the leaf it actually feeds)."""
  _CAL_LAYER.append(int(index))
  try:
    yield
  finally:
    _CAL_LAYER.pop()


@contextlib.contextmanager
def observe_gemm_moments():
  """Capture per-GEMM input *second moments* for activation-calibrated
  low-rank truncation (LiteASR, arXiv 2502.20583): for every eagerly
  observed GEMM input x (rows flattened to (N, m)) accumulate

      {key: {"xtx": sum_n x_n x_n^T  (m, m) float64,
             "count": N rows seen, "amax": max |x|}}

  keyed like `observe_gemm_inputs` (including the "@L{i}" layer tag).
  E[x x^T] = xtx / count is the Gram matrix `core.svd.activation_split`
  whitens with. Eager-only, like the amax tap."""
  log: dict = {}
  _MOMENT_OBSERVERS.append(log)
  try:
    yield log
  finally:
    _remove_by_identity(_MOMENT_OBSERVERS, log)


def clear_jit_caches() -> None:
  """Drop every cached jit compilation/trace so the next call re-traces.

  Dispatch decisions (and their correlation scopes) are only emitted when
  a program actually traces; a warm jit cache silently replays the old
  program. Auditors call this before tracing so `record_dispatch` sees
  the program as it lowers *now*, not as it lowered earlier."""
  jax.clear_caches()


def _obs_key(name: Optional[str]) -> str:
  key = name or "<unnamed>"
  if _CAL_LAYER:
    key = f"{key}@L{_CAL_LAYER[-1]}"
  return key


def _observe(name: Optional[str], x: jax.Array) -> None:
  if (not _OBSERVERS and not _MOMENT_OBSERVERS) \
      or isinstance(x, jax.core.Tracer):
    return
  key = _obs_key(name)
  amax = float(jax.numpy.max(jax.numpy.abs(x.astype(jax.numpy.float32))))
  for log in _OBSERVERS:
    log[key] = max(log.get(key, 0.0), amax)
  if _MOMENT_OBSERVERS:
    rows = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
    xtx = rows.T @ rows
    for log in _MOMENT_OBSERVERS:
      ent = log.get(key)
      if ent is None:
        log[key] = {"xtx": xtx.copy(), "count": rows.shape[0],
                    "amax": amax}
      else:
        ent["xtx"] += xtx
        ent["count"] += rows.shape[0]
        ent["amax"] = max(ent["amax"], amax)


# ---------------------------------------------------------------------------
# Classification.
# ---------------------------------------------------------------------------

def _flat_batch(x: jax.Array) -> int:
  return math.prod(x.shape[:-1]) if x.ndim > 1 else 1


def _is_quantized(leaf) -> bool:
  # lazy: repro.quant imports this module (observer + compress plan), so
  # the leaf type can't be imported at dispatch's module level
  from repro.quant.leaf import QuantizedLinear
  return isinstance(leaf, QuantizedLinear)


def classify(leaf, x: jax.Array, policy: Optional[KernelPolicy],
             name: Optional[str] = None) -> str:
  """Pick the regime for one GEMM. Pure shape/metadata logic (trace-time).

  Mirrors the degenerate-shape gates of kernels/ops so the returned regime
  is the kernel that actually executes, never an optimistic label. The
  one nuance: a pre-quantized leaf is ALWAYS the int8_gemm regime — for
  sub-LANE shapes the ops wrapper runs the int8 ref oracle, which is the
  same w8a8 arithmetic, so the label stays truthful about the math."""
  if policy is None or policy.mode == "jnp_only":
    return "jnp"
  if name is None:
    name = getattr(leaf, "name", None)
  if _is_quantized(leaf):
    # quantized storage classifies by TYPE, not by shape or override:
    # there is no float weight to run any other regime on. An explicit
    # "jnp" override still works — the reference path for a quantized
    # leaf is its own w8a8 oracle (leaf.apply), identical arithmetic.
    return "jnp" if policy.override_for(name) == "jnp" else "int8_gemm"
  factored = isinstance(leaf, FactoredLinear) and leaf.is_factored
  regime = policy.override_for(name)
  if regime == "gru_cell":
    # the gru_cell regime only exists at the recurrent-step call site
    # (maybe_gru_cell); at a plain GEMM site the override means "don't
    # special-case", i.e. the reference path
    regime = "jnp"
  if regime is None:
    if factored:
      regime = "lowrank_gemm"
    elif _flat_batch(x) <= policy.decode_batch_max:
      regime = "decode_matvec"
    else:
      regime = "jnp"
  # degenerate-shape gates (identical to the ops wrappers' LANE checks)
  if regime == "lowrank_gemm":
    if not factored or leaf.u.ndim != 2 or \
        min(leaf.u.shape[-2], leaf.u.shape[-1], leaf.v.shape[-1]) < ops.LANE:
      regime = "jnp"
  elif regime in ("decode_matvec", "int8_gemm"):
    w = leaf.w if isinstance(leaf, FactoredLinear) else leaf
    if factored or w is None or w.ndim != 2 or \
        min(w.shape) < ops.LANE or \
        (regime == "decode_matvec" and
         _flat_batch(x) > policy.decode_batch_max):
      regime = "jnp"
  return regime


# ---------------------------------------------------------------------------
# The GEMM entry point.
# ---------------------------------------------------------------------------

def _jnp_gemm(leaf, x: jax.Array) -> jax.Array:
  if isinstance(leaf, FactoredLinear) or _is_quantized(leaf):
    return leaf.apply(x)
  return matmul_ref(x, leaf)


def gemm(leaf, x: jax.Array, policy: Optional[KernelPolicy],
         name: Optional[str] = None) -> jax.Array:
  """y[..., n] = x[..., m] @ W(m, n), routed by `policy`.

  `layers.common.gemm` and `FactoredLinear.apply` both land here whenever a
  policy is passed; with policy None / jnp_only this IS the historical jnp
  path (same code object), so default numerics are unchanged."""
  regime = classify(leaf, x, policy, name)
  cid = _record(name or getattr(leaf, "name", None), regime)
  _observe(name or getattr(leaf, "name", None), x)
  # The named scope is the trace-side half of the correlation: every op
  # lowered for this routed GEMM carries "dispatch:{regime}:c{cid}" in its
  # name stack, and the DispatchRecord with the same cid carries the
  # logical name + regime. repro.analysis joins the two to prove no
  # dot_general in a decode trace bypassed this function.
  with jax.named_scope(f"dispatch:{regime}:c{cid}"):
    if regime == "jnp":
      return _jnp_gemm(leaf, x)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if regime == "lowrank_gemm":
      y = ops.lowrank_gemm(x2, leaf.u, leaf.v, interpret=policy.interpret)
    elif regime == "decode_matvec":
      w = leaf.w if isinstance(leaf, FactoredLinear) else leaf
      y = ops.decode_matvec(x2, w, interpret=policy.interpret)
    elif regime == "int8_gemm":
      if _is_quantized(leaf):
        # pre-quantized storage: stored int8 weights + scales consumed
        # directly (the serving win); only activations quantize per call
        from repro.quant.leaf import kernel_apply
        y = kernel_apply(leaf, x2, interpret=policy.interpret)
      else:
        w = leaf.w if isinstance(leaf, FactoredLinear) else leaf
        y = ops.quantized_matmul(x2, w, interpret=policy.interpret)
    else:  # pragma: no cover — REGIMES is closed above
      raise ValueError(f"unroutable regime {regime!r}")
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


# ---------------------------------------------------------------------------
# The recurrent-step entry point (layers/gru).
# ---------------------------------------------------------------------------

def maybe_gru_cell(xw: jax.Array, h: jax.Array, rec, bias: jax.Array,
                   policy: Optional[KernelPolicy]) -> Optional[jax.Array]:
  """Route one GRU step to the fused kernel, or return None to decline
  (caller falls back to the reference gate math, whose inner recurrent
  GEMM still consults the policy)."""
  if policy is None or policy.mode == "jnp_only":
    return None
  name = getattr(rec, "name", None)
  override = policy.override_for(name)
  if override is not None and override != "gru_cell":
    return None
  unfactored = isinstance(rec, FactoredLinear) and not rec.is_factored \
      and rec.w.ndim == 2
  if not unfactored or h.shape[-1] < ops.LANE:
    # no _record here: the caller's fallback routes the recurrent GEMM
    # through gemm(), which records the real decision for this name
    return None
  cid = _record(name, "gru_cell")
  with jax.named_scope(f"dispatch:gru_cell:c{cid}"):
    return ops.gru_cell(xw, h, rec.w, bias, interpret=policy.interpret)
