"""Blockwise online-softmax (flash) attention kernel.

Not a paper contribution — needed so the assigned archs' 32k prefill never
materializes an S x S score matrix on the TPU target. Matches the jnp
blocking in layers/attention.py (which is its oracle).

Grid: (b*h, nq, nk) with kv innermost. Running (m, l, acc) live in VMEM
scratch across kv steps; causal tiles with kv_start > q_end are skipped
via pl.when (they still occupy grid slots but do no MXU work — the wedge
variant in layers/attention.py removes them statically for the XLA path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, scale: float, causal: bool):
  qi = pl.program_id(1)
  kj = pl.program_id(2)

  @pl.when(kj == 0)
  def _init():
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

  run = (not causal) or (kj * bk <= qi * bq + bq - 1)

  @pl.when(run)
  def _tile():
    q = q_ref[0].astype(jnp.float32)                   # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
      qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
      kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
      s = jnp.where(kpos <= qpos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

  @pl.when(kj == nk - 1)
  def _emit():
    l = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
  """q, k, v: (b, s, h, d) with h == kv heads (GQA pre-repeated) -> same."""
  b, s, h, d = q.shape
  bq = min(block_q, s)
  bk = min(block_k, s)
  assert s % bq == 0 and s % bk == 0, (s, bq, bk)
  nq, nk = s // bq, s // bk
  scale = 1.0 / (d ** 0.5)

  # (b, s, h, d) -> (b*h, s, d) so one grid axis covers batch x heads
  qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
  kt = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
  vt = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)

  out = pl.pallas_call(
      functools.partial(_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                        causal=causal),
      grid=(b * h, nq, nk),
      in_specs=[
          pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
          pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
          pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
      ],
      out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
      out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
      scratch_shapes=[
          pltpu.VMEM((bq,), jnp.float32),
          pltpu.VMEM((bq,), jnp.float32),
          pltpu.VMEM((bq, d), jnp.float32),
      ],
      interpret=interpret,
  )(qt, kt, vt)
  return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
