"""w8a8 int8 GEMM with int32 MXU accumulation + fused per-channel dequant.

The TPU replacement for gemmlowp's u8 path (paper §4): the MXU consumes
signed s8 x s8 -> s32 natively, so symmetric per-channel quantization needs
no zero-point correction GEMM. Dequantization (x_scale[b] * w_scale[n])
happens in-register before the single f32 store — the int32 accumulator
never touches HBM.

Grid: (nn, nm) with the m (contracting) dimension innermost; the int32
accumulator tile lives in VMEM scratch and is dequantized+flushed on the
last m step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, y_ref, acc_ref, *, nm: int):
  j = pl.program_id(1)

  @pl.when(j == 0)
  def _init():
    acc_ref[...] = jnp.zeros_like(acc_ref)

  acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.int32),
                          w_ref[...].astype(jnp.int32),
                          preferred_element_type=jnp.int32)

  @pl.when(j == nm - 1)
  def _dequant():
    y_ref[...] = (acc_ref[...].astype(jnp.float32) *
                  xs_ref[...].astype(jnp.float32)[:, None] *
                  ws_ref[...].astype(jnp.float32)[None, :])


def int8_gemm(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
              w_scale: jax.Array, *, block_m: int = 512, block_n: int = 512,
              interpret: bool = False) -> jax.Array:
  """x_q: (b, m) s8; w_q: (m, n) s8; x_scale: (b,); w_scale: (n,) -> f32."""
  b, m = x_q.shape
  n = w_q.shape[1]
  bm = min(block_m, m)
  bn = min(block_n, n)
  assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
  nm, nn = m // bm, n // bn

  return pl.pallas_call(
      functools.partial(_kernel, nm=nm),
      grid=(nn, nm),
      in_specs=[
          pl.BlockSpec((b, bm), lambda i, j: (0, j)),
          pl.BlockSpec((bm, bn), lambda i, j: (j, i)),
          pl.BlockSpec((b,), lambda i, j: (0,)),
          pl.BlockSpec((bn,), lambda i, j: (i,)),
      ],
      out_specs=pl.BlockSpec((b, bn), lambda i, j: (0, i)),
      out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
      scratch_shapes=[pltpu.VMEM((b, bn), jnp.int32)],
      interpret=interpret,
  )(x_q, w_q, x_scale, w_scale)
