"""Fused low-rank GEMM: y = (x @ U) @ V with the rank-r intermediate held
in VMEM scratch — it never round-trips HBM.

This is the TPU-native form of the paper's factored inference GEMM: in the
low-batch regime the win is streaming r(m+n) weight bytes instead of mn,
and fusing the two skinny GEMMs removes the (B, r) HBM round-trip and the
second kernel launch.

Grid: (nm + nn,) — the first nm steps accumulate t = x @ U over m-tiles
into scratch; the remaining nn steps emit y n-tiles from t @ V. The output
block index stays 0 during phase 1, so nothing is flushed until the first
real write. Block shapes are (8, 128)-aligned by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, u_ref, v_ref, y_ref, t_ref, *, nm: int):
  i = pl.program_id(0)

  @pl.when(i == 0)
  def _init():
    t_ref[...] = jnp.zeros_like(t_ref)

  @pl.when(i < nm)
  def _accumulate():
    t_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                          u_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

  @pl.when(i >= nm)
  def _emit():
    y_ref[...] = jnp.dot(t_ref[...], v_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32
                         ).astype(y_ref.dtype)


def lowrank_gemm(x: jax.Array, u: jax.Array, v: jax.Array, *,
                 block_m: int = 512, block_n: int = 512,
                 interpret: bool = False) -> jax.Array:
  """x: (b, m), u: (m, r), v: (r, n) -> (b, n). Dims pre-padded by ops."""
  b, m = x.shape
  r = u.shape[1]
  n = v.shape[1]
  bm = min(block_m, m)
  bn = min(block_n, n)
  assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)
  nm, nn = m // bm, n // bn

  return pl.pallas_call(
      functools.partial(_kernel, nm=nm),
      grid=(nm + nn,),
      in_specs=[
          pl.BlockSpec((b, bm), lambda i: (0, jnp.minimum(i, nm - 1))),
          pl.BlockSpec((bm, r), lambda i: (jnp.minimum(i, nm - 1), 0)),
          pl.BlockSpec((r, bn), lambda i: (0, jnp.maximum(i - nm, 0))),
      ],
      out_specs=pl.BlockSpec((b, bn), lambda i: (0, jnp.maximum(i - nm, 0))),
      out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
      scratch_shapes=[pltpu.VMEM((b, r), jnp.float32)],
      interpret=interpret,
  )(x, u, v)
