"""Training loop: microbatched grad accumulation, the paper's two-stage
schedule (stage-1 trace-norm training -> truncated-SVD warmstart ->
stage-2 fine-tune), trace-norm diagnostics, checkpoint/restart.

The step function is a single jit containing fwd+bwd (scanned over
microbatches), the regularizer, and the optimizer update — the same
program the dry-run lowers for the production mesh. Stage transitions
re-jit (params change structure: full-rank factored -> truncated).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.compress import FactorizationPlan, to_stage1, to_stage2
from repro.core.schedule import TwoStageSchedule
from repro.core.tracenorm import (RegularizerConfig, regularization_loss,
                                  trace_norm_metrics)
from repro.dist.sharding import (Constraint, identity_constraint,
                                 make_constraint)
from repro.layers.common import ModelConfig
from repro.models.api import ModelApi, get_model
from repro.optim import AdamWConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
  lr: Callable[[jax.Array], jax.Array] | float = 1e-3
  optimizer: str = "adamw"
  adam: AdamWConfig = AdamWConfig(max_grad_norm=1.0)
  microbatches: int = 1
  regularizer: RegularizerConfig = RegularizerConfig()
  checkpoint_dir: Optional[str] = None
  checkpoint_every: int = 0          # steps; 0 = off
  async_checkpoint: bool = True


def _lr_at(lr, step):
  return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig,
                    api: Optional[ModelApi] = None,
                    cs: Constraint = identity_constraint,
                    reg: Optional[RegularizerConfig] = None,
                    donate: bool = True):
  """Build the jitted (params, opt_state, batch, step) -> ... function."""
  api = api or get_model(model_cfg)
  reg = train_cfg.regularizer if reg is None else reg
  opt_init, opt_apply = make_optimizer(train_cfg.optimizer)

  def loss_fn(params, batch):
    loss, metrics = api.loss_fn(params, batch, model_cfg, cs)
    if reg.kind != "none":
      r = regularization_loss(params, reg)
      metrics = dict(metrics, reg=r)
      loss = loss + r
    return loss, metrics

  def grads_of(params, batch):
    k = train_cfg.microbatches
    if k <= 1:
      (loss, metrics), grads = jax.value_and_grad(
          loss_fn, has_aux=True)(params, batch)
      return loss, metrics, grads
    # microbatch accumulation: scan over k slices of the leading dim
    def slice_mb(x, i):
      mb = x.shape[0] // k
      return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    def body(carry, i):
      acc_loss, acc_g = carry
      mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
      (loss, metrics), g = jax.value_and_grad(
          loss_fn, has_aux=True)(params, mb)
      acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                           acc_g, g)
      return (acc_loss + loss, acc_g), metrics
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, gsum), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_g), jnp.arange(k))
    grads = jax.tree.map(lambda g: g / k, gsum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / k, metrics, grads

  def step_fn(params, opt_state, batch, step):
    loss, metrics, grads = grads_of(params, batch)
    lr = _lr_at(train_cfg.lr, step)
    params, opt_state, opt_metrics = opt_apply(
        params, grads, opt_state, lr, train_cfg.adam)
    metrics = dict(metrics, loss=loss, lr=lr, **opt_metrics)
    return params, opt_state, metrics

  return opt_init, jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())


class Trainer:
  """Drives make_train_step with the two-stage schedule + checkpoints."""

  def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig, *,
               schedule: Optional[TwoStageSchedule] = None,
               plan: Optional[FactorizationPlan] = None,
               mesh=None, batch_size: int = 0, rng=None):
    self.model_cfg = model_cfg
    self.train_cfg = train_cfg
    self.schedule = schedule
    self.plan = plan or FactorizationPlan()
    self.api = get_model(model_cfg)
    self.cs = make_constraint(mesh, model_cfg, batch_size)
    rng = jax.random.PRNGKey(0) if rng is None else rng
    params = self.api.init(rng, model_cfg)
    if schedule is not None and schedule.regularizer.kind == "trace":
      params = to_stage1(params, self.plan)     # full-rank factored form
    self.params = params
    self.step = 0
    self.stage = 1 if schedule is not None else 0
    self._lr_scale = 1.0
    self._build(reg=self._current_reg())
    self.opt_state = self._opt_init(self.params)
    self.ckpt = (CheckpointManager(train_cfg.checkpoint_dir)
                 if train_cfg.checkpoint_dir else None)
    self.metrics_history: list[dict] = []

  def _current_reg(self) -> RegularizerConfig:
    if self.schedule is None:
      return self.train_cfg.regularizer
    return self.schedule.regularizer_at(self.step)

  def _build(self, reg: RegularizerConfig) -> None:
    tc = self.train_cfg
    if self._scaled_lr() is not tc.lr:
      tc = dataclasses.replace(tc, lr=self._scaled_lr())
    self._opt_init, self._step_fn = make_train_step(
        self.model_cfg, tc, self.api, self.cs, reg=reg)

  def _scaled_lr(self):
    base = self.train_cfg.lr
    if self._lr_scale == 1.0:
      return base
    if callable(base):
      return lambda s: base(s) * self._lr_scale
    return base * self._lr_scale

  # -- two-stage transition ---------------------------------------------------

  def maybe_transition(self) -> bool:
    """Stage-1 -> stage-2 at the schedule's transition step (paper §3.2.3)."""
    if (self.schedule is None or self.stage != 1 or
        self.step < self.schedule.transition_step):
      return False
    self.params = to_stage2(self.params, self.plan,
                            self.schedule.truncation)
    self.stage = 2
    self._lr_scale = self.schedule.stage2_lr_scale()
    self._build(reg=RegularizerConfig(kind="none"))
    self.opt_state = self._opt_init(self.params)   # moments reset: shapes changed
    return True

  # -- stepping ---------------------------------------------------------------

  def train_step(self, batch: dict) -> dict:
    self.maybe_transition()
    t0 = time.perf_counter()
    self.params, self.opt_state, metrics = self._step_fn(
        self.params, self.opt_state, batch, jnp.asarray(self.step))
    metrics = {k: float(v) for k, v in metrics.items()}
    metrics["step"] = self.step
    metrics["stage"] = self.stage
    metrics["wall_s"] = time.perf_counter() - t0
    self.metrics_history.append(metrics)
    self.step += 1
    if (self.ckpt and self.train_cfg.checkpoint_every and
        self.step % self.train_cfg.checkpoint_every == 0):
      self.save()
    return metrics

  def tracenorm_report(self) -> dict:
    """SVD diagnostics (nu, trace norm, rank90) per factored GEMM."""
    return {k: {kk: float(vv) for kk, vv in m.items()}
            for k, m in trace_norm_metrics(self.params).items()}

  # -- checkpointing ----------------------------------------------------------

  def save(self, blocking: Optional[bool] = None) -> None:
    if self.ckpt is None:
      return
    blocking = (not self.train_cfg.async_checkpoint
                if blocking is None else blocking)
    self.ckpt.save(self.step, {"params": self.params,
                               "opt": self.opt_state},
                   extra={"step": self.step, "stage": self.stage},
                   blocking=blocking)

  def restore(self, step: Optional[int] = None) -> None:
    if self.ckpt is None:
      raise ValueError("no checkpoint dir configured")
    self.ckpt.wait()
    template = {"params": self.params, "opt": self.opt_state}
    tree, extra = self.ckpt.restore(template, step=step)
    self.params = tree["params"]
    self.opt_state = tree["opt"]
    self.step = int(extra.get("step", 0))
    self.stage = int(extra.get("stage", self.stage))
