"""Training: microbatched step builder + two-stage Trainer."""
from repro.training.trainer import TrainConfig, Trainer, make_train_step

__all__ = ["TrainConfig", "Trainer", "make_train_step"]
