"""CTC loss in pure JAX (log-space forward algorithm via lax.scan).

The DS2 reproduction's loss. Blank id = 0. Handles padded logit frames and
padded label sequences via lengths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def ctc_loss(log_probs: jax.Array, logit_lengths: jax.Array,
             labels: jax.Array, label_lengths: jax.Array,
             blank: int = 0) -> jax.Array:
  """Mean negative log likelihood.

  log_probs: (b, t, v) log-softmaxed; logit_lengths: (b,);
  labels: (b, l) padded with anything; label_lengths: (b,).
  """
  b, t, v = log_probs.shape
  l = labels.shape[1]
  s = 2 * l + 1   # extended sequence: blank label blank label ... blank

  # extended labels: ext[2i] = blank, ext[2i+1] = labels[i]
  ext = jnp.full((b, s), blank, jnp.int32)
  ext = ext.at[:, 1::2].set(labels.astype(jnp.int32))
  ext_valid = jnp.arange(s)[None, :] < (2 * label_lengths[:, None] + 1)

  # transitions: from j-1 always; from j-2 only if ext[j] != blank and
  # ext[j] != ext[j-2]
  ext_prev2 = jnp.concatenate([jnp.full((b, 2), -1, jnp.int32),
                               ext[:, :-2]], axis=1)
  allow_skip = (ext != blank) & (ext != ext_prev2)

  alpha0 = jnp.full((b, s), NEG)
  alpha0 = alpha0.at[:, 0].set(log_probs[:, 0, blank])
  first_lab = jnp.take_along_axis(
      log_probs[:, 0], ext[:, 1:2], axis=1)[:, 0]
  alpha0 = alpha0.at[:, 1].set(jnp.where(label_lengths > 0, first_lab, NEG))

  def step(alpha, inp):
    lp_t, t_idx = inp                               # (b, v), scalar
    stay = alpha
    prev1 = jnp.concatenate([jnp.full((b, 1), NEG), alpha[:, :-1]], axis=1)
    prev2 = jnp.concatenate([jnp.full((b, 2), NEG), alpha[:, :-2]], axis=1)
    prev2 = jnp.where(allow_skip, prev2, NEG)
    merged = jnp.logaddexp(jnp.logaddexp(stay, prev1), prev2)
    emit = jnp.take_along_axis(lp_t, ext, axis=1)   # (b, s)
    new = merged + emit
    new = jnp.where(ext_valid, new, NEG)
    # frames beyond logit_lengths: freeze alpha
    active = (t_idx < logit_lengths)[:, None]
    new = jnp.where(active, new, alpha)
    return new, None

  alpha, _ = jax.lax.scan(
      step, alpha0, (log_probs.transpose(1, 0, 2)[1:], jnp.arange(1, t)))

  # final: alpha at last two valid extended positions
  last = 2 * label_lengths                          # blank after last label
  a_last = jnp.take_along_axis(alpha, last[:, None], axis=1)[:, 0]
  a_prev = jnp.take_along_axis(
      alpha, jnp.maximum(last - 1, 0)[:, None], axis=1)[:, 0]
  a_prev = jnp.where(label_lengths > 0, a_prev, NEG)
  ll = jnp.logaddexp(a_last, a_prev)
  return -jnp.mean(ll)


def ctc_greedy_decode(log_probs: jax.Array, logit_lengths: jax.Array,
                      blank: int = 0) -> jax.Array:
  """Best-path decode: argmax per frame, collapse repeats, drop blanks.
  Returns (b, t) sequences padded with -1."""
  b, t, _ = log_probs.shape
  path = jnp.argmax(log_probs, axis=-1)             # (b, t)
  prev = jnp.concatenate([jnp.full((b, 1), -1), path[:, :-1]], axis=1)
  frame_idx = jnp.arange(t)[None, :]
  keep = (path != blank) & (path != prev) & (frame_idx < logit_lengths[:, None])
  # stable compaction: sort by (not keep, frame index)
  order = jnp.argsort(jnp.where(keep, frame_idx, t + frame_idx), axis=1)
  vals = jnp.take_along_axis(jnp.where(keep, path, -1), order, axis=1)
  return vals
