"""Decoder-only transformer LM, scan-over-layers, covering the dense GQA
archs (chameleon/llama3/glm4/stablelm/qwen3) and the DeepSeek family
(MLA attention + shared/routed MoE + optional MTP head).

Layer stacks are homogeneous scans over stacked params (MaxText-style):
deepseek configs get two stacks (leading dense-FFN layers, then MoE
layers). Remat policy wraps the scanned body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers import mla as mla_lib
from repro.layers import moe as moe_lib
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.embedding import embed, init_embedding, logits as lm_logits
from repro.layers.ffn import init_swiglu, swiglu_forward
from repro.layers.norms import init_rms, rms_norm


def _init_layer(key, cfg: ModelConfig, *, use_moe: bool):
  ks = jax.random.split(key, 2)
  p = {"ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model)}
  if cfg.mla is not None:
    p["attn"] = mla_lib.init_mla(ks[0], cfg, layer_prefix="layers")
  else:
    p["attn"] = attn_lib.init_attention(ks[0], cfg, layer_prefix="layers")
  if use_moe:
    p["moe"] = moe_lib.init_moe(ks[1], cfg, layer_prefix="layers")
  else:
    p["ffn"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff,
                           layer_prefix="layers", dtype=cfg.dtype)
  return p


def _stack_init(key, cfg: ModelConfig, n: int, *, use_moe: bool):
  keys = jax.random.split(key, n)
  return jax.vmap(functools.partial(_init_layer, cfg=cfg, use_moe=use_moe)
                  )(keys)


def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
  ks = jax.random.split(key, 4)
  n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.num_layers
  n_moe = cfg.num_layers - n_dense if cfg.moe else 0
  p = {
      "embedding": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                  dtype=cfg.dtype, tie=cfg.tie_embeddings),
      "final_norm": init_rms(cfg.d_model),
  }
  if n_dense:
    p["dense_layers"] = _stack_init(ks[1], cfg, n_dense, use_moe=False)
  if n_moe:
    p["moe_layers"] = _stack_init(ks[2], cfg, n_moe, use_moe=True)
  if cfg.mtp:
    kp, kl = jax.random.split(ks[3])
    from repro.core.factored import dense as dense_init
    p["mtp"] = {
        "proj": dense_init(kp, 2 * cfg.d_model, cfg.d_model,
                           name="mtp/proj", dtype=cfg.dtype),
        "layer": _init_layer(kl, cfg, use_moe=False),
        "norm": init_rms(cfg.d_model),
    }
  return p


def _layer_fwd(x, lp, cfg: ModelConfig, cs: Constraint, *, use_moe: bool,
               policy=None):
  # gather the FSDP-sharded layer slice INSIDE the remat region, so the
  # backward pass re-gathers instead of keeping every layer live
  lp = cs(lp, "layer_params")
  h = rms_norm(x, lp["ln1"], cfg.norm_eps)
  if cfg.mla is not None:
    h = mla_lib.mla_forward(lp["attn"], h, cfg, cs, policy)
  else:
    h = attn_lib.attention_forward(lp["attn"], h, cfg, cs, policy)
  x = cs(x + h, "bsd")
  h = rms_norm(x, lp["ln2"], cfg.norm_eps)
  if use_moe:
    h, aux = moe_lib.moe_forward(lp["moe"], h, cfg, cs, policy)
  else:
    h, aux = swiglu_forward(lp["ffn"], h, cs, policy), jnp.zeros(
        (), jnp.float32)
  return cs(x + h, "bsd"), aux


def _scan_stack(x, stack, cfg: ModelConfig, cs: Constraint, *,
                use_moe: bool, policy=None):
  body = functools.partial(_layer_fwd, cfg=cfg, cs=cs, use_moe=use_moe,
                           policy=policy)
  if cfg.remat == "full":
    body = jax.remat(body)
  elif cfg.remat == "dots":
    body = jax.remat(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
  def scan_body(h, lp):
    h, aux = body(h, lp)
    return h, aux
  x, auxes = jax.lax.scan(scan_body, x, stack)
  return x, jnp.sum(auxes)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            cs: Constraint = _id_cs, *, last_only: bool = False,
            policy=None) -> tuple[jax.Array, jax.Array]:
  """tokens (b, s) -> (logits (b, s, v), moe aux loss).

  last_only=True (serving prefill) narrows to the final position before
  the vocab projection, so the (b, s, v) logits tensor never exists."""
  x = cs(embed(params["embedding"], tokens), "bsd")
  aux = jnp.zeros((), jnp.float32)
  if "dense_layers" in params:
    x, a = _scan_stack(x, params["dense_layers"], cfg, cs, use_moe=False,
                       policy=policy)
    aux += a
  if "moe_layers" in params:
    x, a = _scan_stack(x, params["moe_layers"], cfg, cs, use_moe=True,
                       policy=policy)
    aux += a
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  if last_only:
    x = x[:, -1:]
  return cs(lm_logits(params["embedding"], x, policy), "bsv"), aux


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
  lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
  ll = jnp.take_along_axis(lp, targets[..., None].astype(jnp.int32),
                           axis=-1)[..., 0]
  return -jnp.mean(ll)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            cs: Constraint = _id_cs) -> tuple[jax.Array, dict]:
  logits, aux = forward(params, batch["tokens"], cfg, cs)
  loss = _xent(logits, batch["targets"])
  metrics = {"xent": loss, "moe_aux": aux}
  total = loss
  if cfg.moe:
    total = total + cfg.moe.router_aux_weight * aux
  if cfg.mtp and "mtp" in params:
    # Multi-token prediction (deepseek-v3): predict t+2 from [h_t; emb_{t+1}].
    # Keep the full seq length (attention blocks need s % block == 0); the
    # roll wraps the last position, which the target slice drops.
    x = embed(params["embedding"], batch["tokens"])
    h = jnp.concatenate([x, jnp.roll(x, -1, axis=1)], axis=-1)
    h = gemm(params["mtp"]["proj"], h)
    h, _ = _layer_fwd(h, params["mtp"]["layer"], cfg, cs, use_moe=False)
    h = rms_norm(h, params["mtp"]["norm"], cfg.norm_eps)
    mtp_logits = lm_logits(params["embedding"], h)
    if batch["targets"].shape[1] > 2:
      mtp_loss = _xent(mtp_logits[:, :-2], batch["targets"][:, 2:])
    else:
      mtp_loss = _xent(mtp_logits[:, -1:], batch["targets"][:, -1:])
    metrics["mtp"] = mtp_loss
    total = total + 0.3 * mtp_loss
  return total, metrics


# ----------------------------------------------------------------------------
# Decode.
# ----------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=None) -> dict:
  n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.num_layers
  n_moe = cfg.num_layers - n_dense if cfg.moe else 0
  mk = (mla_lib.init_mla_cache if cfg.mla is not None
        else attn_lib.init_kv_cache)
  state = {}
  if n_dense:
    state["dense"] = mk(cfg, batch, max_len, stack=(n_dense,),
                        dtype=cache_dtype)
  if n_moe:
    state["moe"] = mk(cfg, batch, max_len, stack=(n_moe,), dtype=cache_dtype)
  return state


def decode_state_batch_axes(cfg: ModelConfig) -> dict:
  """Batch-axis index per decode-state leaf (same structure as
  `init_decode_state`) — the contract the ModelApi slot-surgery helpers
  (`insert_slot` / `extract_slot` / `reset_slot`) operate on. Caches are
  stacked over layers, so the batch axis sits after the stack dims."""
  n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.num_layers
  n_moe = cfg.num_layers - n_dense if cfg.moe else 0
  cache = ({"c_kv": 1, "k_rope": 1} if cfg.mla is not None
           else {"k": 1, "v": 1})
  axes = {}
  if n_dense:
    axes["dense"] = dict(cache)
  if n_moe:
    axes["moe"] = dict(cache)
  return axes


def decode_state_carry(cfg: ModelConfig) -> dict:
  """Speculative-rewind contract: the whole decode state is attention KV
  (GQA k/v or MLA c_kv/k_rope) written at absolute positions — rows past
  the committed position are never read under the causal mask, so a
  rejected draft suffix rewinds by moving the position counter alone.

  Prefix-snapshot contract (serving.prefix_cache): the same positional
  property makes a cached prefix a row slice — `prefix_view(state, m)`
  keeps KV rows [0, m) and splicing them into a fresh state reproduces
  the cold prefill state at m bit-for-bit, at ANY m <= the fed length."""
  return jax.tree.map(lambda _: False, decode_state_batch_axes(cfg))


def _decode_stack(x, stack, cache, positions, cfg: ModelConfig,
                  cs: Constraint, *, use_moe: bool, policy=None):
  dec = (mla_lib.mla_decode if cfg.mla is not None
         else attn_lib.attention_decode)
  def body(h, xs):
    lp, lc = xs
    lp = cs(lp, "layer_params")
    a = rms_norm(h, lp["ln1"], cfg.norm_eps)
    a, new_c = dec(lp["attn"], a, lc, positions, cfg, cs, policy)
    h = h + a
    f = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if use_moe:
      f, _ = moe_lib.moe_forward(lp["moe"], f, cfg, cs, policy)
    else:
      f = swiglu_forward(lp["ffn"], f, cs, policy)
    return h + f, new_c
  x, new_cache = jax.lax.scan(body, x, (stack, cache))
  return x, new_cache


def decode_step(params: dict, state: dict, token: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, dict]:
  """token (b, 1), positions (b,) -> (logits (b, 1, v), new state)."""
  x = cs(embed(params["embedding"], token), "bsd")
  new_state = dict(state)
  if "dense_layers" in params:
    x, new_state["dense"] = _decode_stack(
        x, params["dense_layers"], state["dense"], positions, cfg, cs,
        use_moe=False, policy=policy)
  if "moe_layers" in params:
    x, new_state["moe"] = _decode_stack(
        x, params["moe_layers"], state["moe"], positions, cfg, cs,
        use_moe=True, policy=policy)
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), new_state


def _window_stack(x, stack, cache, positions, cfg: ModelConfig,
                  cs: Constraint, *, use_moe: bool, policy=None):
  dec = (mla_lib.mla_decode_window if cfg.mla is not None
         else attn_lib.attention_decode_window)
  def body(h, xs):
    lp, lc = xs
    lp = cs(lp, "layer_params")
    a = rms_norm(h, lp["ln1"], cfg.norm_eps)
    a, new_c = dec(lp["attn"], a, lc, positions, cfg, cs, policy)
    h = h + a
    f = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if use_moe:
      f, _ = moe_lib.moe_forward(lp["moe"], f, cfg, cs, policy)
    else:
      f = swiglu_forward(lp["ffn"], f, cs, policy)
    return h + f, new_c
  x, new_cache = jax.lax.scan(body, x, (stack, cache))
  return x, new_cache


def decode_window(params: dict, state: dict, tokens: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, policy=None
                  ) -> tuple[jax.Array, dict]:
  """Batched window decode: tokens (b, W) at positions `positions + t`
  -> (logits (b, W, v), state after W tokens). One weight pass for the
  whole window — the attention layers run `attention_decode_window` /
  `mla_decode_window` and every FFN/MoE/norm is position-independent, so
  each window row is bit-identical to W sequential `decode_step` calls
  (the invariant speculative verification's losslessness rests on)."""
  x = cs(embed(params["embedding"], tokens), "bsd")
  new_state = dict(state)
  if "dense_layers" in params:
    x, new_state["dense"] = _window_stack(
        x, params["dense_layers"], state["dense"], positions, cfg, cs,
        use_moe=False, policy=policy)
  if "moe_layers" in params:
    x, new_state["moe"] = _window_stack(
        x, params["moe_layers"], state["moe"], positions, cfg, cs,
        use_moe=True, policy=policy)
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), new_state
