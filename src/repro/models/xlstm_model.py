"""xLSTM LM: alternating mLSTM / sLSTM block pairs (1:1), scan over pairs.

xlstm-350m: 24 blocks = 12 (mLSTM, sLSTM) pairs, d_model 1024, 4 heads.
d_ff = 0 per the assigned config — blocks carry their own projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import xlstm as xl
from repro.layers.common import (Constraint, ModelConfig,
                                 identity_constraint as _id_cs)
from repro.layers.embedding import embed, init_embedding, logits as lm_logits
from repro.layers.norms import init_rms, rms_norm


def _npairs(cfg: ModelConfig) -> int:
  return cfg.num_layers // 2


def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
  ks = jax.random.split(key, 3)
  def init_pair(pkey):
    k1, k2 = jax.random.split(pkey)
    return {
        "m_norm": init_rms(cfg.d_model),
        "mlstm": xl.init_mlstm(k1, cfg, layer_prefix="pairs"),
        "s_norm": init_rms(cfg.d_model),
        "slstm": xl.init_slstm(k2, cfg, layer_prefix="pairs"),
    }
  return {
      "embedding": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                  dtype=cfg.dtype, tie=cfg.tie_embeddings),
      "final_norm": init_rms(cfg.d_model),
      "pairs": jax.vmap(init_pair)(jax.random.split(ks[1], _npairs(cfg))),
  }


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            cs: Constraint = _id_cs, *, last_only: bool = False,
            policy=None) -> tuple[jax.Array, jax.Array]:
  x = cs(embed(params["embedding"], tokens), "bsd")
  def pair_block(h, lp):
    lp = cs(lp, "layer_params")     # gather inside the remat region
    h = h + xl.mlstm_forward(lp["mlstm"],
                             rms_norm(h, lp["m_norm"], cfg.norm_eps), cfg, cs,
                             policy=policy)
    h = h + xl.slstm_forward(lp["slstm"],
                             rms_norm(h, lp["s_norm"], cfg.norm_eps), cfg, cs,
                             policy)
    return h
  block = jax.remat(pair_block) if cfg.remat == "full" else pair_block
  def body(h, lp):
    return cs(block(h, lp), "bsd"), None
  x, _ = jax.lax.scan(body, x, params["pairs"])
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  if last_only:
    x = x[:, -1:]
  return cs(lm_logits(params["embedding"], x, policy), "bsv"), jnp.zeros(
      (), jnp.float32)


def loss_fn(params, batch, cfg, cs=_id_cs):
  logits, _ = forward(params, batch["tokens"], cfg, cs)
  lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
  ll = jnp.take_along_axis(lp, batch["targets"][..., None].astype(jnp.int32),
                           axis=-1)[..., 0]
  loss = -jnp.mean(ll)
  return loss, {"xent": loss}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=None) -> dict:
  n = _npairs(cfg)
  return {
      "mlstm": xl.init_mlstm_state(cfg, batch, stack=(n,)),
      "slstm": xl.init_slstm_state(cfg, batch, stack=(n,)),
  }


def decode_state_batch_axes(cfg: ModelConfig) -> dict:
  """Batch-axis index per decode-state leaf (slot-surgery contract):
  both block states are stacked over the pair dimension."""
  return {
      "mlstm": {"C": 1, "n": 1, "m": 1},
      "slstm": {"h": 1, "c": 1, "n": 1, "m": 1},
  }


def decode_state_carry(cfg: ModelConfig) -> dict:
  """Speculative-rewind contract: every xLSTM state leaf (mLSTM matrix
  memory / normalizer / stabilizer, sLSTM hidden/cell/normalizer/
  stabilizer) is a read-modify-write carry — rewind requires the
  pre-draft snapshot replayed through the accepted prefix.

  Prefix-snapshot contract (serving.prefix_cache): all-carry means a
  cached prefix is the whole (fixed-size) state copied verbatim, valid
  at EXACTLY the snapshot length — cheap to cache, impossible to
  truncate; entries exist only at lengths a prefill stopped at."""
  return jax.tree.map(lambda _: True, decode_state_batch_axes(cfg))


def decode_step(params: dict, state: dict, token: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, dict]:
  x = cs(embed(params["embedding"], token), "bsd")
  def body(h, xs):
    lp, ms, ss = xs
    lp = cs(lp, "layer_params")
    y, ms1 = xl.mlstm_decode(lp["mlstm"],
                             rms_norm(h, lp["m_norm"], cfg.norm_eps), ms,
                             cfg, cs, policy=policy)
    h = h + y
    y, ss1 = xl.slstm_decode(lp["slstm"],
                             rms_norm(h, lp["s_norm"], cfg.norm_eps), ss,
                             cfg, cs, policy)
    return h + y, (ms1, ss1)
  x, (ms, ss) = jax.lax.scan(body, x,
                             (params["pairs"], state["mlstm"],
                              state["slstm"]))
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), {"mlstm": ms,
                                                     "slstm": ss}


def decode_window(params: dict, state: dict, tokens: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, policy=None
                  ) -> tuple[jax.Array, dict]:
  """Batched window decode: tokens (b, W) -> (logits (b, W, v), state).

  Mirrors `decode_step` with `mlstm_decode_window` / `slstm_decode_window`:
  every non-recurrent GEMM reads its weights once for the whole window,
  only the O(1) carries scan over positions — rows bit-identical to W
  sequential steps. `positions` is unused (pure-carry family) but kept for
  the uniform family signature."""
  del positions
  x = cs(embed(params["embedding"], tokens), "bsd")
  def body(h, xs):
    lp, ms, ss = xs
    lp = cs(lp, "layer_params")
    y, ms1 = xl.mlstm_decode_window(lp["mlstm"],
                                    rms_norm(h, lp["m_norm"], cfg.norm_eps),
                                    ms, cfg, cs, policy=policy)
    h = h + y
    y, ss1 = xl.slstm_decode_window(lp["slstm"],
                                    rms_norm(h, lp["s_norm"], cfg.norm_eps),
                                    ss, cfg, cs, policy)
    return h + y, (ms1, ss1)
  x, (ms, ss) = jax.lax.scan(body, x,
                             (params["pairs"], state["mlstm"],
                              state["slstm"]))
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), {"mlstm": ms,
                                                     "slstm": ss}
