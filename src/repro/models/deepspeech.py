"""Deep Speech 2 acoustic model — the paper's baseline architecture.

Forward-only GRU DS2 (Amodei et al. 2016) with the paper's Appendix-B
modifications: mel-80 features (B.3), two 2D convolutions, *growing* GRU
sizes 768/1024/1280 (B.1), fully connected 1536, CTC output. All GRU
weights use the partially-joint factorization (B.2) so the trace-norm
recipe applies at the paper's granularity; the FC and output GEMMs are
factored as `nonrec`.

The reduced configs used for CPU training in the reproduction keep the
same growing-size structure at smaller dims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.gru import gru_forward, init_gru
from repro.models.ctc import ctc_loss


CONV1_TIME_STRIDE = 2   # conv1 halves time; conv2's time stride is
                        # cfg.time_stride
CONV_FREQ_STRIDE = 2    # both convs halve frequency

def conv_out_len(t: int, k: int, stride: int) -> int:
  return (t + stride - 1) // stride  # ceil(t / stride), see conv_time_pads


def conv_time_pads(t: int, k: int, stride: int) -> tuple:
  """(pad_left, pad_right) for the streaming time-padding convention.

  The left pad is a *fixed* `(k - stride) // 2` regardless of sequence
  length; the right pad completes exactly `ceil(t / stride)` output
  frames. XLA's "SAME" instead centres the total pad, which makes the
  left context depend on `t % stride` — a full-utterance conv and a
  streamed one would then disagree whenever the final length isn't a
  stride multiple (the stream has already committed its left pad before
  the length is known). For stride-multiple lengths both conventions
  coincide bit-for-bit; for the rest this one is the streamable choice.
  """
  out = (t + stride - 1) // stride
  pad_l = (k - stride) // 2
  pad_r = (out - 1) * stride + k - t - pad_l
  return pad_l, max(pad_r, 0)


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
  ks = jax.random.split(key, 8)
  ch = cfg.conv_channels
  # conv1: (time 11 x freq 41), stride (2, 2); conv2: (11 x 21), stride (t, 2)
  conv1 = jax.random.normal(ks[0], (11, 41, 1, ch), jnp.float32) * 0.05
  conv2 = jax.random.normal(ks[1], (11, 21, ch, ch), jnp.float32) * 0.05
  freq_after = ((cfg.feat_dim + 1) // 2 + 1) // 2
  gru_in = freq_after * ch
  grus = {}
  prev = gru_in
  for i, h in enumerate(cfg.gru_dims):
    grus[f"gru{i}"] = init_gru(ks[2 + i], prev, h, layer_prefix=f"gru{i}",
                               dtype=cfg.dtype)
    prev = h
  return {
      "conv1": conv1.astype(cfg.dtype),
      "conv2": conv2.astype(cfg.dtype),
      "grus": grus,
      "fc": dense(ks[6], prev, cfg.fc_dim, name="fc", group="nonrec",
                  dtype=cfg.dtype),
      "out": dense(ks[7], cfg.fc_dim, cfg.vocab_size, name="out",
                   group="nonrec", dtype=cfg.dtype),
  }


def _freq_pads(f: int, k: int, stride: int) -> tuple:
  total = (conv_out_len(f, k, stride) - 1) * stride + k - f
  return total // 2, total - total // 2   # "SAME": centred (freq is static)


def _frontend(params: dict, feats: jax.Array, cfg: ModelConfig
              ) -> jax.Array:
  """feats (b, t, f) -> (b, t', gru_in). Two strided 2D convs + ReLU.

  Time padding follows `conv_time_pads` (fixed left context) so chunked
  streaming through `_ConvStream` reproduces this function exactly for
  *any* utterance length, not just stride multiples.
  """
  x = feats[..., None]                                   # (b, t, f, 1)
  k1, f1 = params["conv1"].shape[:2]
  x = jax.lax.conv_general_dilated(
      x.astype(cfg.dtype), params["conv1"],
      window_strides=(CONV1_TIME_STRIDE, CONV_FREQ_STRIDE),
      padding=(conv_time_pads(x.shape[1], k1, CONV1_TIME_STRIDE),
               _freq_pads(x.shape[2], f1, CONV_FREQ_STRIDE)),
      dimension_numbers=("NHWC", "HWIO", "NHWC"))
  x = jax.nn.relu(x.astype(jnp.float32)).astype(cfg.dtype)
  k2, f2 = params["conv2"].shape[:2]
  x = jax.lax.conv_general_dilated(
      x, params["conv2"],
      window_strides=(cfg.time_stride, CONV_FREQ_STRIDE),
      padding=(conv_time_pads(x.shape[1], k2, cfg.time_stride),
               _freq_pads(x.shape[2], f2, CONV_FREQ_STRIDE)),
      dimension_numbers=("NHWC", "HWIO", "NHWC"))
  x = jax.nn.relu(x.astype(jnp.float32)).astype(cfg.dtype)
  b, t, f, c = x.shape
  return x.reshape(b, t, f * c)


def forward(params: dict, feats: jax.Array, cfg: ModelConfig,
            cs: Constraint = _id_cs, policy=None) -> jax.Array:
  """feats (b, t, feat_dim) -> log_probs (b, t', vocab)."""
  x = _frontend(params, feats, cfg)
  for i in range(len(cfg.gru_dims)):
    x = gru_forward(params["grus"][f"gru{i}"], x, cs, policy)
  x = jax.nn.relu(
      gemm(params["fc"], x, policy).astype(jnp.float32)).astype(x.dtype)
  logits = gemm(params["out"], x, policy)
  return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def output_lengths(input_lengths: jax.Array, cfg: ModelConfig) -> jax.Array:
  s1 = CONV1_TIME_STRIDE
  t1 = (input_lengths + s1 - 1) // s1
  return (t1 + cfg.time_stride - 1) // cfg.time_stride


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            cs: Constraint = _id_cs):
  """batch: feats (b,t,f), feat_lengths (b,), labels (b,l),
  label_lengths (b,)."""
  log_probs = forward(params, batch["feats"], cfg, cs)
  out_lens = output_lengths(batch["feat_lengths"], cfg)
  loss = ctc_loss(log_probs, out_lens, batch["labels"],
                  batch["label_lengths"])
  return loss, {"ctc": loss}


# -- streaming inference (the paper's embedded deployment mode) --------------


def init_decode_state(cfg: ModelConfig, batch: int) -> dict:
  """Streaming GRU hidden states (the conv frontend is applied on small
  feature chunks by the serving loop)."""
  return {f"gru{i}": jnp.zeros((batch, h), cfg.dtype)
          for i, h in enumerate(cfg.gru_dims)}


def decode_state_batch_axes(cfg: ModelConfig) -> dict:
  """Batch-axis index per decode-state leaf (slot-surgery contract):
  streaming GRU hidden states carry batch leading."""
  return {f"gru{i}": 0 for i in range(len(cfg.gru_dims))}


def decode_state_carry(cfg: ModelConfig) -> dict:
  """Speculative-rewind contract: every GRU hidden state is a read-
  modify-write carry — rewind requires the pre-draft snapshot replayed
  through the accepted prefix.

  Prefix-snapshot contract (serving.prefix_cache): all-carry, like
  xLSTM — a cached prefix is the fixed-size hidden states copied whole,
  valid at exactly the number of frames fed; no positional slicing
  exists in this family."""
  return {f"gru{i}": True for i in range(len(cfg.gru_dims))}


def decode_step(params: dict, state: dict, x_t: jax.Array,
                cfg: ModelConfig, cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, dict]:
  """One post-frontend frame x_t (b, gru_in) -> (log_probs (b, v), state).

  This is the paper's low-batch regime: each GRU step is a skinny GEMM
  against the recurrent matrix — the workload kernels/decode_matvec and
  kernels/gru_cell target. A decode-regime `policy` routes exactly those
  call sites through the Pallas kernels.
  """
  from repro.layers.gru import gru_decode
  new_state = {}
  h = x_t
  for i in range(len(cfg.gru_dims)):
    hi = gru_decode(params["grus"][f"gru{i}"], h, state[f"gru{i}"], cs,
                    policy)
    new_state[f"gru{i}"] = hi
    h = hi
  h = jax.nn.relu(
      gemm(params["fc"], h, policy).astype(jnp.float32)).astype(h.dtype)
  logits = gemm(params["out"], h, policy)
  return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1), new_state


def api_decode_step(params: dict, state: dict, feat: jax.Array,
                    positions: jax.Array, cfg: ModelConfig,
                    cs: Constraint = _id_cs, policy=None
                    ) -> tuple[jax.Array, dict]:
  """ModelApi-uniform wrapper over the frame step: feat (b, 1, gru_in),
  logits (b, 1, v). `positions` is accepted and ignored — the streaming
  state is purely recurrent, there is no positional cache — which gives
  DS2 the same decode_step/decode_window surface as the LM families."""
  del positions
  log_probs, new_state = decode_step(params, state, feat[:, 0], cfg, cs,
                                     policy)
  return log_probs[:, None], new_state


def api_decode_window(params: dict, state: dict, feat: jax.Array,
                      positions: jax.Array, cfg: ModelConfig,
                      cs: Constraint = _id_cs, policy=None
                      ) -> tuple[jax.Array, dict]:
  """Batched window decode: feat (b, W, gru_in) -> (log_probs (b, W, v),
  state). Per layer the non-recurrent W_{z,r,h} GEMM batches over the
  window in one weight pass (paper §4's Wx batching, now in the decode
  path); only the `gru_cell` recurrence scans over positions, seeded from
  the streaming carry — each frame matches `api_decode_step` bit-for-bit.
  `positions` is ignored exactly as in the step path."""
  from repro.layers.gru import gru_cell
  del positions
  b, W, _ = feat.shape
  new_state = {}
  h = feat
  for i in range(len(cfg.gru_dims)):
    p = params["grus"][f"gru{i}"]
    hidden = cfg.gru_dims[i]
    xw = gemm(p["nonrec"], h, policy)
    def step(hc, xwt, p=p, hidden=hidden):
      h1 = gru_cell(xwt, hc, p["rec"], p["bias"], hidden, policy)
      return h1, h1
    hlast, hs = jax.lax.scan(step, state[f"gru{i}"], xw.transpose(1, 0, 2))
    new_state[f"gru{i}"] = hlast
    h = hs.transpose(1, 0, 2)
  h = jax.nn.relu(
      gemm(params["fc"], h, policy).astype(jnp.float32)).astype(h.dtype)
  logits = gemm(params["out"], h, policy)
  return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1), new_state
