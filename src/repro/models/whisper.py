"""Whisper-style encoder-decoder (audio backbone only; conv frontend is a
stub per the brief — `input_specs()` feeds precomputed frame embeddings).

Encoder: bidirectional self-attention + GELU FFN over (b, frames, d).
Decoder: causal self-attention (KV cache on decode) + cross-attention to
encoder output + GELU FFN. Sinusoidal positions on the encoder, learned on
the decoder (matching Radford et al. 2022 structurally).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers import attention as attn_lib
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.embedding import embed, init_embedding, logits as lm_logits
from repro.layers.ffn import gelu_ffn_forward, init_gelu_ffn
from repro.layers.norms import init_ln, layer_norm

NEG_INF = -2.0 ** 30


def _sinusoid(length: int, d: int) -> jax.Array:
  pos = jnp.arange(length)[:, None].astype(jnp.float32)
  dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
  inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
  ang = pos * inv
  return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_xattn(key, cfg: ModelConfig, prefix: str):
  d, h = cfg.d_model, cfg.num_heads
  hd = cfg.resolved_head_dim
  ks = jax.random.split(key, 4)
  return {
      "wq": dense(ks[0], d, h * hd, name=f"{prefix}/xattn_q",
                  dtype=cfg.dtype),
      "wk": dense(ks[1], d, h * hd, name=f"{prefix}/xattn_k",
                  dtype=cfg.dtype),
      "wv": dense(ks[2], d, h * hd, name=f"{prefix}/xattn_v",
                  dtype=cfg.dtype),
      "wo": dense(ks[3], h * hd, d, name=f"{prefix}/xattn_o",
                  dtype=cfg.dtype),
  }


def _xattn(p, x, mem, cfg, cs, policy=None):
  """Cross attention: queries from x (b,s,d), keys/values from mem."""
  b, s, _ = x.shape
  h, hd = cfg.num_heads, cfg.resolved_head_dim
  q = gemm(p["wq"], x, policy).reshape(b, s, h, hd)
  k = gemm(p["wk"], mem, policy).reshape(b, mem.shape[1], h, hd)
  v = gemm(p["wv"], mem, policy).reshape(b, mem.shape[1], h, hd)
  sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                  k.astype(jnp.float32)) / (hd ** 0.5)
  pr = jax.nn.softmax(sc, axis=-1)
  o = jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(jnp.float32))
  return gemm(p["wo"], o.reshape(b, s, h * hd).astype(x.dtype), policy)


def _init_enc_layer(key, cfg: ModelConfig):
  ks = jax.random.split(key, 2)
  return {
      "ln1": init_ln(cfg.d_model),
      "attn": attn_lib.init_attention(ks[0], cfg, layer_prefix="enc"),
      "ln2": init_ln(cfg.d_model),
      "ffn": init_gelu_ffn(ks[1], cfg.d_model, cfg.d_ff, layer_prefix="enc",
                           dtype=cfg.dtype),
  }


def _init_dec_layer(key, cfg: ModelConfig):
  ks = jax.random.split(key, 3)
  return {
      "ln1": init_ln(cfg.d_model),
      "attn": attn_lib.init_attention(ks[0], cfg, layer_prefix="dec"),
      "ln2": init_ln(cfg.d_model),
      "xattn": _init_xattn(ks[1], cfg, "dec"),
      "ln3": init_ln(cfg.d_model),
      "ffn": init_gelu_ffn(ks[2], cfg.d_model, cfg.d_ff, layer_prefix="dec",
                           dtype=cfg.dtype),
  }


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
  ks = jax.random.split(key, 4)
  enc_n = cfg.encoder_layers or cfg.num_layers
  return {
      "embedding": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                  dtype=cfg.dtype, tie=True),
      "pos_dec": jax.random.normal(ks[3], (cfg.max_source_positions * 32,
                                           cfg.d_model), jnp.float32).astype(
          cfg.dtype) * 0.01,
      "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
          jax.random.split(ks[1], enc_n)),
      "enc_ln": init_ln(cfg.d_model),
      "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
          jax.random.split(ks[2], cfg.num_layers)),
      "dec_ln": init_ln(cfg.d_model),
  }


def _bidir_attention(p, x, cfg, cs, policy=None):
  """Non-causal full self-attention via the flash path with mask disabled:
  encoder sequences can be long (prefill_32k), so reuse blockwise attention
  with an all-visible mask by passing positions = max."""
  b, s, _ = x.shape
  h, hd = cfg.num_heads, cfg.resolved_head_dim
  q = gemm(p["wq"], x, policy).reshape(b, s, h, hd)
  k = gemm(p["wk"], x, policy).reshape(b, s, h, hd)
  v = gemm(p["wv"], x, policy).reshape(b, s, h, hd)
  # blockwise non-causal: scan over kv blocks with online softmax
  bkv = min(cfg.attn_block_kv, s)
  nk = s // bkv
  kb = k.reshape(b, nk, bkv, h, hd)
  vb = v.reshape(b, nk, bkv, h, hd)
  scale = 1.0 / (hd ** 0.5)
  m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
  l0 = jnp.zeros((b, h, s), jnp.float32)
  o0 = jnp.zeros((b, s, h, hd), jnp.float32)
  def kv_step2(carry, xs):
    m, l, o = carry
    kj, vj = xs
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kj.astype(jnp.float32)) * scale
    m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
    pexp = jnp.exp(sc - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l = l * alpha + jnp.sum(pexp, axis=-1)
    o = o * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
        "bhqk,bkhd->bqhd", pexp, vj.astype(jnp.float32))
    return (m_new, l, o), None
  (m, l, o), _ = jax.lax.scan(kv_step2, (m0, l0, o0),
                              (kb.transpose(1, 0, 2, 3, 4),
                               vb.transpose(1, 0, 2, 3, 4)))
  o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
  return gemm(p["wo"], o.reshape(b, s, h * hd).astype(x.dtype), policy)


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           cs: Constraint = _id_cs, policy=None) -> jax.Array:
  b, t, d = frames.shape
  x = frames.astype(cfg.dtype) + _sinusoid(t, d).astype(cfg.dtype)[None]
  x = cs(x, "bsd")
  def scan_body(h, lp):
    g = functools.partial(_enc_block, cfg=cfg, cs=cs, policy=policy)
    if cfg.remat == "full":
      g = jax.remat(g)
    return cs(g(h, lp), "bsd"), None
  x, _ = jax.lax.scan(scan_body, x, params["enc_layers"])
  return layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"],
                    cfg.norm_eps)


def encode_unrolled(params: dict, frames: jax.Array, cfg: ModelConfig,
                    cs: Constraint = _id_cs, policy=None) -> jax.Array:
  """`encode` with the layer scan unrolled into an eager Python loop.

  Same math as `encode` (the scan body IS `_enc_block`; a scan over a
  stacked pytree and a loop over its slices apply identical per-layer
  programs), but activations stay *concrete*, so with a policy threaded
  every encoder GEMM routes through `dispatch.gemm` eagerly and the
  calibration observers see it — per layer, because each block runs
  under `dispatch.calibration_layer(i)`. This is the forward the
  LiteASR-style calibration uses: `encode`'s scan turns every
  activation into a tracer the observers must skip, which is exactly
  the PR 4 blind spot that left whisper's encoder uncalibratable.
  Do not jit this; for serving use `encode`.
  """
  from repro.kernels import dispatch
  b, t, d = frames.shape
  x = frames.astype(cfg.dtype) + _sinusoid(t, d).astype(cfg.dtype)[None]
  x = cs(x, "bsd")
  n_layers = jax.tree.leaves(params["enc_layers"])[0].shape[0]
  for i in range(n_layers):
    lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
    with dispatch.calibration_layer(i):
      x = cs(_enc_block(x, lp, cfg, cs, policy), "bsd")
  return layer_norm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"],
                    cfg.norm_eps)


def _enc_block(h, lp, cfg, cs, policy=None):
  lp = cs(lp, "layer_params")       # gather inside the remat region
  a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
  h = h + _bidir_attention(lp["attn"], a, cfg, cs, policy)
  f = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
  return h + gelu_ffn_forward(lp["ffn"], f, cs, policy)


def _dec_block(h, lp, mem, cfg, cs):
  lp = cs(lp, "layer_params")       # gather inside the remat region
  a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
  h = h + attn_lib.attention_forward(lp["attn"], a, cfg, cs)
  a = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
  h = h + _xattn(lp["xattn"], a, mem, cfg, cs)
  f = layer_norm(h, lp["ln3"]["scale"], lp["ln3"]["bias"], cfg.norm_eps)
  return h + gelu_ffn_forward(lp["ffn"], f, cs)


def decode_train(params: dict, tokens: jax.Array, mem: jax.Array,
                 cfg: ModelConfig, cs: Constraint = _id_cs) -> jax.Array:
  b, s = tokens.shape
  x = embed(params["embedding"], tokens)
  x = x + params["pos_dec"][:s][None].astype(x.dtype)
  def scan_body(h, lp):
    g = functools.partial(_dec_block, mem=mem, cfg=cfg, cs=cs)
    if cfg.remat == "full":
      g = jax.remat(g)
    return cs(g(h, lp), "bsd"), None
  x, _ = jax.lax.scan(scan_body, x, params["dec_layers"])
  x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"],
                 cfg.norm_eps)
  return lm_logits(params["embedding"], x)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            cs: Constraint = _id_cs):
  mem = encode(params, batch["frames"], cfg, cs)
  logits = decode_train(params, batch["tokens"], mem, cfg, cs)
  lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
  ll = jnp.take_along_axis(lp, batch["targets"][..., None].astype(jnp.int32),
                           axis=-1)[..., 0]
  loss = -jnp.mean(ll)
  return loss, {"xent": loss}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 1500, cache_dtype=None) -> dict:
  return {
      "kv": attn_lib.init_kv_cache(cfg, batch, max_len,
                                   stack=(cfg.num_layers,),
                                   dtype=cache_dtype),
      "mem": jnp.zeros((batch, enc_len, cfg.d_model), cfg.dtype),
  }


def decode_state_batch_axes(cfg: ModelConfig) -> dict:
  """Batch-axis index per decode-state leaf (slot-surgery contract):
  the self-attention cache is stacked over layers; the encoder memory
  carries batch leading."""
  return {"kv": {"k": 1, "v": 1}, "mem": 0}


def decode_state_carry(cfg: ModelConfig) -> dict:
  """Speculative-rewind contract: the self-attention KV cache rewinds
  positionally and the encoder memory is step-invariant (decode_step
  returns it untouched) — no carry anywhere, rewind is free.

  Prefix-snapshot contract (serving.prefix_cache): KV rows [0, m) slice
  positionally; the step-invariant encoder memory is copied whole into
  the snapshot (it has no length axis to slice) and spliced back
  verbatim — a cached prefix is only reusable against the same memory."""
  return {"kv": {"k": False, "v": False}, "mem": False}


def decode_step(params: dict, state: dict, token: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, dict]:
  b = token.shape[0]
  x = embed(params["embedding"], token)
  x = x + params["pos_dec"][positions][:, None].astype(x.dtype)
  mem = state["mem"]
  def body(h, xs):
    lp, lc = xs
    lp = cs(lp, "layer_params")
    a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
    a, c1 = attn_lib.attention_decode(lp["attn"], a, lc, positions, cfg, cs,
                                      policy)
    h = h + a
    a = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
    h = h + _xattn(lp["xattn"], a, mem, cfg, cs, policy)
    f = layer_norm(h, lp["ln3"]["scale"], lp["ln3"]["bias"], cfg.norm_eps)
    return h + gelu_ffn_forward(lp["ffn"], f, cs, policy), c1
  x, kv = jax.lax.scan(body, x, (params["dec_layers"], state["kv"]))
  x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"],
                 cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), {"kv": kv, "mem": mem}


def decode_window(params: dict, state: dict, tokens: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, policy=None
                  ) -> tuple[jax.Array, dict]:
  """Batched window decode: tokens (b, W) -> (logits (b, W, v), state).

  Mirrors `decode_step` with `attention_decode_window` for the causal
  self-attention; cross-attention over the (step-invariant) encoder
  memory and the FFN are position-independent, so they just batch. One
  weight pass for the window, rows bit-identical to W sequential steps."""
  pos2d = positions[:, None] + jnp.arange(tokens.shape[1])[None, :]
  x = embed(params["embedding"], tokens)
  x = x + params["pos_dec"][pos2d].astype(x.dtype)
  mem = state["mem"]
  def body(h, xs):
    lp, lc = xs
    lp = cs(lp, "layer_params")
    a = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
    a, c1 = attn_lib.attention_decode_window(lp["attn"], a, lc, positions,
                                             cfg, cs, policy)
    h = h + a
    a = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
    h = h + _xattn(lp["xattn"], a, mem, cfg, cs, policy)
    f = layer_norm(h, lp["ln3"]["scale"], lp["ln3"]["bias"], cfg.norm_eps)
    return h + gelu_ffn_forward(lp["ffn"], f, cs, policy), c1
  x, kv = jax.lax.scan(body, x, (params["dec_layers"], state["kv"]))
  x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"],
                 cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), {"kv": kv, "mem": mem}
