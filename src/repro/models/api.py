"""Unified model API — one dispatch surface over the model zoo.

Every family exposes:
  init(key, cfg)                          -> params pytree
  loss_fn(params, batch, cfg, cs)         -> (scalar loss, metrics dict)
  init_decode_state(cfg, batch, max_len)  -> decode-state pytree (if decodable)
  decode_step(params, state, token/feat, positions, cfg, cs, policy)
                                          -> (logits, new state)

The training loop, serving engine, dry-run, and benchmarks all go through
`get_model(cfg)` so an `--arch <id>` flag is the only thing that changes
between runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.layers.common import (Constraint, ModelConfig,
                                 identity_constraint)
from repro.models import deepspeech, transformer, whisper, xlstm_model, zamba

__all__ = ["Constraint", "ModelApi", "get_model", "identity_constraint"]


@dataclasses.dataclass(frozen=True)
class ModelApi:
  """One model family behind a uniform callable surface.

  The sharding-constraint contract: every `loss_fn` / `forward` /
  `decode_step` threads a constraint callable `cs(x, logical_name) -> x`
  through its layers, annotating activations (and scanned layer slices)
  by LOGICAL name only — "bsd", "bsv", "bshd_q", "layer_params", ... —
  never with concrete meshes or PartitionSpecs. The single factory for a
  real `cs` is `repro.dist.sharding.make_constraint(mesh, cfg, batch,
  decode=...)`; single-device callers omit the argument and get
  `identity_constraint` (the default on every model function), which
  makes each annotation a no-op. Model code therefore compiles
  identically for train, serve and dry-run — only the `cs` passed in
  (and the jit in/out shardings around it) changes.

  The kernel-policy contract is the execution-side twin: `forward` /
  `decode_step` also thread a `policy` (a
  `repro.kernels.dispatch.KernelPolicy`) to every GEMM call site, which
  classifies each matmul by regime (decode batch -> decode_matvec,
  factored leaf -> lowrank_gemm, recurrent step -> gru_cell, per-name
  overrides) and lowers it through the Pallas kernels. The single
  factory for a serving policy is `repro.kernels.dispatch.decode_policy`;
  the default (None) is the plain jnp path, so training and eval are
  byte-identical unless a caller opts in. Like `cs`, the policy is
  trace-time static: pass it by closure, never as a jit operand.
  """
  family: str
  init: Callable
  loss_fn: Callable
  forward: Optional[Callable] = None
  init_decode_state: Optional[Callable] = None
  decode_step: Optional[Callable] = None
  # encoder for enc-dec families (used by serving to fill the memory)
  encode: Optional[Callable] = None

  @property
  def decodable(self) -> bool:
    return self.decode_step is not None


def get_model(cfg: ModelConfig) -> ModelApi:
  fam = cfg.family
  if fam == "transformer":
    return ModelApi(
        family=fam, init=transformer.init_lm, loss_fn=transformer.loss_fn,
        forward=transformer.forward,
        init_decode_state=transformer.init_decode_state,
        decode_step=transformer.decode_step)
  if fam == "zamba":
    return ModelApi(
        family=fam, init=zamba.init_lm, loss_fn=zamba.loss_fn,
        forward=zamba.forward, init_decode_state=zamba.init_decode_state,
        decode_step=zamba.decode_step)
  if fam == "xlstm":
    return ModelApi(
        family=fam, init=xlstm_model.init_lm, loss_fn=xlstm_model.loss_fn,
        forward=xlstm_model.forward,
        init_decode_state=xlstm_model.init_decode_state,
        decode_step=xlstm_model.decode_step)
  if fam == "whisper":
    return ModelApi(
        family=fam, init=whisper.init_model, loss_fn=whisper.loss_fn,
        forward=None, init_decode_state=whisper.init_decode_state,
        decode_step=whisper.decode_step, encode=whisper.encode)
  if fam == "deepspeech":
    return ModelApi(
        family=fam, init=deepspeech.init_model, loss_fn=deepspeech.loss_fn,
        forward=deepspeech.forward,
        init_decode_state=lambda cfg, batch, max_len=None:
            deepspeech.init_decode_state(cfg, batch),
        decode_step=deepspeech.decode_step)
  raise ValueError(f"unknown model family: {fam}")
