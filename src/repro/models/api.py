"""Unified model API — one dispatch surface over the model zoo.

Every family exposes:
  init(key, cfg)                          -> params pytree
  loss_fn(params, batch, cfg, cs)         -> (scalar loss, metrics dict)
  init_decode_state(cfg, batch, max_len)  -> decode-state pytree (if decodable)
  decode_step(params, state, token/feat, positions, cfg, cs, policy)
                                          -> (logits (b, 1, v), new state)
  decode_state_carry(cfg)                 -> bool pytree: which decode-state
                                          leaves are read-modify-write
                                          carries (speculative rewind AND
                                          the prefix-cache snapshot split)

`ModelApi` derives the prefix-snapshot surface from those contracts:
`decode_state_length_axes` / `prefix_view` / `slot_snapshot` /
`splice_prefix` turn "the decode state after m tokens" into a bounded,
cacheable snapshot and back (serving.prefix_cache stores these).

The training loop, serving engine, dry-run, and benchmarks all go through
`get_model(cfg)` so an `--arch <id>` flag is the only thing that changes
between runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.layers.common import (Constraint, ModelConfig,
                                 identity_constraint)
from repro.models import deepspeech, transformer, whisper, xlstm_model, zamba

__all__ = ["Constraint", "KV_CACHE_KEYS", "ModelApi", "cast_kv_cache",
           "get_model", "identity_constraint"]

#: Leaf names that tag an attention KV cache inside a decode-state pytree
#: (GQA caches store "k"/"v"; MLA caches store the latent "c_kv" plus the
#: shared "k_rope"). Everything else in decode state — SSM carries, conv
#: tails, xLSTM accumulators, GRU hidden states, encoder memory — is a
#: recurrent carry that must keep its full working precision.
KV_CACHE_KEYS = frozenset({"k", "v", "c_kv", "k_rope"})


def _leaf_key(path) -> Optional[str]:
  if path and isinstance(path[-1], jax.tree_util.DictKey):
    return path[-1].key
  return None


def cast_kv_cache(state, dtype):
  """Cast only the attention KV-cache leaves of a decode state to `dtype`.

  This is the whole scope of `LMEngine(cache_dtype=...)`: the KV cache is
  write-once-read-many, so a low-precision copy trades a bounded readback
  error for halved cache traffic (the paper's bandwidth argument). SSM /
  recurrent carries are read-modify-write every step — downcasting them
  compounds error across the sequence — so they are left untouched.
  """
  if dtype is None:
    return state
  def cast(path, x):
    if _leaf_key(path) in KV_CACHE_KEYS and jnp.issubdtype(
        x.dtype, jnp.floating):
      return x.astype(dtype)
    return x
  return jax.tree_util.tree_map_with_path(cast, state)


@dataclasses.dataclass(frozen=True)
class ModelApi:
  """One model family behind a uniform callable surface.

  The sharding-constraint contract: every `loss_fn` / `forward` /
  `decode_step` threads a constraint callable `cs(x, logical_name) -> x`
  through its layers, annotating activations (and scanned layer slices)
  by LOGICAL name only — "bsd", "bsv", "bshd_q", "layer_params", ... —
  never with concrete meshes or PartitionSpecs. The single factory for a
  real `cs` is `repro.dist.sharding.make_constraint(mesh, cfg, batch,
  decode=...)`; single-device callers omit the argument and get
  `identity_constraint` (the default on every model function), which
  makes each annotation a no-op. Model code therefore compiles
  identically for train, serve and dry-run — only the `cs` passed in
  (and the jit in/out shardings around it) changes.

  The kernel-policy contract is the execution-side twin: `forward` /
  `decode_step` also thread a `policy` (a
  `repro.kernels.dispatch.KernelPolicy`) to every GEMM call site, which
  classifies each matmul by regime (decode batch -> decode_matvec,
  factored leaf -> lowrank_gemm, recurrent step -> gru_cell, PTQ'd
  quantized leaf -> int8_gemm on its stored scales, per-name
  overrides) and lowers it through the Pallas kernels. The single
  factory for a serving policy is `repro.kernels.dispatch.decode_policy`;
  the default (None) is the plain jnp path, so training and eval are
  byte-identical unless a caller opts in. Like `cs`, the policy is
  trace-time static: pass it by closure, never as a jit operand.
  """
  family: str
  init: Callable
  loss_fn: Callable
  forward: Optional[Callable] = None
  init_decode_state: Optional[Callable] = None
  decode_step: Optional[Callable] = None
  # encoder for enc-dec families (used by serving to fill the memory)
  encode: Optional[Callable] = None
  # cfg -> pytree of ints, same structure as init_decode_state's output,
  # giving the batch-axis index of every decode-state leaf. This is the
  # family's slot-surgery contract: caches stack over layer dims, so the
  # batch axis is not uniformly leading.
  decode_state_batch_axes: Optional[Callable] = None
  # cfg -> pytree of bools, same structure as init_decode_state's output:
  # True for read-modify-write carries (SSM states, conv tails, xLSTM
  # accumulators, GRU hiddens) that a speculative rewind must snapshot
  # before drafting and replay up to the accepted length; False for
  # leaves whose rewind is free — attention KV rows are written at
  # absolute positions (rows past the committed position are dead until
  # overwritten, never read under the causal mask) and step-invariant
  # leaves (whisper's encoder memory) never change at all.
  decode_state_carry: Optional[Callable] = None
  # family batched window forward: (params, state, tokens (b, W),
  # positions (b,), cfg, cs, policy) -> (logits (b, W, v), state after W
  # tokens), computing the whole window in ONE weight pass (attention
  # families: one causal pass over the KV cache; carry families: batched
  # non-recurrent GEMMs + an elementwise state scan). Contract, pinned by
  # the parity grid in tests/test_spec_window_parity.py: token-for-token
  # (argmax) equal to W sequential decode_step calls everywhere, and
  # bit-identical where the backend delivers it (transformer, zamba,
  # deepspeech are bitwise; xlstm and whisper land within a few ulp —
  # XLA fuses the two program shapes differently, see the grid test).
  # Token equality is the invariant speculative acceptance rests on.
  decode_window_batched: Optional[Callable] = None

  @property
  def decodable(self) -> bool:
    return self.decode_step is not None

  def decode_window(self, params, state, tokens, positions,
                    cfg: ModelConfig, cs: Constraint = identity_constraint,
                    policy=None):
    """Decode a W-token window in one batched forward pass.

    tokens (b, W) ids — or (b, W, f) frames for deepspeech — fed at
    positions `positions + t`; returns (logits (b, W, v) float32, state
    after all W steps). Routes to the family's `decode_window_batched`
    (one weight read amortized over the window — the paper's §4
    economics applied to speculative verification), whose per-position
    argmaxes ARE vanilla greedy's choices (bit-identical logits on the
    bitwise families, ulp-close on xlstm/whisper — see
    `decode_window_batched`). Families without a batched forward fall
    back to the sequential scan.

    Rewind contract: the caller owns undoing the W - accepted rejected
    suffix. KV-cache leaves need only the position counter moved back
    (`decode_state_carry` False); carry leaves must be restored from a
    pre-window snapshot and replayed through the accepted prefix
    (`decode_state_carry` True) — see serving.engine's speculative path.
    """
    if not self.decodable:
      raise ValueError(f"{self.family} has no decode path")
    if self.decode_window_batched is None:
      return self.decode_window_sequential(params, state, tokens, positions,
                                           cfg, cs, policy)
    logits, state = self.decode_window_batched(params, state, tokens,
                                               positions, cfg, cs, policy)
    return logits.astype(jnp.float32), state

  def decode_window_sequential(self, params, state, tokens, positions,
                               cfg: ModelConfig,
                               cs: Constraint = identity_constraint,
                               policy=None):
    """Reference W-token window: a fused scan of `decode_step`, one
    position per iteration (k+1 serial weight reads). Kept as the parity
    oracle for the batched window and as the fallback for families
    without one; semantics identical to `decode_window`."""
    if not self.decodable:
      raise ValueError(f"{self.family} has no decode path")
    def body(st, t):
      tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
      logits, st1 = self.decode_step(params, st, tok, positions + t, cfg,
                                     cs, policy)
      return st1, logits[:, 0].astype(jnp.float32)
    state, logits = jax.lax.scan(body, state, jnp.arange(tokens.shape[1]))
    return jnp.moveaxis(logits, 0, 1), state

  # -- decode-state slot surgery ------------------------------------------
  # The continuous-batching engine treats each batch row of the decode
  # state as a *slot* with its own request lifecycle. These helpers move
  # single-request (batch-1) states in and out of a live batched state
  # without re-tracing: `slot` may be a traced int32, so one jitted
  # program serves every slot index.

  def _slot_axes(self, cfg: ModelConfig):
    if self.decode_state_batch_axes is None:
      raise ValueError(
          f"{self.family} does not define decode_state_batch_axes")
    return self.decode_state_batch_axes(cfg)

  def extract_slot(self, cfg: ModelConfig, state, slot):
    """Slice slot `slot` out of a batched decode state (keeps batch=1)."""
    return jax.tree.map(
        lambda x, ax: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=ax),
        state, self._slot_axes(cfg))

  def insert_slot(self, cfg: ModelConfig, state, slot_state, slot):
    """Write a batch-1 `slot_state` into slot `slot` of a batched state."""
    return jax.tree.map(
        lambda x, s, ax: jax.lax.dynamic_update_slice_in_dim(
            x, s.astype(x.dtype), slot, axis=ax),
        state, slot_state, self._slot_axes(cfg))

  def reset_slot(self, cfg: ModelConfig, state, slot, *, max_len=None):
    """Return `state` with slot `slot` restored to its init value (fresh
    KV rows / SSM carries), leaving every other slot untouched."""
    fresh = self.init_decode_state(cfg, 1, max_len)
    return self.insert_slot(cfg, state, fresh, slot)

  # -- prefix snapshots (the prefix-cache contract) -------------------------
  # A decode state after m tokens splits into three leaf kinds:
  #   * positional KV (attention k/v, MLA c_kv/k_rope): only rows [0, m)
  #     on the length axis are ever read under the causal mask — a prefix
  #     snapshot keeps exactly those rows and nothing else;
  #   * read-modify-write carries (SSM states, conv tails, xLSTM
  #     accumulators, GRU hiddens): fixed-size, copied whole, and valid
  #     at EXACTLY m — they cannot be sliced to a shorter prefix, which
  #     is why the prefix cache only ever replays whole inserted entries;
  #   * step-invariant leaves (whisper's encoder memory): copied whole.
  # This formalizes what the speculative rewind (PR 5) does ad hoc: the
  # same positional-vs-carry split `decode_state_carry` names, plus the
  # length axis that makes the positional half a bounded snapshot.

  def decode_state_length_axes(self, cfg: ModelConfig):
    """Per-leaf decode-position axis: the axis indexed by the write
    position for attention-KV leaves (always the axis after the batch
    axis — the cache layout every family shares), -1 for carry and
    step-invariant leaves, which have no positional extent."""
    def f(path, ax):
      return ax + 1 if _leaf_key(path) in KV_CACHE_KEYS else -1
    return jax.tree_util.tree_map_with_path(f, self._slot_axes(cfg))

  def prefix_view(self, cfg: ModelConfig, slot_state, length: int):
    """Fixed-size snapshot of a batch-1 decode state after exactly
    `length` tokens: KV leaves sliced to rows [0, length), carries and
    step-invariant leaves copied whole. `slot_state` must actually BE
    the state after `length` tokens — carries are only valid there."""
    return jax.tree.map(
        lambda x, ax: x if ax < 0 else jax.lax.slice_in_dim(
            x, 0, length, axis=ax),
        slot_state, self.decode_state_length_axes(cfg))

  def slot_snapshot(self, cfg: ModelConfig, state, slot, length: int):
    """`extract_slot` + `prefix_view`: the cacheable snapshot of one
    live slot's first `length` positions."""
    return self.prefix_view(cfg, self.extract_slot(cfg, state, slot),
                            length)

  def splice_prefix(self, cfg: ModelConfig, fresh, snapshot):
    """Inverse of `prefix_view`: write `snapshot` into a fresh batch-1
    state. KV rows land at [0, m) with zeros beyond — bit-identical to
    what a cold prefill of those m tokens leaves behind, so decoding
    from the spliced state is indistinguishable from never having
    evicted the request. Eager-safe: plain slice-update ops, no new jit
    program (the engine's no-new-signatures contract)."""
    return jax.tree.map(
        lambda f, s, ax: (s.astype(f.dtype) if ax < 0
                          else jax.lax.dynamic_update_slice_in_dim(
                              f, s.astype(f.dtype), 0, axis=ax)),
        fresh, snapshot, self.decode_state_length_axes(cfg))


def get_model(cfg: ModelConfig) -> ModelApi:
  fam = cfg.family
  if fam == "transformer":
    return ModelApi(
        family=fam, init=transformer.init_lm, loss_fn=transformer.loss_fn,
        forward=transformer.forward,
        init_decode_state=transformer.init_decode_state,
        decode_step=transformer.decode_step,
        decode_state_batch_axes=transformer.decode_state_batch_axes,
        decode_state_carry=transformer.decode_state_carry,
        decode_window_batched=transformer.decode_window)
  if fam == "zamba":
    return ModelApi(
        family=fam, init=zamba.init_lm, loss_fn=zamba.loss_fn,
        forward=zamba.forward, init_decode_state=zamba.init_decode_state,
        decode_step=zamba.decode_step,
        decode_state_batch_axes=zamba.decode_state_batch_axes,
        decode_state_carry=zamba.decode_state_carry,
        decode_window_batched=zamba.decode_window)
  if fam == "xlstm":
    return ModelApi(
        family=fam, init=xlstm_model.init_lm, loss_fn=xlstm_model.loss_fn,
        forward=xlstm_model.forward,
        init_decode_state=xlstm_model.init_decode_state,
        decode_step=xlstm_model.decode_step,
        decode_state_batch_axes=xlstm_model.decode_state_batch_axes,
        decode_state_carry=xlstm_model.decode_state_carry,
        decode_window_batched=xlstm_model.decode_window)
  if fam == "whisper":
    return ModelApi(
        family=fam, init=whisper.init_model, loss_fn=whisper.loss_fn,
        forward=None, init_decode_state=whisper.init_decode_state,
        decode_step=whisper.decode_step, encode=whisper.encode,
        decode_state_batch_axes=whisper.decode_state_batch_axes,
        decode_state_carry=whisper.decode_state_carry,
        decode_window_batched=whisper.decode_window)
  if fam == "deepspeech":
    return ModelApi(
        family=fam, init=deepspeech.init_model, loss_fn=deepspeech.loss_fn,
        forward=deepspeech.forward,
        init_decode_state=lambda cfg, batch, max_len=None:
            deepspeech.init_decode_state(cfg, batch),
        decode_step=deepspeech.api_decode_step,
        decode_state_batch_axes=deepspeech.decode_state_batch_axes,
        decode_state_carry=deepspeech.decode_state_carry,
        decode_window_batched=deepspeech.api_decode_window)
  raise ValueError(f"unknown model family: {fam}")
