"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention+MLP block
applied periodically (arXiv:2411.15242).

Structure: num_layers Mamba2 blocks; every `attn_every` blocks, the shared
transformer block (one set of weights, reused ~num_layers/attn_every times)
runs on the concatenation-projected hidden state. The shared block is the
extreme end of the paper's Appendix-B.2 weight-sharing spectrum, and its
GEMMs are factored/regularized like any other.

Scan layout: main stack reshaped (groups, attn_every, ...) and scanned with
a nested scan; remainder layers get their own short scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers import mamba2 as m2
from repro.layers.common import (Constraint, ModelConfig,
                                 identity_constraint as _id_cs)
from repro.layers.embedding import embed, init_embedding, logits as lm_logits
from repro.layers.ffn import init_swiglu, swiglu_forward
from repro.layers.norms import init_rms, rms_norm


def _plan(cfg: ModelConfig) -> tuple[int, int, int]:
  k = cfg.attn_every or 6
  groups = cfg.num_layers // k
  tail = cfg.num_layers - groups * k
  return k, groups, tail


def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
  k, groups, tail = _plan(cfg)
  ks = jax.random.split(key, 6)
  mamba_init = functools.partial(m2.init_mamba2, cfg=cfg,
                                 layer_prefix="mamba")
  def init_group(gkey):
    return jax.vmap(lambda kk: mamba_init(kk))(jax.random.split(gkey, k))
  p = {
      "embedding": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                  dtype=cfg.dtype, tie=cfg.tie_embeddings),
      "final_norm": init_rms(cfg.d_model),
      "main": jax.vmap(init_group)(jax.random.split(ks[1], groups)),
      "shared_attn": {
          "ln1": init_rms(cfg.d_model),
          "attn": attn_lib.init_attention(ks[2], cfg, layer_prefix="shared"),
          "ln2": init_rms(cfg.d_model),
          "ffn": init_swiglu(ks[3], cfg.d_model, cfg.d_ff,
                             layer_prefix="shared", dtype=cfg.dtype),
      },
  }
  if tail:
    p["tail"] = jax.vmap(lambda kk: mamba_init(kk))(
        jax.random.split(ks[4], tail))
  return p


def _shared_block(x, sp, cfg, cs, positions_mode, policy=None):
  h = rms_norm(x, sp["ln1"], cfg.norm_eps)
  h = attn_lib.attention_forward(sp["attn"], h, cfg, cs, policy)
  x = x + h
  h = rms_norm(x, sp["ln2"], cfg.norm_eps)
  return x + swiglu_forward(sp["ffn"], h, cs, policy)


def _mamba_scan(x, stack, cfg, cs, remat=True, policy=None):
  def block(h, lp):
    lp = cs(lp, "layer_params")     # gather inside the remat region
    return h + m2.mamba2_forward(
        lp, rms_norm(h, lp["norm_in"], cfg.norm_eps), cfg, cs,
        policy=policy)
  if remat:
    block = jax.remat(block)
  def body(h, lp):
    return cs(block(h, lp), "bsd"), None
  x, _ = jax.lax.scan(body, x, stack)
  return x


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            cs: Constraint = _id_cs, *, last_only: bool = False,
            policy=None) -> tuple[jax.Array, jax.Array]:
  x = cs(embed(params["embedding"], tokens), "bsd")
  def group_body(h, gstack):
    h = _shared_block(h, params["shared_attn"], cfg, cs, None, policy)
    h = _mamba_scan(h, gstack, cfg, cs, policy=policy)
    return h, None
  x, _ = jax.lax.scan(group_body, x, params["main"])
  if "tail" in params:
    x = _mamba_scan(x, params["tail"], cfg, cs, policy=policy)
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  if last_only:
    x = x[:, -1:]
  return cs(lm_logits(params["embedding"], x, policy), "bsv"), jnp.zeros(
      (), jnp.float32)


def loss_fn(params, batch, cfg, cs=_id_cs):
  logits, _ = forward(params, batch["tokens"], cfg, cs)
  lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
  ll = jnp.take_along_axis(lp, batch["targets"][..., None].astype(jnp.int32),
                           axis=-1)[..., 0]
  loss = -jnp.mean(ll)
  return loss, {"xent": loss}


# -- decode -------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=None) -> dict:
  k, groups, tail = _plan(cfg)
  st = {
      "main_ssm": m2.init_mamba2_state(cfg, batch, stack=(groups, k)),
      "shared_kv": attn_lib.init_kv_cache(cfg, batch, max_len,
                                          stack=(groups,),
                                          dtype=cache_dtype),
  }
  if tail:
    st["tail_ssm"] = m2.init_mamba2_state(cfg, batch, stack=(tail,))
  return st


def decode_state_batch_axes(cfg: ModelConfig) -> dict:
  """Batch-axis index per decode-state leaf (slot-surgery contract).

  `main_ssm` is stacked (groups, attn_every, ...) so batch is axis 2;
  the shared KV cache and the tail SSM stack one level only."""
  _, _, tail = _plan(cfg)
  axes = {
      "main_ssm": {"ssm": 2, "conv": 2},
      "shared_kv": {"k": 1, "v": 1},
  }
  if tail:
    axes["tail_ssm"] = {"ssm": 1, "conv": 1}
  return axes


def decode_state_carry(cfg: ModelConfig) -> dict:
  """Speculative-rewind contract: Mamba2 SSM states and conv tails are
  read-modify-write every step — rewinding a rejected draft suffix needs
  the pre-draft snapshot replayed through the accepted prefix. The shared
  attention KV cache rewinds positionally (overwrite, free).

  Prefix-snapshot contract (serving.prefix_cache): the carry leaves are
  fixed-size and valid at EXACTLY the length they were fed to — a cached
  prefix copies them whole (KV rows slice positionally as usual), and a
  snapshot can only be taken at a length the prefill actually stopped
  at, never truncated to a shorter prefix after the fact."""
  _, _, tail = _plan(cfg)
  carry = {
      "main_ssm": {"ssm": True, "conv": True},
      "shared_kv": {"k": False, "v": False},
  }
  if tail:
    carry["tail_ssm"] = {"ssm": True, "conv": True}
  return carry


def decode_step(params: dict, state: dict, token: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, dict]:
  x = cs(embed(params["embedding"], token), "bsd")
  new_state = dict(state)

  def group_body(h, xs):
    gstack, g_ssm, g_kv = xs
    a = rms_norm(h, params["shared_attn"]["ln1"], cfg.norm_eps)
    a, kv1 = attn_lib.attention_decode(params["shared_attn"]["attn"], a,
                                       g_kv, positions, cfg, cs, policy)
    h = h + a
    f = rms_norm(h, params["shared_attn"]["ln2"], cfg.norm_eps)
    h = h + swiglu_forward(params["shared_attn"]["ffn"], f, cs, policy)
    def mamba_body(hh, ys):
      lp, ls = ys
      lp = cs(lp, "layer_params")
      y, s1 = m2.mamba2_decode(
          lp, rms_norm(hh, lp["norm_in"], cfg.norm_eps), ls, cfg, cs,
          policy=policy)
      return hh + y, s1
    h, ssm1 = jax.lax.scan(mamba_body, h, (gstack, g_ssm))
    return h, (ssm1, kv1)

  x, (main_ssm, shared_kv) = jax.lax.scan(
      group_body, x, (params["main"], state["main_ssm"],
                      state["shared_kv"]))
  new_state["main_ssm"] = main_ssm
  new_state["shared_kv"] = shared_kv
  if "tail" in params:
    def mamba_body(hh, ys):
      lp, ls = ys
      lp = cs(lp, "layer_params")
      y, s1 = m2.mamba2_decode(
          lp, rms_norm(hh, lp["norm_in"], cfg.norm_eps), ls, cfg, cs,
          policy=policy)
      return hh + y, s1
    x, tail_ssm = jax.lax.scan(mamba_body, x,
                               (params["tail"], state["tail_ssm"]))
    new_state["tail_ssm"] = tail_ssm
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), new_state


def decode_window(params: dict, state: dict, tokens: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, policy=None
                  ) -> tuple[jax.Array, dict]:
  """Batched window decode: tokens (b, W) -> (logits (b, W, v), state).

  Mirrors `decode_step` with the window variants: the shared attention
  block runs `attention_decode_window` (one causal pass over the KV
  cache), each Mamba2 block runs `mamba2_decode_window` (batched GEMMs,
  elementwise state scan) — one weight pass for the whole window, rows
  bit-identical to W sequential steps."""
  x = cs(embed(params["embedding"], tokens), "bsd")
  new_state = dict(state)

  def group_body(h, xs):
    gstack, g_ssm, g_kv = xs
    a = rms_norm(h, params["shared_attn"]["ln1"], cfg.norm_eps)
    a, kv1 = attn_lib.attention_decode_window(
        params["shared_attn"]["attn"], a, g_kv, positions, cfg, cs, policy)
    h = h + a
    f = rms_norm(h, params["shared_attn"]["ln2"], cfg.norm_eps)
    h = h + swiglu_forward(params["shared_attn"]["ffn"], f, cs, policy)
    def mamba_body(hh, ys):
      lp, ls = ys
      lp = cs(lp, "layer_params")
      y, s1 = m2.mamba2_decode_window(
          lp, rms_norm(hh, lp["norm_in"], cfg.norm_eps), ls, cfg, cs,
          policy=policy)
      return hh + y, s1
    h, ssm1 = jax.lax.scan(mamba_body, h, (gstack, g_ssm))
    return h, (ssm1, kv1)

  x, (main_ssm, shared_kv) = jax.lax.scan(
      group_body, x, (params["main"], state["main_ssm"],
                      state["shared_kv"]))
  new_state["main_ssm"] = main_ssm
  new_state["shared_kv"] = shared_kv
  if "tail" in params:
    def mamba_body(hh, ys):
      lp, ls = ys
      lp = cs(lp, "layer_params")
      y, s1 = m2.mamba2_decode_window(
          lp, rms_norm(hh, lp["norm_in"], cfg.norm_eps), ls, cfg, cs,
          policy=policy)
      return hh + y, s1
    x, tail_ssm = jax.lax.scan(mamba_body, x,
                               (params["tail"], state["tail_ssm"]))
    new_state["tail_ssm"] = tail_ssm
  x = rms_norm(x, params["final_norm"], cfg.norm_eps)
  return lm_logits(params["embedding"], x, policy), new_state
