"""QuantizedLinear — the genuinely-quantized GEMM leaf (paper §4).

The serving-side sibling of `FactoredLinear`: the same logical
name/group namespace (so `FactorizationPlan` globs, sharding rules, and
`KernelPolicy` per-name overrides keep matching), but the weight storage
is symmetric per-column int8 plus f32 scales — the exact operand format
`kernels/int8_gemm` consumes. A quantized leaf is produced once by
`repro.quant.quantize_params` (PTQ); from then on every decode step
reads int8 weights directly, with NO per-call weight requantization
(retiring the KNOWN COST note that used to live on
`kernels.ops.quantized_matmul`).

Shapes (a leading layer axis L is allowed — scanned stacks quantize per
(layer, column), and the serving scan slices every field so each
iteration consumes the 2-D form):
  unfactored: w_q ([L,] m, n) s8, w_scale ([L,] n) f32
  factored:   u_q ([L,] m, r) s8, u_scale ([L,] r) f32;
              v_q ([L,] r, n) s8, v_scale ([L,] n) f32
  act_scale:  optional () f32 — a calibrated static activation range;
              None means dynamic per-row activation quantization.

Arithmetic: w8a8. Activations are quantized per row (dynamically, or
with the calibrated static scale), the int8 GEMM accumulates in int32,
and the per-row x per-column dequant happens on the f32 output —
identical math in `apply()` (the jnp reference path) and in the Pallas
`int8_gemm` kernel the dispatcher routes to, which is what makes the
pallas/jnp serving parity hold token-for-token.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.factored import register_gemm_leaf
from repro.kernels import ref


def _act_quantize(x: jax.Array, act_scale: Optional[jax.Array]
                  ) -> tuple[jax.Array, jax.Array]:
  """Quantize an activation (..., m): calibrated static scale if present,
  dynamic symmetric per-row otherwise. Returns (q, per-row scales)."""
  if act_scale is None:
    return ref.quantize_rowwise(x)
  return ref.quantize_static(x, act_scale)


@register_gemm_leaf
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedLinear:
  """An int8-quantized GEMM weight, unfactored (w_q) or factored
  (u_q @ v_q), with per-column scales stored alongside."""
  w_q: Optional[jax.Array]
  w_scale: Optional[jax.Array]
  u_q: Optional[jax.Array]
  u_scale: Optional[jax.Array]
  v_q: Optional[jax.Array]
  v_scale: Optional[jax.Array]
  act_scale: Optional[jax.Array] = None
  name: str = dataclasses.field(metadata=dict(static=True), default="gemm")
  group: str = dataclasses.field(metadata=dict(static=True),
                                 default="nonrec")
  #: dtype string of the float weight this leaf was quantized from;
  #: `product()` dequantizes back into it
  orig_dtype: str = dataclasses.field(metadata=dict(static=True),
                                      default="float32")

  # -- structure ------------------------------------------------------------
  @property
  def is_factored(self) -> bool:
    return self.u_q is not None

  @property
  def in_dim(self) -> int:
    return self.u_q.shape[-2] if self.is_factored else self.w_q.shape[-2]

  @property
  def out_dim(self) -> int:
    return self.v_q.shape[-1] if self.is_factored else self.w_q.shape[-1]

  @property
  def rank(self) -> int:
    if self.is_factored:
      return self.u_q.shape[-1]
    return min(self.w_q.shape[-2], self.w_q.shape[-1])

  @property
  def num_params(self) -> int:
    if self.is_factored:
      return self.u_q.size + self.v_q.size
    return self.w_q.size

  @property
  def dtype(self):
    return jnp.dtype(self.orig_dtype)

  # -- math -----------------------------------------------------------------
  def product(self) -> jax.Array:
    """Materialize the dequantized W (the float-math escape hatch some
    layers use for absorbed/stacked weights)."""
    if self.is_factored:
      u = self.u_q.astype(jnp.float32) * self.u_scale[..., None, :]
      v = self.v_q.astype(jnp.float32) * self.v_scale[..., None, :]
      return jnp.matmul(u, v).astype(self.dtype)
    return (self.w_q.astype(jnp.float32) *
            self.w_scale[..., None, :]).astype(self.dtype)

  def apply(self, x: jax.Array, policy=None) -> jax.Array:
    """y = x @ W in w8a8 arithmetic (the jnp reference for the int8_gemm
    regime); `policy` routes through kernels.dispatch like FactoredLinear.
    """
    if policy is not None:
      from repro.kernels import dispatch
      return dispatch.gemm(self, x, policy)
    lead = x.shape[:-1]
    y = ref_apply(self, x.reshape(-1, x.shape[-1]))
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)

  def __call__(self, x: jax.Array) -> jax.Array:
    return self.apply(x)


def _apply(leaf: QuantizedLinear, x2: jax.Array, int8_gemm) -> jax.Array:
  """ONE w8a8 flow for both execution paths, parameterized by the
  int8 GEMM implementation — the pallas/jnp token-for-token parity
  guarantee is structural, not maintained by hand. x2 (b, m) -> f32
  (b, n). The factored path requantizes the rank intermediate per row
  (w8a8 on both skinny GEMMs)."""
  x_q, x_s = _act_quantize(x2, leaf.act_scale)
  if leaf.is_factored:
    t = int8_gemm(x_q, leaf.u_q, x_s, leaf.u_scale)
    t_q, t_s = ref.quantize_rowwise(t)
    return int8_gemm(t_q, leaf.v_q, t_s, leaf.v_scale)
  return int8_gemm(x_q, leaf.w_q, x_s, leaf.w_scale)


def ref_apply(leaf: QuantizedLinear, x2: jax.Array) -> jax.Array:
  """The pure-jnp int8 oracle for one quantized GEMM."""
  return _apply(leaf, x2, ref.int8_gemm)


def kernel_apply(leaf: QuantizedLinear, x2: jax.Array,
                 interpret: Optional[bool] = None) -> jax.Array:
  """The Pallas path for one quantized GEMM (what `kernels.dispatch`
  routes the int8_gemm regime to for pre-quantized leaves): activations
  quantize per call (cheap, O(bm)), stored weight scales are consumed
  directly — zero weight quantize ops in the traced step."""
  from repro.kernels import ops
  return _apply(leaf, x2,
                functools.partial(ops.int8_gemm, interpret=interpret))
