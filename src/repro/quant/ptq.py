"""Post-training quantization: one-shot float tree -> quantized tree.

`quantize_params` walks a params pytree, replaces every `FactoredLinear`
the plan matches with a `QuantizedLinear` (symmetric per-column int8,
the `kernels/int8_gemm` operand format), and leaves everything else —
conv stacks, norms, embedding tables, biases — untouched. The plan is a
`core.compress.FactorizationPlan`, so quantization scoping composes with
the compression pipeline in the same logical-name glob namespace:
stage-2-truncate with one plan, then PTQ with another (or the same one).

Optional activation-range calibration: run the float model over a few
batches inside `calibrate_activation_ranges` and pass the resulting
{name: amax} dict as `calib`. Calibrated leaves quantize activations
with a static scale (amax / 127) instead of the dynamic per-row max;
leaves without a calibration entry (e.g. recurrent GEMMs hidden inside a
`lax.scan`, whose activations are tracers) keep dynamic quantization.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import FactorizationPlan
from repro.core.factored import FactoredLinear, map_factored_leaves
from repro.kernels import ref
from repro.quant.leaf import QuantizedLinear

#: default PTQ scope: every GEMM leaf, regardless of size (quantizing a
#: tiny GEMM is harmless — unlike factoring one, which is why
#: FactorizationPlan's own default min_dim is 128)
DEFAULT_PLAN = FactorizationPlan(min_dim=1)


def quantize_leaf(leaf: FactoredLinear,
                  act_amax: Optional[float] = None) -> QuantizedLinear:
  """Symmetric per-column int8 quantization of one GEMM leaf.

  Layer-stacked (L, m, n) leaves quantize per (layer, column); the scan
  that consumes them slices every field, so each iteration sees an
  ordinary 2-D QuantizedLinear."""
  act_scale = None
  if act_amax is not None:
    act_scale = jnp.float32(max(float(act_amax), 1e-8) / 127.0)
  kw = dict(act_scale=act_scale, name=leaf.name, group=leaf.group,
            orig_dtype=str(jnp.dtype(leaf.dtype)))
  if leaf.is_factored:
    u_q, u_s = ref.quantize_colwise(leaf.u)
    v_q, v_s = ref.quantize_colwise(leaf.v)
    return QuantizedLinear(w_q=None, w_scale=None, u_q=u_q, u_scale=u_s,
                           v_q=v_q, v_scale=v_s, **kw)
  w_q, w_s = ref.quantize_colwise(leaf.w)
  return QuantizedLinear(w_q=w_q, w_scale=w_s, u_q=None, u_scale=None,
                         v_q=None, v_scale=None, **kw)


def quantize_params(params: Any, plan: Optional[FactorizationPlan] = None,
                    *, calib: Optional[Mapping[str, float]] = None) -> Any:
  """One-shot PTQ over a params pytree.

  plan  — which GEMMs to quantize, matched on logical names exactly like
          compression plans (default: all of them). Layer-stacked (3D+)
          leaves quantize per layer: the serving scan slices every field,
          handing each iteration a 2-D QuantizedLinear.
  calib — optional {logical name: activation amax} from
          `calibrate_activation_ranges`; matched leaves get a static
          activation scale.
  """
  plan = DEFAULT_PLAN if plan is None else plan

  def f(leaf: FactoredLinear):
    if not plan.matches(leaf):
      return leaf
    amax = calib.get(leaf.name) if calib else None
    return quantize_leaf(leaf, act_amax=amax)

  return map_factored_leaves(f, params)


def is_quantized(tree: Any) -> bool:
  """True if any GEMM leaf in the tree is a QuantizedLinear."""
  found = False
  def check(x):
    nonlocal found
    found = found or isinstance(x, QuantizedLinear)
    return x
  jax.tree.map(check, tree,
               is_leaf=lambda x: isinstance(x, QuantizedLinear))
  return found


def calibrate_activation_ranges(apply_fn, batches: Iterable[Any]
                                ) -> dict[str, float]:
  """Record per-GEMM activation ranges by running the float model.

  `apply_fn(batch)` must run the model forward *eagerly* (not under jit)
  with a KernelPolicy threaded — `dispatch.JNP_ONLY` works and keeps the
  numerics the plain jnp path — so every GEMM routes through
  `kernels.dispatch.gemm`, whose input observer this taps. GEMMs whose
  activations are tracers (inside a `lax.scan`/jit) are skipped; those
  leaves simply keep dynamic activation quantization.

  Returns {logical GEMM name: max |x| seen across all batches}.
  """
  from repro.kernels import dispatch
  ran = False
  with dispatch.observe_gemm_inputs() as log:
    for batch in batches:
      ran = True
      apply_fn(batch)
  if ran and not log:
    # The observer saw every GEMM skip it — that happens exactly when the
    # activations were tracers, i.e. apply_fn ran under jit (or with no
    # policy threaded, so no GEMM routed through dispatch.gemm at all).
    # Returning {} here used to silently produce an uncalibrated model.
    raise RuntimeError(
        "calibrate_activation_ranges observed zero GEMM activations. "
        "apply_fn must run the model EAGERLY (not under jax.jit) with a "
        "KernelPolicy threaded (dispatch.JNP_ONLY works) so activations "
        "are concrete when dispatch.gemm observes them; under jit every "
        "activation is a tracer and calibration is silently empty.")
  out = dict(log)
  # Layer-tagged entries ("name@L3", from dispatch.calibration_layer
  # around scan-stacked leaves) additionally fold into their base name
  # by max: quantize_params looks leaves up by base name, and the
  # stacked leaf's single act_scale must cover every layer's range.
  for key, amax in log.items():
    base = _split_layer_key(key)[0]
    if base != key:
      out[base] = max(out.get(base, 0.0), amax)
  return out


def _split_layer_key(key: str) -> tuple[str, Optional[int]]:
  base, sep, idx = key.rpartition("@L")
  if sep and idx.isdigit():
    return base, int(idx)
  return key, None


@dataclasses.dataclass
class ActivationStats:
  """Calibrated input statistics for one GEMM leaf.

  second_moment — E[x x^T]: (m, m), or (L, m, m) stacked per scan layer
  when the forward tagged layers with `dispatch.calibration_layer`.
  count/amax aggregate over layers. `core.compress.to_stage2(calib=...)`
  consumes the `second_moment` for activation-weighted truncation."""
  second_moment: "np.ndarray"
  count: int
  amax: float


def calibrate_activation_stats(apply_fn, batches: Iterable[Any]
                               ) -> dict[str, ActivationStats]:
  """Collect per-GEMM input Gram matrices for calibrated truncation.

  Same eager-forward contract as `calibrate_activation_ranges`, tapping
  `dispatch.observe_gemm_moments` instead of the amax observer. Entries
  tagged "name@L{i}" (scan-stacked leaves observed layer-by-layer, e.g.
  through `models.whisper.encode_unrolled`) are assembled into ONE
  `ActivationStats` per base name whose second_moment is stacked
  (L, m, m) in layer order — the per-layer Gram matrices the stacked
  branch of `svd.truncate_leaf` whitens with. Layer indices must be
  contiguous from 0 (a gap means some layer's GEMM never ran eagerly).
  """
  from repro.kernels import dispatch
  ran = False
  with dispatch.observe_gemm_moments() as log:
    for batch in batches:
      ran = True
      apply_fn(batch)
  if ran and not log:
    raise RuntimeError(
        "calibrate_activation_stats observed zero GEMM activations — "
        "apply_fn must run eagerly with a KernelPolicy threaded (see "
        "calibrate_activation_ranges).")
  flat: dict[str, dict] = {}
  layered: dict[str, dict[int, dict]] = {}
  for key, ent in log.items():
    base, idx = _split_layer_key(key)
    if idx is None:
      flat[base] = ent
    else:
      layered.setdefault(base, {})[idx] = ent
  out: dict[str, ActivationStats] = {}
  for name, ent in flat.items():
    out[name] = ActivationStats(
        second_moment=ent["xtx"] / max(ent["count"], 1),
        count=ent["count"], amax=ent["amax"])
  for name, by_layer in layered.items():
    n = len(by_layer)
    if sorted(by_layer) != list(range(n)):
      raise RuntimeError(
          f"leaf {name!r}: calibration saw layer indices "
          f"{sorted(by_layer)} — expected contiguous 0..{n - 1}; some "
          "scan layer never ran eagerly under calibration_layer")
    stack = np.stack([by_layer[i]["xtx"] / max(by_layer[i]["count"], 1)
                      for i in range(n)])
    out[name] = ActivationStats(
        second_moment=stack,
        count=sum(by_layer[i]["count"] for i in range(n)),
        amax=max(by_layer[i]["amax"] for i in range(n)))
  return out
