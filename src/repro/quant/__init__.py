"""repro.quant — quantized weight leaves end-to-end (paper §4).

  leaf — QuantizedLinear: int8 w/u/v + per-column scales, same logical
         name/group namespace as FactoredLinear; w8a8 reference apply
  ptq  — quantize_params: one-shot post-training quantization over a
         params pytree, plan-scoped, with optional activation-range
         calibration over a data iterator

A PTQ'd tree is a first-class serving artifact: `kernels.dispatch`
classifies its leaves into the int8_gemm regime consuming the stored
scales directly (no per-call weight requantization), both serving
engines accept it unchanged, `launch.serve --quantize` builds one, and
`checkpoint.CheckpointManager` round-trips it bit-identically.
"""
from repro.quant.leaf import QuantizedLinear, kernel_apply, ref_apply
from repro.quant.ptq import (DEFAULT_PLAN, ActivationStats,
                             calibrate_activation_ranges,
                             calibrate_activation_stats, is_quantized,
                             quantize_leaf, quantize_params)

__all__ = ["QuantizedLinear", "kernel_apply", "ref_apply", "DEFAULT_PLAN",
           "ActivationStats", "calibrate_activation_ranges",
           "calibrate_activation_stats", "is_quantized", "quantize_leaf",
           "quantize_params"]
