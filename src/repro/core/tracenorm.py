"""Variational trace-norm regularization (paper §3.1, Lemma 1).

The trace norm (nuclear norm / Schatten 1-norm) ||W||_T = sum_i sigma_i(W)
admits the variational characterization

    ||W||_T = min_{W = U V} (||U||_F^2 + ||V||_F^2) / 2

over factorizations U: m x r, V: r x n with r = min(m, n). Penalizing
(||U||_F^2 + ||V||_F^2)/2 on a *factored* parameterization is therefore an
exact surrogate for an l1 penalty on the singular values of W = UV
(Srebro et al. 2005; Ciliberto et al. 2017, Prop. 1) — it drives W toward low
rank without fixing the rank in advance.

This module provides the penalty, the paper's nondimensional trace norm
coefficient nu(W) (Definition 1), and singular-value diagnostics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.core.factored import iter_factored_leaves


def frobenius_sq(x: jax.Array) -> jax.Array:
  """||x||_F^2 in float32 regardless of the param dtype."""
  x = x.astype(jnp.float32)
  return jnp.sum(x * x)


def variational_trace_norm_penalty(u: jax.Array, v: jax.Array) -> jax.Array:
  """(||U||_F^2 + ||V||_F^2) / 2 — eq. (3)'s penalty for one factored GEMM."""
  return 0.5 * (frobenius_sq(u) + frobenius_sq(v))


def l2_penalty(w: jax.Array) -> jax.Array:
  """Standard l2 penalty, the paper's baseline regularizer: ||W||_F^2 / 2."""
  return 0.5 * frobenius_sq(w)


@dataclasses.dataclass(frozen=True)
class RegularizerConfig:
  """Regularization strengths, split as in paper §3.2.1.

  The paper found separate strengths for recurrent vs non-recurrent weights
  beneficial for both trace-norm and l2 regularization, and that for trace
  norm it works well to tie lambda_rec to a multiple of lambda_nonrec.
  """
  kind: str = "none"             # "none" | "trace" | "l2"
  lambda_rec: float = 0.0        # strength on recurrent-group weights
  lambda_nonrec: float = 0.0     # strength on non-recurrent-group weights

  def strength_for(self, group: str) -> float:
    return self.lambda_rec if group == "rec" else self.lambda_nonrec


def regularization_loss(params: Any, cfg: RegularizerConfig) -> jax.Array:
  """Total regularization term for a model param tree.

  Walks the tree for FactoredLinear leaves (paper's factored GEMMs) and
  applies the variational trace-norm penalty, or — for kind="l2" — applies
  the Frobenius penalty to the *product's* factors (equivalent to penalizing
  each factor; used when the stage-1 model is kept unfactored, l2 applies to
  plain 2D weight leaves tagged as GEMMs).
  """
  if cfg.kind == "none":
    return jnp.zeros((), jnp.float32)
  total = jnp.zeros((), jnp.float32)
  for leaf in iter_factored_leaves(params):
    lam = cfg.strength_for(leaf.group)
    if lam == 0.0:
      continue
    if leaf.is_factored:
      if cfg.kind == "trace":
        total = total + lam * variational_trace_norm_penalty(leaf.u, leaf.v)
      else:  # l2 on the factors of UV
        total = total + lam * (l2_penalty(leaf.u) + l2_penalty(leaf.v))
    else:
      # Unfactored GEMM: the exact trace norm is not cheaply differentiable
      # (it would need an SVD under grad). kind="l2" applies the Frobenius
      # baseline; kind="trace" skips it — the FactorizationPlan left this
      # GEMM out on purpose (min_dim / exclude), mirroring the paper's
      # "each *large* GEMM" scope.
      if cfg.kind == "l2":
        total = total + lam * l2_penalty(leaf.w)
  return total


# --------------------------------------------------------------------------
# Diagnostics: singular values, nu(W), rank @ explained-variance.
# --------------------------------------------------------------------------

def singular_values(w: jax.Array) -> jax.Array:
  """Singular values of a 2D matrix, descending, float32."""
  if w.ndim != 2:
    raise ValueError(f"expected 2D matrix, got shape {w.shape}")
  return jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)


def nu_coefficient(w: jax.Array) -> jax.Array:
  """Nondimensional trace norm coefficient nu(W) — paper Definition 1.

      nu(W) = (||sigma||_1 / ||sigma||_2 - 1) / (sqrt(d) - 1),  d = min(m, n)

  Properties (paper Prop. 1, property-tested in tests/test_tracenorm.py):
  scale-invariant; in [0, 1]; 0 iff rank 1; 1 iff maximal rank with all
  singular values equal. Smaller nu => better low-rank approximability.
  """
  sigma = singular_values(w)
  d = sigma.shape[0]
  if d < 2:
    raise ValueError("nu(W) requires min(m, n) >= 2")
  l1 = jnp.sum(sigma)
  l2 = jnp.sqrt(jnp.sum(sigma * sigma))
  return (l1 / l2 - 1.0) / (jnp.sqrt(jnp.asarray(d, jnp.float32)) - 1.0)


def nu_from_sigma(sigma: jax.Array) -> jax.Array:
  """nu from a precomputed singular value vector."""
  d = sigma.shape[0]
  l1 = jnp.sum(sigma)
  l2 = jnp.sqrt(jnp.sum(sigma * sigma))
  return (l1 / l2 - 1.0) / (jnp.sqrt(jnp.asarray(d, jnp.float32)) - 1.0)


def rank_for_variance(sigma: jax.Array, threshold: float) -> jax.Array:
  """Smallest k such that sum_{i<=k} sigma_i^2 >= threshold * sum sigma_i^2.

  This is the paper's SVD truncation rule ("retain only as many singular
  values as required to explain a specified percentage of the variance").
  """
  var = sigma * sigma
  cum = jnp.cumsum(var)
  total = cum[-1]
  frac = cum / jnp.maximum(total, 1e-30)
  # clamp to [1, d]: for an all-zero sigma the 1e-30 guard makes every
  # frac < threshold, which would report rank d + 1 (> len(sigma))
  return jnp.clip(jnp.sum(frac < threshold) + 1, 1, sigma.shape[0])


def trace_norm_metrics(params: Any) -> Mapping[str, jax.Array]:
  """Per-factored-GEMM diagnostics {name -> {nu, trace_norm, rank90}}.

  Used by the training loop's metric stream and the Fig. 2 / Fig. 3
  benchmarks. Runs SVDs — call sparingly (eval cadence, not per step).
  """
  out = {}
  for leaf in iter_factored_leaves(params):
    w = leaf.product()
    mats = ([(leaf.name, w)] if w.ndim == 2 else
            [(f"{leaf.name}[{i}]", m) for i, m in
             enumerate(w.reshape((-1,) + w.shape[-2:]))])
    for name, m in mats:
      sigma = singular_values(m)
      out[name] = {
          "nu": nu_from_sigma(sigma),
          "trace_norm": jnp.sum(sigma),
          "frobenius": jnp.sqrt(jnp.sum(sigma * sigma)),
          "rank90": rank_for_variance(sigma, 0.90),
      }
  return out
