"""Model-level compression driver — the paper's technique as a framework
feature.

A `FactorizationPlan` declares, by logical GEMM name pattern, which weights
of a model are factored and how their stage-2 rank is chosen. This mirrors
the paper's scope ("each large GEMM in the model") and Appendix B.2's
*partially joint* grouping: models expose their GRU recurrent weights as one
concatenated GEMM named `*/rec` and the non-recurrent ones as `*/nonrec`, so
the plan (and the regularizer's lambda_rec/lambda_nonrec split) operates at
exactly the granularity the paper chose.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Optional, Sequence

from repro.core import svd
from repro.core.factored import (FactoredLinear, count_params,
                                 iter_factored_leaves, map_factored_leaves)
from repro.core.svd import TruncationSpec


@dataclasses.dataclass(frozen=True)
class FactorizationPlan:
  """Which GEMMs to factor, matched on FactoredLinear.name glob patterns."""
  include: Sequence[str] = ("*",)       # glob patterns of GEMM names
  exclude: Sequence[str] = ()           # exceptions (e.g. "*embed*")
  min_dim: int = 128                    # don't factor tiny GEMMs
  truncation: TruncationSpec = TruncationSpec()

  def matches(self, leaf: FactoredLinear) -> bool:
    name = leaf.name
    if any(fnmatch.fnmatch(name, p) for p in self.exclude):
      return False
    if not any(fnmatch.fnmatch(name, p) for p in self.include):
      return False
    shape = leaf.u.shape[:-1] + (leaf.v.shape[-1],) if leaf.is_factored \
        else leaf.w.shape
    return min(shape[-2], shape[-1]) >= self.min_dim


def to_stage1(params: Any, plan: FactorizationPlan) -> Any:
  """Factor every matching GEMM at full rank (balanced SVD split).

  Stage-1 models are then trained with `RegularizerConfig(kind="trace")`.
  """
  def f(leaf: FactoredLinear) -> FactoredLinear:
    if not plan.matches(leaf) or leaf.is_factored:
      return leaf
    return svd.factorize_leaf(leaf)
  return map_factored_leaves(f, params)


def to_stage2(params: Any, plan: FactorizationPlan,
              truncation: Optional[TruncationSpec] = None,
              calib: Optional[dict] = None) -> Any:
  """Warmstart a stage-2 model: truncated SVD of every matching GEMM.

  `calib` maps leaf name -> input Gram matrix E[x x^T] ((m, m), or
  (L, m, m) per-layer for scan-stacked leaves) — or any object with a
  `.second_moment` attribute holding it, e.g. the `ActivationStats`
  that `repro.quant.calibrate_activation_stats` collects. Leaves with
  stats get the LiteASR activation-weighted truncation
  (`svd.activation_split`); leaves without fall back to the weight
  spectrum."""
  spec = truncation or plan.truncation
  calib = calib or {}
  def f(leaf: FactoredLinear) -> FactoredLinear:
    if not plan.matches(leaf):
      return leaf
    cov = calib.get(leaf.name)
    cov = getattr(cov, "second_moment", cov)
    return svd.truncate_leaf(leaf, spec, cov=cov)
  return map_factored_leaves(f, params)


def compression_report(before: Any, after: Any,
                       calib: Optional[dict] = None) -> dict:
  """Params/rank table for EXPERIMENTS.md and the tier benchmarks.

  When `calib` (the mapping handed to `to_stage2`) is given, each row
  records whether its rank was activation-calibrated — the ledger
  distinguishes spectrum-only from LiteASR-calibrated truncations."""
  rows = []
  b = {l.name: l for l in iter_factored_leaves(before)}
  for leaf in iter_factored_leaves(after):
    orig = b.get(leaf.name)
    rows.append({
        "name": leaf.name,
        "group": leaf.group,
        "shape": (leaf.in_dim, leaf.out_dim),
        "rank": leaf.rank if leaf.is_factored else None,
        "params": leaf.num_params,
        "params_before": orig.num_params if orig is not None else None,
        "calibrated": bool(calib) and leaf.name in calib,
    })
  return {
      "gemms": rows,
      "total_params_before": count_params(before),
      "total_params_after": count_params(after),
      "calibrated_gemms": sorted(calib.keys()) if calib else [],
  }


def leaf_names(params: Any) -> list[str]:
  return [l.name for l in iter_factored_leaves(params)]
