"""Core library: the paper's contribution as composable JAX modules.

  factored   — FactoredLinear pytree node (W = UV), constructors, traversal
  tracenorm  — variational trace-norm penalty, nu(W), singular-value metrics
  svd        — balanced SVD splits, explained-variance truncation, warmstart
  compress   — FactorizationPlan + stage-1/stage-2 tree drivers
  schedule   — two-stage training schedule + LR schedules
"""
from repro.core.factored import (FactoredLinear, count_params, dense,
                                 factored, is_gemm_leaf,
                                 iter_factored_leaves, iter_gemm_leaves,
                                 map_factored_leaves, register_gemm_leaf)
from repro.core.tracenorm import (RegularizerConfig, nu_coefficient,
                                  rank_for_variance, regularization_loss,
                                  singular_values, trace_norm_metrics,
                                  variational_trace_norm_penalty)
from repro.core.svd import (TruncationSpec, balanced_split,
                            explained_variance_rank, factorize_tree,
                            collapse_tree, warmstart_tree)
from repro.core.compress import (FactorizationPlan, compression_report,
                                 to_stage1, to_stage2)
from repro.core.schedule import (TwoStageSchedule, cosine_schedule,
                                 linear_warmup_exp_decay)

__all__ = [
    "FactoredLinear", "count_params", "dense", "factored", "is_gemm_leaf",
    "iter_factored_leaves", "iter_gemm_leaves", "map_factored_leaves",
    "register_gemm_leaf",
    "RegularizerConfig", "nu_coefficient", "rank_for_variance",
    "regularization_loss", "singular_values", "trace_norm_metrics",
    "variational_trace_norm_penalty",
    "TruncationSpec", "balanced_split", "explained_variance_rank",
    "factorize_tree", "collapse_tree", "warmstart_tree",
    "FactorizationPlan", "compression_report", "to_stage1", "to_stage2",
    "TwoStageSchedule", "cosine_schedule", "linear_warmup_exp_decay",
]
