"""Truncated-SVD warmstarting (paper §3, stage 1 -> stage 2).

Implements:
  * the Lemma-1 balanced split  W = (U sqrt(S)) (sqrt(S) V^T), which attains
    equality in the variational characterization — used to factorize a
    pretrained unfactored model into the stage-1 form;
  * explained-variance rank truncation ("retain only as many singular values
    as required to explain a specified percentage of the variance",
    Prabhavalkar et al. 2016);
  * tree-level warmstart: stage-1 (full-rank factored, trace-norm-trained)
    -> stage-2 (rank-truncated factored) models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.factored import FactoredLinear, map_factored_leaves


def balanced_split(w: jax.Array, rank: Optional[int] = None
                   ) -> tuple[jax.Array, jax.Array]:
  """Factor w (m, n) into u (m, r), v (r, n) with u = U sqrt(S), v = sqrt(S)V^T.

  This choice attains equality in Lemma 1: ||u||_F^2 = ||v||_F^2 = ||w||_T
  (when rank is full), so a stage-1 model warmstarted this way starts *at*
  the variational minimum of the penalty.
  """
  if w.ndim != 2:
    raise ValueError(f"balanced_split expects 2D, got {w.shape}")
  r = min(w.shape) if rank is None else rank
  uu, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
  sq = jnp.sqrt(s[:r])
  u = (uu[:, :r] * sq[None, :]).astype(w.dtype)
  v = (sq[:, None] * vt[:r, :]).astype(w.dtype)
  return u, v


def explained_variance_rank(s: jax.Array | np.ndarray, threshold: float) -> int:
  """Smallest r with sum_{i<r} s_i^2 >= threshold * sum s_i^2 (concrete int)."""
  s = np.asarray(s, dtype=np.float64)
  var = s * s
  cum = np.cumsum(var)
  total = cum[-1]
  if total <= 0:
    return 1
  return int(np.searchsorted(cum / total, threshold) + 1)


@dataclasses.dataclass(frozen=True)
class TruncationSpec:
  """How to pick the stage-2 rank for each GEMM."""
  variance_threshold: Optional[float] = 0.9   # paper's knob (Fig. 3/4)
  fixed_rank: Optional[int] = None            # override: exact rank
  max_rank: Optional[int] = None              # cap (latency budget)
  round_to: int = 8                           # TPU-friendly rank rounding

  def pick(self, s: np.ndarray) -> int:
    if self.fixed_rank is not None:
      r = self.fixed_rank
    else:
      r = explained_variance_rank(s, self.variance_threshold)
    if self.max_rank is not None:
      r = min(r, self.max_rank)
    r = max(self.round_to, int(np.ceil(r / self.round_to)) * self.round_to)
    return min(r, len(s))


def _whitener(cov: np.ndarray, eps: float = 1e-6) -> np.ndarray:
  """Cholesky factor L of a symmetrized, trace-regularized Gram matrix.

  cov is E[x x^T] (m, m) from the calibration tap; the regularization
  keeps the factorization defined when calibration saw fewer rows than
  m (rank-deficient Gram) without perturbing well-conditioned stats."""
  m = cov.shape[0]
  c = np.asarray(cov, np.float64)
  c = 0.5 * (c + c.T)
  c = c + (eps * np.trace(c) / m + 1e-12) * np.eye(m)
  return np.linalg.cholesky(c)


def activation_split(w, cov: np.ndarray, spec: TruncationSpec
                     ) -> tuple[jax.Array, jax.Array, np.ndarray]:
  """Activation-weighted truncated split of one 2-D GEMM (LiteASR).

  Spectrum-only truncation minimizes ||W - UV||_F, which weights every
  input direction equally; what serving accuracy cares about is the
  OUTPUT error E||x W - x UV||^2 = ||L^T (W - UV)||_F^2 with L the
  Cholesky factor of E[x x^T]. The minimizer is the truncated SVD of
  the whitened matrix L^T W = U' S V'^T mapped back through L^{-T}:

      u = L^{-T} U'_r sqrt(S_r),   v = sqrt(S_r) V'_r^T

  and the *rank itself* is picked from the whitened spectrum S — ranks
  follow output-reconstruction energy, not weight energy. Returns
  (u, v, whitened_singular_values)."""
  wl = np.asarray(w, np.float64)
  lch = _whitener(cov)
  uu, s, vt = np.linalg.svd(lch.T @ wl, full_matrices=False)
  r = spec.pick(s)
  sq = np.sqrt(s[:r])
  u = np.linalg.solve(lch.T, uu[:, :r] * sq[None, :])
  v = sq[:, None] * vt[:r, :]
  return (jnp.asarray(u.astype(np.asarray(w).dtype)),
          jnp.asarray(v.astype(np.asarray(w).dtype)), s)


def truncate_leaf(leaf: FactoredLinear, spec: TruncationSpec,
                  cov: Optional[np.ndarray] = None) -> FactoredLinear:
  """Stage-2 warmstart for one GEMM: truncated balanced SVD of product().

  With `cov` (the calibrated input Gram matrix E[x x^T]: (m, m), or
  (L, m, m) per-layer for a stacked leaf, or (m, m) broadcast over the
  stack) the split is activation-weighted: rank and factors both come
  from the whitened spectrum (see `activation_split`)."""
  w = leaf.product()
  if w.ndim == 2:
    if cov is not None:
      u, v, _ = activation_split(w, np.asarray(cov), spec)
      return FactoredLinear(w=None, u=u, v=v, name=leaf.name,
                            group=leaf.group)
    s = np.asarray(jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False))
    r = spec.pick(s)
    u, v = balanced_split(w, r)
    return FactoredLinear(w=None, u=u, v=v, name=leaf.name, group=leaf.group)
  # Stacked (L, m, n): pick one rank for the whole stack (max over layers) so
  # the scan stays homogeneous, then split each layer.
  flat = w.reshape((-1,) + w.shape[-2:])
  if cov is not None:
    covs = np.asarray(cov, np.float64)
    if covs.ndim == 2:
      covs = np.broadcast_to(covs, (flat.shape[0],) + covs.shape)
    else:
      covs = covs.reshape((-1,) + covs.shape[-2:])
    if covs.shape[0] != flat.shape[0]:
      raise ValueError(
          f"leaf {leaf.name!r}: {flat.shape[0]} stacked layers but "
          f"calibration has {covs.shape[0]} Gram matrices — layer-tagged "
          f"stats (dispatch.calibration_layer) are required per layer")
    whitened = [np.linalg.svd(_whitener(c).T @ np.asarray(m, np.float64),
                              compute_uv=False)
                for m, c in zip(flat, covs)]
    r = max(spec.pick(s) for s in whitened)
    fixed = dataclasses.replace(spec, fixed_rank=r, round_to=1)
    uvs = [activation_split(m, c, fixed)[:2] for m, c in zip(flat, covs)]
    us, vs = [u for u, _ in uvs], [v for _, v in uvs]
  else:
    svals = [np.asarray(jnp.linalg.svd(m.astype(jnp.float32),
                                       compute_uv=False))
             for m in flat]
    r = max(spec.pick(s) for s in svals)
    us, vs = [], []
    for m in flat:
      u, v = balanced_split(m, r)
      us.append(u)
      vs.append(v)
  u = jnp.stack(us).reshape(w.shape[:-2] + us[0].shape)
  v = jnp.stack(vs).reshape(w.shape[:-2] + vs[0].shape)
  return FactoredLinear(w=None, u=u, v=v, name=leaf.name, group=leaf.group)


def factorize_leaf(leaf: FactoredLinear, rank: Optional[int] = None
                   ) -> FactoredLinear:
  """Stage-1 form: full-rank balanced split of an unfactored GEMM."""
  if leaf.is_factored:
    return leaf
  w = leaf.w
  if w.ndim == 2:
    u, v = balanced_split(w, rank)
  else:
    flat = w.reshape((-1,) + w.shape[-2:])
    uvs = [balanced_split(m, rank) for m in flat]
    u = jnp.stack([x for x, _ in uvs]).reshape(w.shape[:-2] + uvs[0][0].shape)
    v = jnp.stack([x for _, x in uvs]).reshape(w.shape[:-2] + uvs[0][1].shape)
  return FactoredLinear(w=None, u=u, v=v, name=leaf.name, group=leaf.group)


def collapse_leaf(leaf: FactoredLinear) -> FactoredLinear:
  """Inverse of factorize: materialize W = UV as an unfactored node."""
  if not leaf.is_factored:
    return leaf
  return FactoredLinear(w=leaf.product(), u=None, v=None,
                        name=leaf.name, group=leaf.group)


# -- tree-level drivers ------------------------------------------------------

def warmstart_tree(params: Any, spec: TruncationSpec) -> Any:
  """Stage-1 -> stage-2: truncate every factored GEMM in the tree."""
  return map_factored_leaves(lambda l: truncate_leaf(l, spec), params)


def factorize_tree(params: Any) -> Any:
  """Unfactored -> stage-1 full-rank factored (balanced SVD split)."""
  return map_factored_leaves(factorize_leaf, params)


def collapse_tree(params: Any) -> Any:
  """Factored -> unfactored (e.g. before export or re-factorization)."""
  return map_factored_leaves(collapse_leaf, params)
