"""Two-stage training schedule (paper §3.2.2–3.2.3).

Stage 1: full-rank factored model + trace-norm (or l2) regularization.
Stage 2: truncated-SVD warmstart, regularization off.

§3.2.3's finding: the transition can happen well before stage-1 convergence
(epoch 15 of 80 in the paper) with no CER loss, and the learning-rate
schedule should *continue across the transition* as if a single model were
being trained — stage 2 inherits the stage-1 LR at the transition step.
(§3.2.2's alternative, used when stage 1 ran to convergence: restart stage-2
LR at 3x the final stage-1 LR.)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig


@dataclasses.dataclass(frozen=True)
class TwoStageSchedule:
  total_steps: int
  transition_step: int                  # stage-1 -> stage-2 switch
  regularizer: RegularizerConfig        # applied during stage 1 only
  truncation: TruncationSpec            # rank rule at the transition
  # LR policy: "continue" (paper §3.2.3) or "restart_3x" (paper §3.2.2).
  lr_policy: str = "continue"

  def stage(self, step: int) -> int:
    return 1 if step < self.transition_step else 2

  def regularizer_at(self, step: int) -> RegularizerConfig:
    if self.stage(step) == 1:
      return self.regularizer
    return RegularizerConfig(kind="none")

  def stage2_lr_scale(self) -> float:
    return 1.0 if self.lr_policy == "continue" else 3.0


def linear_warmup_exp_decay(base_lr: float, warmup: int, decay: float,
                            decay_every: int):
  """The DS2-style LR schedule used by the speech reproduction: linear
  warmup then stepwise exponential decay ("anneal by a constant factor each
  epoch", Amodei et al. 2016)."""
  def lr(step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    n_decays = jnp.floor(jnp.maximum(step - warmup, 0.0) / decay_every)
    return base_lr * warm * (decay ** n_decays)
  return lr


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
  """Cosine decay with warmup — used by the LM training examples."""
  def lr(step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos
  return lr
