"""Factored GEMM parameterization — the paper's W = UV building block.

Every "large GEMM" weight in this framework is held in a `FactoredLinear`
pytree node. The node is either *unfactored* (`w` set) or *factored*
(`u`, `v` set, `w` None). Stage-1 training (paper §3.1) uses full-rank
factored nodes (r = min(m, n)) with the variational trace-norm penalty;
stage-2 uses truncated nodes (r chosen by explained variance); inference
consumes factored nodes through the fused low-rank Pallas kernels.

Metadata (static, not traced):
  name  — logical GEMM name ("gru0/rec", "attn/qkv", ...), used by
          factorization plans and sharding rules.
  group — "rec" | "nonrec": the paper's regularization split (§3.2.1,
          Appendix B.2). Recurrent weights get lambda_rec, everything
          else lambda_nonrec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp


def acc_dtype(x: jax.Array):
  """Dot output dtype policy — THE single source of truth for GEMM
  accumulation behavior (FactoredLinear.apply and layers.common.gemm
  both route through it): bf16 inputs emit bf16 directly — the MXU still
  accumulates f32 internally, and emitting bf16 halves the GEMM output
  HBM traffic and makes the TP all-reduces bf16 instead of f32
  (EXPERIMENTS.md §Perf iteration A1). f32 inputs keep f32 (CPU tests)."""
  return x.dtype if x.dtype == jnp.bfloat16 else jnp.float32


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
  """The framework's reference GEMM: y = x @ w with `acc_dtype`
  accumulation, output in x.dtype. Defined once, next to the dtype
  policy, so layers.common.gemm, the kernel dispatcher's jnp regime, and
  the tied-embedding head all share one code object — the jnp_only
  bit-exactness guarantee hangs on this."""
  return jnp.matmul(x, w, preferred_element_type=acc_dtype(x)).astype(
      x.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FactoredLinear:
  """A GEMM weight, unfactored (w) or factored (u @ v).

  Shapes: w: (..., m, n); u: (..., m, r); v: (..., r, n). Leading
  dimensions (e.g. a stacked layer axis under jax.lax.scan) are allowed and
  batch through product()/apply().
  """
  w: Optional[jax.Array]
  u: Optional[jax.Array]
  v: Optional[jax.Array]
  name: str = dataclasses.field(metadata=dict(static=True), default="gemm")
  group: str = dataclasses.field(metadata=dict(static=True), default="nonrec")

  # -- structure ------------------------------------------------------------
  @property
  def is_factored(self) -> bool:
    return self.u is not None

  @property
  def in_dim(self) -> int:
    return self.u.shape[-2] if self.is_factored else self.w.shape[-2]

  @property
  def out_dim(self) -> int:
    return self.v.shape[-1] if self.is_factored else self.w.shape[-1]

  @property
  def rank(self) -> int:
    """Factorization rank (min(m, n) if unfactored)."""
    if self.is_factored:
      return self.u.shape[-1]
    return min(self.w.shape[-2], self.w.shape[-1])

  @property
  def num_params(self) -> int:
    if self.is_factored:
      return self.u.size + self.v.size
    return self.w.size

  @property
  def dtype(self):
    return self.u.dtype if self.is_factored else self.w.dtype

  # -- math -----------------------------------------------------------------
  def product(self) -> jax.Array:
    """Materialize W = UV (or return w). Batches over leading dims."""
    if self.is_factored:
      return jnp.matmul(
          self.u, self.v, preferred_element_type=jnp.float32
      ).astype(self.u.dtype)
    return self.w

  def apply(self, x: jax.Array, policy=None) -> jax.Array:
    """y = x @ W, computed as (x @ U) @ V when factored.

    The factored path is the paper's inference form: two skinny GEMMs of
    r(m + n) total weight bytes instead of one mn GEMM — bandwidth-bound
    decode reads r(m+n)/mn of the unfactored traffic. Accumulation dtype
    follows `acc_dtype` (one policy for every GEMM in the framework).
    Weights must be 2D: a stacked leaf against a batched activation
    would silently broadcast the layer axis against the batch axis.

    `policy` (a kernels.dispatch.KernelPolicy) routes the GEMM to the
    shape-specialized Pallas kernels; None keeps the jnp path below.
    Imported lazily: core.factored is the leaf module kernels.dispatch
    itself depends on.
    """
    if policy is not None:
      from repro.kernels import dispatch
      return dispatch.gemm(self, x, policy)
    acc = acc_dtype(x)
    if self.is_factored:
      if self.u.ndim != 2:
        raise ValueError("apply() expects 2D factors; slice stacked dims first")
      t = jnp.matmul(x, self.u, preferred_element_type=acc)
      t = t.astype(x.dtype)
      return jnp.matmul(t, self.v, preferred_element_type=acc).astype(x.dtype)
    if self.w.ndim != 2:
      raise ValueError("apply() expects a 2D weight; slice stacked dims first")
    return matmul_ref(x, self.w)

  def __call__(self, x: jax.Array) -> jax.Array:
    return self.apply(x)


# ----------------------------------------------------------------------------
# Constructors.
# ----------------------------------------------------------------------------

def dense(key: jax.Array, m: int, n: int, *, name: str, group: str = "nonrec",
          dtype=jnp.float32, scale: Optional[float] = None,
          stack: tuple[int, ...] = ()) -> FactoredLinear:
  """Unfactored GEMM with LeCun-normal init (stddev 1/sqrt(m))."""
  scale = (1.0 / m) ** 0.5 if scale is None else scale
  w = jax.random.normal(key, stack + (m, n), jnp.float32) * scale
  return FactoredLinear(w=w.astype(dtype), u=None, v=None, name=name,
                        group=group)


def factored(key: jax.Array, m: int, n: int, r: Optional[int] = None, *,
             name: str, group: str = "nonrec", dtype=jnp.float32,
             scale: Optional[float] = None,
             stack: tuple[int, ...] = ()) -> FactoredLinear:
  """Factored GEMM with r = min(m, n) by default (stage-1 full-rank form).

  Init: U, V each get stddev (scale / r)^(1/2) * (1/m)^(1/4)-style balanced
  init so that W = UV has the same variance as the dense init above and
  ||U||_F^2 == ||V||_F^2 at init (the penalty's minimizer is balanced).
  """
  r = min(m, n) if r is None else r
  ku, kv = jax.random.split(key)
  scale = (1.0 / m) ** 0.5 if scale is None else scale
  # var(W_ij) = r * var(U) * var(V); balance var(U)*m == var(V)*... we simply
  # take su = sv = sqrt(scale / sqrt(r)) giving var(W) = scale^2.
  s = (scale / (r ** 0.5)) ** 0.5
  u = jax.random.normal(ku, stack + (m, r), jnp.float32) * s
  v = jax.random.normal(kv, stack + (r, n), jnp.float32) * s
  return FactoredLinear(w=None, u=u.astype(dtype), v=v.astype(dtype),
                        name=name, group=group)


# ----------------------------------------------------------------------------
# Tree traversal.
# ----------------------------------------------------------------------------

#: GEMM-leaf node types every tree traversal stops at. FactoredLinear is
#: built in; sibling leaf representations living in the same name/group
#: namespace (repro.quant's QuantizedLinear) register themselves on import
#: so traversal, param counting, and reports treat them as whole GEMMs
#: instead of descending into their arrays.
GEMM_LEAF_TYPES: tuple = (FactoredLinear,)


def register_gemm_leaf(cls) -> type:
  """Register another GEMM-leaf node type (idempotent; returns `cls` so it
  can be used as a class decorator)."""
  global GEMM_LEAF_TYPES
  if cls not in GEMM_LEAF_TYPES:
    GEMM_LEAF_TYPES = GEMM_LEAF_TYPES + (cls,)
  return cls


def is_gemm_leaf(x: Any) -> bool:
  return isinstance(x, GEMM_LEAF_TYPES)


def iter_factored_leaves(tree: Any) -> Iterator[FactoredLinear]:
  """Yield every FactoredLinear node in a pytree (depth-first).

  FactoredLinear registers as a pytree *node*, so plain tree_flatten would
  descend into it; we traverse with `is_leaf` to stop at the node level.
  Other GEMM-leaf types (e.g. QuantizedLinear) are passed over whole, not
  descended into.
  """
  leaves = jax.tree.leaves(tree, is_leaf=is_gemm_leaf)
  for leaf in leaves:
    if isinstance(leaf, FactoredLinear):
      yield leaf


def map_factored_leaves(fn, tree: Any) -> Any:
  """tree_map over FactoredLinear nodes only (other leaves — including
  other registered GEMM-leaf nodes — untouched)."""
  return jax.tree.map(
      lambda x: fn(x) if isinstance(x, FactoredLinear) else x,
      tree, is_leaf=is_gemm_leaf)


def iter_gemm_leaves(tree: Any) -> Iterator[Any]:
  """Yield every GEMM-leaf node of any registered type (depth-first)."""
  for leaf in jax.tree.leaves(tree, is_leaf=is_gemm_leaf):
    if is_gemm_leaf(leaf):
      yield leaf


def count_params(tree: Any) -> int:
  """Total parameter count, counting factored nodes at their factored size."""
  total = 0
  for leaf in jax.tree.leaves(tree, is_leaf=is_gemm_leaf):
    if is_gemm_leaf(leaf):
      total += leaf.num_params
    else:
      total += leaf.size
  return total
