"""Checkpoint save/restore with elastic resharding."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
