"""Checkpointing: host-gathered numpy shards per pytree leaf + JSON
manifest; restore reshards onto any mesh (elastic: save on N devices,
load on M). Async saves run on a background thread so the step loop never
blocks on disk.

Layout:
  <dir>/step_000042.tmp/...   (written first)
  <dir>/step_000042/          (atomic rename on completion)
      manifest.json           {leaf path -> file, dtype, shape, meta}
      <leaf>.npy              one file per pytree leaf

Keyed by pytree *path*, so restore only needs a structure template (from
jax.eval_shape over the model init) — static FactoredLinear /
QuantizedLinear metadata never touches disk and can evolve without
invalidating checkpoints. Quantized (PTQ) trees are first-class: int8
weight arrays and f32 scales are ordinary leaves ("fc/w_q",
"fc/w_scale", ...) and round-trip bit-identically, so a PTQ'd checkpoint
is a deployable serving artifact.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# numpy round-trips ml_dtypes (bfloat16, float8) as raw void ("V2") — store
# them as unsigned views and restore through the manifest's dtype string
_EXOTIC_VIEW = {2: np.uint16, 1: np.uint8}


def _to_native(arr: np.ndarray) -> tuple[np.ndarray, str]:
  dt = str(arr.dtype)
  if arr.dtype.kind not in "biufc":       # ml_dtypes etc.
    return arr.view(_EXOTIC_VIEW[arr.dtype.itemsize]), dt
  return arr, dt


def _from_native(arr: np.ndarray, dtype_str: str) -> np.ndarray:
  if arr.dtype.kind not in "biufc" or str(arr.dtype) != dtype_str:
    try:
      return arr.view(jnp.dtype(dtype_str))
    except TypeError:
      return arr
  return arr


def _path_str(path) -> str:
  toks = []
  for k in path:
    if hasattr(k, "key"):
      toks.append(str(k.key))
    elif hasattr(k, "name"):
      toks.append(str(k.name))
    elif hasattr(k, "idx"):
      toks.append(str(k.idx))
    else:
      toks.append(str(k))
  return "/".join(toks)


def _fname(path_str: str) -> str:
  return re.sub(r"[^A-Za-z0-9_.-]", "_", path_str) + ".npy"


class CheckpointManager:

  def __init__(self, directory: str, *, keep: int = 3):
    self.directory = directory
    self.keep = keep
    os.makedirs(directory, exist_ok=True)
    self._thread: Optional[threading.Thread] = None

  # -- save -----------------------------------------------------------------

  def save(self, step: int, tree: Any, *, extra: Optional[dict] = None,
           blocking: bool = True) -> None:
    """Gather every leaf to host and persist. blocking=False runs the disk
    write on a background thread (the gather happens inline — cheap next to
    a training step — so the live tree can keep mutating)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    host = [(_path_str(p), np.asarray(jax.device_get(x))) for p, x in flat]
    if blocking:
      self._write(step, host, extra)
    else:
      self.wait()
      self._thread = threading.Thread(
          target=self._write, args=(step, host, extra), daemon=True)
      self._thread.start()

  def wait(self) -> None:
    if self._thread is not None:
      self._thread.join()
      self._thread = None

  def _write(self, step: int, host: list, extra: Optional[dict]) -> None:
    final = os.path.join(self.directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
      shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for pstr, arr in host:
      fn = _fname(pstr)
      native, dtype_str = _to_native(arr)
      np.save(os.path.join(tmp, fn), native)
      manifest["leaves"][pstr] = {
          "file": fn, "dtype": dtype_str, "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
      json.dump(manifest, f)
    if os.path.exists(final):
      shutil.rmtree(final)
    os.rename(tmp, final)
    self._gc()

  def _gc(self) -> None:
    steps = self.all_steps()
    for s in steps[:-self.keep] if self.keep else []:
      shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                    ignore_errors=True)

  # -- restore ----------------------------------------------------------------

  def all_steps(self) -> list[int]:
    out = []
    for d in os.listdir(self.directory):
      m = re.fullmatch(r"step_(\d+)", d)
      if m:
        out.append(int(m.group(1)))
    return sorted(out)

  def latest_step(self) -> Optional[int]:
    steps = self.all_steps()
    return steps[-1] if steps else None

  def restore(self, template: Any, *, step: Optional[int] = None,
              shardings: Any = None) -> tuple[Any, dict]:
    """Rebuild `template`'s structure with stored leaves.

    template: pytree of arrays or ShapeDtypeStructs (e.g. from eval_shape).
    shardings: optional matching tree of NamedSharding — the elastic
    reshard path (checkpoint saved on any topology lands on this one).
    Returns (tree, manifest_extra).

    Restore is template-driven; a checkpoint leaf with no template path
    raises a UserWarning instead of disappearing silently — e.g. a
    calibration-quantized tree (act_scale leaves on disk) restored with
    an uncalibrated template would otherwise quietly fall back to
    dynamic activation quantization and change serving numerics.
    """
    if step is None:
      step = self.latest_step()
      if step is None:
        raise FileNotFoundError(f"no checkpoints in {self.directory}")
    d = os.path.join(self.directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
      manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = None
    if shardings is not None:
      shard_flat = jax.tree.flatten(
          shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
      )[0]
    leaves = []
    consumed = set()
    for i, (p, t) in enumerate(flat):
      pstr = _path_str(p)
      consumed.add(pstr)
      ent = manifest["leaves"].get(pstr)
      if ent is None:
        raise KeyError(f"checkpoint {d} missing leaf {pstr}")
      arr = np.load(os.path.join(d, ent["file"]))
      arr = _from_native(arr, ent["dtype"])
      if tuple(arr.shape) != tuple(t.shape):
        raise ValueError(
            f"shape mismatch for {pstr}: ckpt {arr.shape} vs {t.shape}")
      if shard_flat is not None:
        leaves.append(jax.device_put(arr, shard_flat[i]))
      else:
        leaves.append(jax.numpy.asarray(arr))
    unused = sorted(set(manifest["leaves"]) - consumed)
    if unused:
      warnings.warn(
          f"checkpoint {d} has {len(unused)} leaves the template does not "
          f"reference (first few: {unused[:4]}); they were NOT restored",
          stacklevel=2)
    return treedef.unflatten(leaves), manifest.get("extra", {})
