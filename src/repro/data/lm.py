"""Synthetic LM stream: deterministic, sharded, learnable.

Sequences mix a fixed random bigram successor function (token_{t+1} =
perm[token_t]) with uniform noise; a model that learns the bigram table
drives cross-entropy well below the entropy of uniform sampling, so the
pipeline supports real end-to-end training tests, not just shape checks.

The stream is stateless in (seed, step): any worker can regenerate any
batch — this is what makes checkpoint/restart trivially consistent for
the data layer (no loader state to save) and is how the supervisor's
recovery path replays in-flight steps.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
  vocab_size: int
  seq_len: int
  global_batch: int
  seed: int = 0
  structure: float = 0.8      # fraction of bigram-followed transitions


def _perm(cfg: LMDataConfig) -> np.ndarray:
  rng = np.random.RandomState(cfg.seed + 12345)
  return rng.permutation(cfg.vocab_size)


def batch_at(cfg: LMDataConfig, step: int) -> dict:
  """Regenerable batch for a global step: {tokens, targets} (B, S) int32."""
  rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2 ** 31))
  perm = _perm(cfg)
  b, s = cfg.global_batch, cfg.seq_len
  toks = np.empty((b, s + 1), np.int32)
  toks[:, 0] = rng.randint(0, cfg.vocab_size, size=b)
  structured = rng.rand(b, s) < cfg.structure
  noise = rng.randint(0, cfg.vocab_size, size=(b, s))
  for t in range(s):
    nxt = perm[toks[:, t]]
    toks[:, t + 1] = np.where(structured[:, t], nxt, noise[:, t])
  return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def stream(cfg: LMDataConfig, start_step: int = 0) -> Iterator[dict]:
  step = start_step
  while True:
    yield batch_at(cfg, step)
    step += 1


def shard_batch(batch: dict, sharding) -> dict:
  """Place a host batch onto devices with the given NamedSharding tree."""
  if sharding is None:
    return {k: jax.numpy.asarray(v) for k, v in batch.items()}
  if not isinstance(sharding, dict):
    sharding = {k: sharding for k in batch}
  return {k: jax.device_put(v, sharding[k]) for k, v in batch.items()}
