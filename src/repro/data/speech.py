"""Synthetic speech task for the DS2/CTC reproduction.

WSJ (80 h audio) is not available offline; this pipeline generates random
"phone" strings and renders them to noisy mel-like feature sequences:
each label id owns a fixed random prototype feature vector, emitted for a
random duration (2-4 frames) with additive noise and random silence gaps.
A DS2 model must learn prototype->label mapping and CTC alignment — the
task exercises exactly the (acoustic model, CTC) pair the paper trains,
and its CER responds to capacity/regularization the way Figures 1-5 need
(see EXPERIMENTS.md for the scale caveat).

Like data/lm.py, batches are stateless in (seed, step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpeechDataConfig:
  vocab_size: int = 32          # labels 1..vocab-1 (0 = CTC blank)
  feat_dim: int = 80
  min_label_len: int = 6
  max_label_len: int = 24
  # frames per phone: DS2's conv frontend strides time by 4x, and CTC needs
  # output_length >= label_length — min_dur 5 keeps every utterance feasible
  min_dur: int = 5
  max_dur: int = 8
  silence_prob: float = 0.15
  noise: float = 0.4
  global_batch: int = 16
  seed: int = 0

  @property
  def max_frames(self) -> int:
    return self.max_label_len * (self.max_dur + 2) + 8


def _prototypes(cfg: SpeechDataConfig) -> np.ndarray:
  rng = np.random.RandomState(cfg.seed + 777)
  return rng.randn(cfg.vocab_size, cfg.feat_dim).astype(np.float32)


def batch_at(cfg: SpeechDataConfig, step: int) -> dict:
  rng = np.random.RandomState((cfg.seed * 9_999_991 + step) % (2 ** 31))
  protos = _prototypes(cfg)
  b = cfg.global_batch
  t_max = cfg.max_frames
  l_max = cfg.max_label_len
  feats = np.zeros((b, t_max, cfg.feat_dim), np.float32)
  labels = np.zeros((b, l_max), np.int32)
  feat_lengths = np.zeros((b,), np.int32)
  label_lengths = np.zeros((b,), np.int32)
  for i in range(b):
    n = rng.randint(cfg.min_label_len, cfg.max_label_len + 1)
    seq = rng.randint(1, cfg.vocab_size, size=n)
    labels[i, :n] = seq
    label_lengths[i] = n
    t = 0
    for ph in seq:
      if rng.rand() < cfg.silence_prob:
        gap = rng.randint(1, 3)
        t += gap                      # silence = zeros
      dur = rng.randint(cfg.min_dur, cfg.max_dur + 1)
      feats[i, t:t + dur] = protos[ph][None, :]
      t += dur
    t = min(t + rng.randint(0, 4), t_max)
    feat_lengths[i] = t
  feats += rng.randn(*feats.shape).astype(np.float32) * cfg.noise
  return {"feats": feats, "feat_lengths": feat_lengths,
          "labels": labels, "label_lengths": label_lengths}


def stream(cfg: SpeechDataConfig, start_step: int = 0) -> Iterator[dict]:
  step = start_step
  while True:
    yield batch_at(cfg, step)
    step += 1


# ---------------------------------------------------------------------------
# CER metric (the paper's accuracy axis).
# ---------------------------------------------------------------------------

def edit_distance(a: np.ndarray, b: np.ndarray) -> int:
  """Levenshtein distance between two int sequences."""
  la, lb = len(a), len(b)
  dp = np.arange(lb + 1)
  for i in range(1, la + 1):
    prev = dp.copy()
    dp[0] = i
    for j in range(1, lb + 1):
      cost = 0 if a[i - 1] == b[j - 1] else 1
      dp[j] = min(prev[j] + 1, dp[j - 1] + 1, prev[j - 1] + cost)
  return int(dp[lb])


def cer(decoded: np.ndarray, labels: np.ndarray,
        label_lengths: np.ndarray) -> float:
  """Character error rate from greedy-decoded sequences (-1 padded)."""
  total_err, total_len = 0, 0
  for i in range(len(labels)):
    hyp = decoded[i][decoded[i] >= 0]
    tgt = labels[i][:label_lengths[i]]
    total_err += edit_distance(hyp, tgt)
    total_len += len(tgt)
  return total_err / max(total_len, 1)
