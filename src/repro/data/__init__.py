"""Data pipelines: synthetic LM stream + synthetic speech (CTC) task.

Both are stateless in (seed, step) — any batch can be regenerated on any
host, which makes checkpoint/restart and elastic rescaling trivial at the
data layer.
"""
from repro.data import lm, speech
from repro.data.lm import LMDataConfig
from repro.data.speech import SpeechDataConfig, cer, edit_distance

__all__ = ["lm", "speech", "LMDataConfig", "SpeechDataConfig", "cer",
           "edit_distance"]
