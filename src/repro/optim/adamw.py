"""AdamW over arbitrary pytrees (FactoredLinear nodes are ordinary
subtrees of arrays, so the paper's factored params need no special case).

Moments are stored in f32 regardless of param dtype; the decoupled weight
decay skips 1D params (norms, biases) following standard practice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
  step: jax.Array
  m: Any
  v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
  b1: float = 0.9
  b2: float = 0.999
  eps: float = 1e-8
  weight_decay: float = 0.0
  max_grad_norm: float = 0.0        # 0 = no clipping


def init(params: Any) -> AdamState:
  zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
  return AdamState(step=jnp.zeros((), jnp.int32),
                   m=jax.tree.map(zeros, params),
                   v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
  leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)]
  return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
  norm = global_norm(grads)
  scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
  return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                 ).astype(g.dtype), grads), norm


def apply(params: Any, grads: Any, state: AdamState, lr: jax.Array,
          cfg: AdamWConfig) -> tuple[Any, AdamState, dict]:
  metrics = {}
  if cfg.max_grad_norm > 0:
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    metrics["grad_norm"] = gnorm
  step = state.step + 1
  b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
  b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

  def upd(p, g, m, v):
    g = g.astype(jnp.float32)
    m1 = cfg.b1 * m + (1 - cfg.b1) * g
    v1 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m1 / b1c
    vhat = v1 / b2c
    delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if cfg.weight_decay and p.ndim >= 2:
      delta = delta + cfg.weight_decay * p.astype(jnp.float32)
    p1 = p.astype(jnp.float32) - lr * delta
    return p1.astype(p.dtype), m1, v1

  # three passes (XLA CSEs the shared subexpressions under jit)
  new_p = jax.tree.map(lambda *a: upd(*a)[0], params, grads, state.m,
                       state.v)
  new_m = jax.tree.map(lambda *a: upd(*a)[1], params, grads, state.m,
                       state.v)
  new_v = jax.tree.map(lambda *a: upd(*a)[2], params, grads, state.m,
                       state.v)
  return new_p, AdamState(step=step, m=new_m, v=new_v), metrics
