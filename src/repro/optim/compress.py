"""Int8 gradient compression with error feedback for the pod-axis
all-reduce (DESIGN §6): the cross-pod links are the scarce resource, so
the gradient reduction that crosses them is quantized to int8 — 4x fewer
wire bytes than f32 (2x vs bf16) — with the quantization error carried to
the next step (error feedback keeps the method unbiased over time).

`compressed_psum` is written for use inside shard_map over the pod axis:
  1. all shards agree on a common scale (psum-max of amax);
  2. each shard quantizes (g + err) to int8;
  3. the int8 payload is summed across pods (int32 accumulate — the wire
     payload is the int8 tensor; XLA upcasts at the reduction);
  4. dequantize with the common scale; the residual stays local.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_common_scale(x: jax.Array, axis_name: str
                          ) -> tuple[jax.Array, jax.Array]:
  """Per-tensor symmetric int8 with a scale agreed across `axis_name`."""
  amax = jnp.max(jnp.abs(x))
  amax = jax.lax.pmax(amax, axis_name)
  scale = jnp.maximum(amax, 1e-12) / 127.0
  q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
  return q, scale


def compressed_psum(x: jax.Array, axis_name: str,
                    err: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
  """Mean of x over `axis_name` via int8 wire payload.

  Returns (mean_estimate, new_error_residual). Call inside shard_map.
  """
  xf = x.astype(jnp.float32)
  if err is not None:
    xf = xf + err.astype(jnp.float32)
  q, scale = quantize_common_scale(xf, axis_name)
  local_hat = q.astype(jnp.float32) * scale
  new_err = xf - local_hat
  total = jax.lax.psum(q.astype(jnp.int32), axis_name)
  n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
  mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
  return mean.astype(x.dtype), new_err.astype(jnp.float32)


def compressed_grad_mean(grads: Any, errs: Any, axis_name: str
                         ) -> tuple[Any, Any]:
  """Tree-level error-feedback compressed mean (inside shard_map)."""
  flat_g, treedef = jax.tree.flatten(grads)
  flat_e = jax.tree.leaves(errs)
  outs = [compressed_psum(g, axis_name, e) for g, e in zip(flat_g, flat_e)]
  new_g = treedef.unflatten([o[0] for o in outs])
  new_e = treedef.unflatten([o[1] for o in outs])
  return new_g, new_e


def init_error(params: Any) -> Any:
  return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
