"""Optimizers + distributed-optimization tricks.

  adamw     — f32-moment AdamW, FactoredLinear-transparent
  q_adam    — int8-moment Adam (fits deepseek-v3-671b optimizer state)
  compress  — int8 error-feedback gradient compression (pod axis)
"""
from repro.optim import adamw, compress, q_adam
from repro.optim.adamw import (AdamState, AdamWConfig, clip_by_global_norm,
                               global_norm)
from repro.optim.q_adam import QAdamState, QTensor

__all__ = ["adamw", "compress", "q_adam", "AdamState", "AdamWConfig",
           "clip_by_global_norm", "global_norm", "QAdamState", "QTensor",
           "make_optimizer"]


def make_optimizer(kind: str):
  """kind: 'adamw' | 'q_adam' -> (init, apply) pair."""
  if kind == "adamw":
    return adamw.init, adamw.apply
  if kind == "q_adam":
    return q_adam.init, q_adam.apply
  raise ValueError(f"unknown optimizer {kind}")
