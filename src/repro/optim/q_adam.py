"""Int8-quantized-state Adam — the distributed-optimization trick that
lets deepseek-v3-671b's optimizer state fit a 256-chip pod (DESIGN §5).

Both moments are stored as int8 with per-row (last-axis) f32 scales:
   m ~ q_m * scale_m,   scale per leading index, symmetric, amax/127.
Each step dequantizes, applies the Adam update in f32, and requantizes.
The quantization error behaves like a small moment-EMA perturbation;
block-wise scaling keeps it below Adam's own eps noise floor in practice
(validated against exact AdamW in tests/test_optim.py).

State cost: 2 bytes/param (vs 8 for f32 Adam) + scales (1/last_dim).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, clip_by_global_norm


class QTensor(NamedTuple):
  q: jax.Array          # int8, same shape as the param
  scale: jax.Array      # f32, shape = param.shape[:-1] + (1,)


class QAdamState(NamedTuple):
  step: jax.Array
  m: Any                # tree of QTensor
  v: Any                # tree of QTensor


def _quantize(x: jax.Array) -> QTensor:
  amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
  scale = jnp.maximum(amax, 1e-12) / 127.0
  q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
  return QTensor(q=q, scale=scale)


def _dequantize(t: QTensor) -> jax.Array:
  return t.q.astype(jnp.float32) * t.scale


def init(params: Any) -> QAdamState:
  def zq(p):
    shape = p.shape if p.ndim else (1,)
    return QTensor(q=jnp.zeros(shape, jnp.int8),
                   scale=jnp.zeros(shape[:-1] + (1,), jnp.float32))
  return QAdamState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zq, params),
                    v=jax.tree.map(zq, params))


# NOTE (EXPERIMENTS §Perf, iteration D1): scanning this update over the
# stacked layer axis of the huge expert leaves was tried to cut the f32
# dequant/requant transients — refuted twice: per-layer slices of the
# 218B-param stacks are still 15 GB, and flattening the leading axes
# breaks the (E: model, d: data) sharding propagation (XLA replicates the
# whole stack). The transient gap needs sharding-aware chunking or leaf
# splitting at init; left as the recorded gap.
_SCAN_UPDATE_ELEMS = None      # scanning disabled (see note)


def apply(params: Any, grads: Any, state: QAdamState, lr: jax.Array,
          cfg: AdamWConfig) -> tuple[Any, QAdamState, dict]:
  metrics = {}
  if cfg.max_grad_norm > 0:
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    metrics["grad_norm"] = gnorm
  step = state.step + 1
  b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
  b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

  def upd(p, g, mq, vq):
    g = g.astype(jnp.float32)
    if g.ndim == 0:
      g = g[None]
      squeeze = True
    else:
      squeeze = False
    m = cfg.b1 * _dequantize(mq) + (1 - cfg.b1) * g
    v = cfg.b2 * _dequantize(vq) + (1 - cfg.b2) * g * g
    mhat = m / b1c
    vhat = v / b2c
    delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
    if squeeze:
      delta = delta[0]
    pf = p.astype(jnp.float32)
    if cfg.weight_decay and p.ndim >= 2:
      delta = delta + cfg.weight_decay * pf
    p1 = (pf - lr * delta).astype(p.dtype)
    return p1, _quantize(m), _quantize(v)

  def upd_leaf(p, g, mq, vq):
    return upd(p, g, mq, vq)

  p_leaves, tdef = jax.tree.flatten(params)
  g_leaves = jax.tree.leaves(grads)
  is_q = lambda t: isinstance(t, QTensor)
  m_leaves = jax.tree.leaves(state.m, is_leaf=is_q)
  v_leaves = jax.tree.leaves(state.v, is_leaf=is_q)
  results = [upd_leaf(p, g, m, v) for p, g, m, v in
             zip(p_leaves, g_leaves, m_leaves, v_leaves)]
  new_p = tdef.unflatten([r[0] for r in results])
  new_m = tdef.unflatten([r[1] for r in results])
  new_v = tdef.unflatten([r[2] for r in results])
  return new_p, QAdamState(step=step, m=new_m, v=new_v), metrics
