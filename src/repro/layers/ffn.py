"""Gated FFN (SwiGLU) and plain GELU FFN blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers.common import gemm, identity_constraint


def init_swiglu(key: jax.Array, d: int, f: int, *, layer_prefix: str,
                dtype, stack: tuple[int, ...] = ()) -> dict:
  ks = jax.random.split(key, 3)
  return {
      "w_gate": dense(ks[0], d, f, name=f"{layer_prefix}/ffn_gate",
                      dtype=dtype, stack=stack),
      "w_up": dense(ks[1], d, f, name=f"{layer_prefix}/ffn_up",
                    dtype=dtype, stack=stack),
      "w_down": dense(ks[2], f, d, name=f"{layer_prefix}/ffn_down",
                      dtype=dtype, stack=stack),
  }


def swiglu_forward(p: dict, x: jax.Array, cs=identity_constraint,
                   policy=None) -> jax.Array:
  g = cs(gemm(p["w_gate"], x, policy), "bsf")
  u = cs(gemm(p["w_up"], x, policy), "bsf")
  h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
  return gemm(p["w_down"], h, policy)


def init_gelu_ffn(key: jax.Array, d: int, f: int, *, layer_prefix: str,
                  dtype, stack: tuple[int, ...] = ()) -> dict:
  ks = jax.random.split(key, 2)
  return {
      "w_in": dense(ks[0], d, f, name=f"{layer_prefix}/ffn_in",
                    dtype=dtype, stack=stack),
      "w_out": dense(ks[1], f, d, name=f"{layer_prefix}/ffn_out",
                     dtype=dtype, stack=stack),
      "b_in": jnp.zeros(stack + (f,), jnp.float32),
      "b_out": jnp.zeros(stack + (d,), jnp.float32),
  }


def gelu_ffn_forward(p: dict, x: jax.Array, cs=identity_constraint,
                     policy=None) -> jax.Array:
  h = gemm(p["w_in"], x, policy) + p["b_in"].astype(x.dtype)
  h = cs(jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype), "bsf")
  return gemm(p["w_out"], h, policy) + p["b_out"].astype(x.dtype)
