"""Token embedding + output head (optionally tied, optionally factored).

The output projection of the DS2 model and the LM heads are "large GEMMs"
in the paper's sense; the embedding table itself can be factored too (a
vocab x rank times rank x d_model product) — useful for the 128k-152k
vocab archs, exposed via FactorizationPlan include=["*embed*"].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense, matmul_ref
from repro.layers.common import gemm


def init_embedding(key: jax.Array, vocab: int, d: int, *, dtype,
                   tie: bool, prefix: str = "") -> dict:
  ks = jax.random.split(key, 2)
  p = {"table": jax.random.normal(ks[0], (vocab, d), jnp.float32).astype(
      dtype) * 0.02}
  if not tie:
    p["head"] = dense(ks[1], d, vocab, name=f"{prefix}lm_head",
                      dtype=dtype)
  return p


def embed(p: dict, tokens: jax.Array) -> jax.Array:
  return p["table"][tokens]


def logits(p: dict, x: jax.Array, policy=None) -> jax.Array:
  if "head" in p:
    return gemm(p["head"], x, policy)
  # Tied head: XLA fuses the table transpose into the matmul for free,
  # while the Pallas kernels would materialize (and pad) a transposed
  # copy of the model's largest weight on every step — so the tied path
  # stays jnp unless a policy override names "lm_head_tied" explicitly.
  if policy is not None and policy.override_for("lm_head_tied"):
    from repro.kernels import dispatch
    return dispatch.gemm(p["table"].T, x, policy, name="lm_head_tied")
  return matmul_ref(x, p["table"].T)
