"""Normalization layers (fp32 statistics, param-dtype output)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
  xf = x.astype(jnp.float32)
  var = jnp.mean(xf * xf, axis=-1, keepdims=True)
  y = xf * jax.lax.rsqrt(var + eps)
  return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
  xf = x.astype(jnp.float32)
  mean = jnp.mean(xf, axis=-1, keepdims=True)
  var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
  y = (xf - mean) * jax.lax.rsqrt(var + eps)
  return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(
      x.dtype)


def init_rms(d: int) -> jax.Array:
  return jnp.ones((d,), jnp.float32)


def init_ln(d: int) -> dict:
  return {"scale": jnp.ones((d,), jnp.float32),
          "bias": jnp.zeros((d,), jnp.float32)}
