"""Mixture-of-Experts FFN with grouped capacity dispatch and EP sharding.

Dispatch is *grouped* (MaxText-style): tokens are split into G groups
aligned with the data-parallel sharding; each group routes and scatters
into its own (E, C_local, D) buffer slice via a per-group cumsum. The
buffer is laid out (G, E, C, D) and annotated P(data, model, None, None):
the group dim stays data-local (no cross-shard scatter traffic) and the
expert dim is expert-parallel over the model axis — XLA inserts the
dispatch/return all-to-alls exactly at the data<->expert boundary.

With G = 1 this degrades to plain global capacity dispatch (the CPU test
path). Tokens past capacity are dropped (standard capacity-factor
semantics). Shared experts (deepseek-style) are ordinary TP-sharded
SwiGLU blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import acc_dtype, dense
from repro.layers.common import (Constraint, MoEConfig, ModelConfig,
                                 identity_constraint as _id_cs)
from repro.layers.ffn import init_swiglu, swiglu_forward


def init_moe(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
             stack: tuple[int, ...] = ()) -> dict:
  m = cfg.moe
  d, fe = cfg.d_model, m.d_expert
  ks = jax.random.split(key, 5)
  p = {
      # router is small and stays in fp32 (standard practice for stability)
      "router": jax.random.normal(ks[0], stack + (d, m.num_experts),
                                  jnp.float32) * (1.0 / d) ** 0.5,
      "w_gate": dense(ks[1], d, fe, name=f"{layer_prefix}/expert_gate",
                      dtype=cfg.dtype, stack=stack + (m.num_experts,)),
      "w_up": dense(ks[2], d, fe, name=f"{layer_prefix}/expert_up",
                    dtype=cfg.dtype, stack=stack + (m.num_experts,)),
      "w_down": dense(ks[3], fe, d, name=f"{layer_prefix}/expert_down",
                      dtype=cfg.dtype, stack=stack + (m.num_experts,)),
  }
  if m.num_shared:
    p["shared"] = init_swiglu(ks[4], d, fe * m.num_shared,
                              layer_prefix=f"{layer_prefix}/shared",
                              dtype=cfg.dtype, stack=stack)
  return p


def _route(router_w: jax.Array, x: jax.Array, m: MoEConfig):
  """Top-k routing. x: (T, D) -> weights (T, k), experts (T, k), aux."""
  logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
  probs = jax.nn.softmax(logits, axis=-1)
  topw, tope = jax.lax.top_k(probs, m.top_k)                # (T, k)
  topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
  # Switch-style load-balance loss: E * sum_e f_e * p_e
  onehot = jax.nn.one_hot(tope[:, 0], m.num_experts)        # primary choice
  f = jnp.mean(onehot, axis=0)
  pbar = jnp.mean(probs, axis=0)
  aux = m.num_experts * jnp.sum(f * pbar)
  return topw, tope, aux


def _dispatch_one_group(xt, topw, tope, m: MoEConfig, cap: int, dtype):
  """Group-local scatter. xt: (T, D) -> buf (E, C, D), bookkeeping."""
  t, d = xt.shape
  flat_e = tope.reshape(-1)                                  # (T*k,)
  onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
  pos = jnp.cumsum(onehot, axis=0) * onehot                  # 1-based
  pos_in_e = jnp.sum(pos, axis=-1) - 1                       # (T*k,)
  keep = pos_in_e < cap
  tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
  safe_pos = jnp.where(keep, pos_in_e, cap - 1)
  buf = jnp.zeros((m.num_experts, cap, d), dtype)
  buf = buf.at[flat_e, safe_pos].add(
      jnp.where(keep[:, None], xt[tok_idx], 0).astype(dtype))
  return buf, (flat_e, safe_pos, keep)


def _combine_one_group(out_buf, bookkeeping, topw, t: int, d: int, dtype):
  flat_e, safe_pos, keep = bookkeeping
  k = topw.shape[-1]
  gathered = out_buf[flat_e, safe_pos]                       # (T*k, D)
  gathered = jnp.where(keep[:, None], gathered, 0)
  combined = gathered * topw.reshape(-1)[:, None].astype(dtype)
  return jnp.sum(combined.reshape(t, k, d), axis=1)


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None
                ) -> tuple[jax.Array, jax.Array]:
  """x: (b, s, d) -> (y, aux_loss).

  The routed-expert einsums are stacked (E, m, n) contractions outside the
  2D-GEMM regimes; only the shared-expert SwiGLU consults `policy`."""
  m = cfg.moe
  b, s, d = x.shape
  t = b * s
  g = max(1, m.dispatch_groups)
  if t % g:
    g = 1
  tg = t // g
  xg = x.reshape(g, tg, d)

  topw, tope, aux = jax.vmap(
      lambda xt: _route(p["router"], xt, m))(xg)
  aux = jnp.mean(aux)

  cap = int(m.capacity_factor * tg * m.top_k / m.num_experts)
  cap = max(8, (cap + 7) // 8 * 8)

  buf, bookkeeping = jax.vmap(
      lambda xt, w, e: _dispatch_one_group(xt, w, e, m, cap, x.dtype)
  )(xg, topw, tope)
  buf = cs(buf, "gecd")                       # (G, E, C, D) -> dp x EP

  # expert FFN, batched over (group, expert) dims; weights stacked (E, d, f)
  acc = acc_dtype(x)
  def expert_ffn(wg, wu, wd, xe):
    gate = jnp.einsum("gecd,edf->gecf", xe, wg,
                      preferred_element_type=acc).astype(x.dtype)
    up = jnp.einsum("gecd,edf->gecf", xe, wu,
                    preferred_element_type=acc).astype(x.dtype)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = cs(h, "gecf")
    return jnp.einsum("gecf,efd->gecd", h, wd,
                      preferred_element_type=acc).astype(x.dtype)

  out_buf = expert_ffn(_w(p["w_gate"]), _w(p["w_up"]), _w(p["w_down"]), buf)
  out_buf = cs(out_buf, "gecd")

  y = jax.vmap(
      lambda ob, bk, w: _combine_one_group(ob, bk, w, tg, d, x.dtype)
  )(out_buf, bookkeeping, topw)
  y = y.reshape(t, d)

  if m.num_shared:
    y = y + swiglu_forward(p["shared"], x.reshape(t, d), cs,
                           policy).reshape(t, d)
  return y.reshape(b, s, d), aux.astype(jnp.float32)


def _w(leaf):
  """Expert weights participate as stacked arrays; factored experts multiply
  out per use (rank small so the matmul is cheap relative to dispatch)."""
  return leaf.product() if hasattr(leaf, "product") else leaf
