"""Multi-head Latent Attention (DeepSeek V2/V3).

MLA is itself a low-rank factorization of the KV projection — the modern
incarnation of the paper's W = UV idea: the KV path is W_uk @ (W_dkv x)
with inner rank kv_lora_rank, and the *compressed* latent c_kv is what gets
cached. The decode path uses the absorbed form (query projected into latent
space), so per-token cache traffic is rank-sized — exactly the paper's
bandwidth argument for factored inference.

Cache layout: c_kv (b, s, kv_lora_rank) + k_rope (b, s, qk_rope_dim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope

NEG_INF = -2.0 ** 30


def init_mla(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
             stack: tuple[int, ...] = ()) -> dict:
  m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
  qk = m.qk_nope_dim + m.qk_rope_dim
  ks = jax.random.split(key, 8)
  p = {}
  if m.q_lora_rank:
    p["wq_a"] = dense(ks[0], d, m.q_lora_rank,
                      name=f"{layer_prefix}/mla_q_a", dtype=cfg.dtype,
                      stack=stack)
    p["q_a_norm"] = jnp.ones(stack + (m.q_lora_rank,), jnp.float32)
    p["wq_b"] = dense(ks[1], m.q_lora_rank, h * qk,
                      name=f"{layer_prefix}/mla_q_b", dtype=cfg.dtype,
                      stack=stack)
  else:
    p["wq"] = dense(ks[0], d, h * qk, name=f"{layer_prefix}/mla_q",
                    dtype=cfg.dtype, stack=stack)
  p["w_dkv"] = dense(ks[2], d, m.kv_lora_rank + m.qk_rope_dim,
                     name=f"{layer_prefix}/mla_dkv", dtype=cfg.dtype,
                     stack=stack)
  p["kv_a_norm"] = jnp.ones(stack + (m.kv_lora_rank,), jnp.float32)
  p["w_uk"] = dense(ks[3], m.kv_lora_rank, h * m.qk_nope_dim,
                    name=f"{layer_prefix}/mla_uk", dtype=cfg.dtype,
                    stack=stack)
  p["w_uv"] = dense(ks[4], m.kv_lora_rank, h * m.v_head_dim,
                    name=f"{layer_prefix}/mla_uv", dtype=cfg.dtype,
                    stack=stack)
  p["wo"] = dense(ks[5], h * m.v_head_dim, d, name=f"{layer_prefix}/mla_o",
                  dtype=cfg.dtype, stack=stack)
  return p


def _queries(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
             policy=None):
  m, h = cfg.mla, cfg.num_heads
  b, s, _ = x.shape
  qk = m.qk_nope_dim + m.qk_rope_dim
  if cfg.mla.q_lora_rank:
    qa = rms_norm(gemm(p["wq_a"], x, policy), p["q_a_norm"], cfg.norm_eps)
    q = gemm(p["wq_b"], qa, policy)
  else:
    q = gemm(p["wq"], x, policy)
  q = q.reshape(b, s, h, qk)
  q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
  q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
  return q_nope, q_rope


def _latents(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
             policy=None):
  m = cfg.mla
  ckv = gemm(p["w_dkv"], x, policy)
  c, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
  c = rms_norm(c, p["kv_a_norm"], cfg.norm_eps)
  # rope part is shared across heads: (b, s, 1, rope_dim)
  k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[
      :, :, 0, :]
  return c, k_rope


def mla_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                cs: Constraint = _id_cs, policy=None) -> jax.Array:
  """Full-sequence causal MLA (train / prefill). Blockwise over queries."""
  m, h = cfg.mla, cfg.num_heads
  b, s, _ = x.shape
  positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
  q_nope, q_rope = _queries(p, x, cfg, positions, policy)
  c, k_rope = _latents(p, x, cfg, positions, policy)
  # up-project k/v from the latent for train/prefill (the non-absorbed form)
  k_nope = gemm(p["w_uk"], c, policy).reshape(b, s, h, m.qk_nope_dim)
  v = gemm(p["w_uv"], c, policy).reshape(b, s, h, m.v_head_dim)
  q_nope = cs(q_nope, "bshd_q")
  k_nope = cs(k_nope, "bshd_q")
  v = cs(v, "bshd_q")

  scale = 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)
  bq = min(cfg.attn_block_q, s)
  bkv = min(cfg.attn_block_kv, s)
  nq, nk = s // bq, s // bkv

  knb = k_nope.reshape(b, nk, bkv, h, m.qk_nope_dim)
  krb = k_rope.reshape(b, nk, bkv, m.qk_rope_dim)
  vb = v.reshape(b, nk, bkv, h, m.v_head_dim)

  def q_block(i, qn_blk, qr_blk):
    """Online-softmax over kv blocks — the (bq, s) score row never exists."""
    m0 = jnp.full((b, h, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, bq), jnp.float32)
    o0 = jnp.zeros((b, bq, h, m.v_head_dim), jnp.float32)

    def kv_step(carry, j):
      mx, l, o = carry
      kn = jax.lax.dynamic_index_in_dim(knb, j, 1, keepdims=False)
      kr = jax.lax.dynamic_index_in_dim(krb, j, 1, keepdims=False)
      vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
      sc = jnp.einsum("bqhd,bkhd->bhqk", qn_blk.astype(jnp.float32),
                      kn.astype(jnp.float32))
      sc += jnp.einsum("bqhr,bkr->bhqk", qr_blk.astype(jnp.float32),
                       kr.astype(jnp.float32))
      sc *= scale
      qpos = i * bq + jnp.arange(bq)[:, None]
      kpos = j * bkv + jnp.arange(bkv)[None, :]
      sc = jnp.where((kpos <= qpos)[None, None], sc, NEG_INF)
      m_new = jnp.maximum(mx, jnp.max(sc, axis=-1))
      pexp = jnp.exp(sc - m_new[..., None])
      alpha = jnp.exp(mx - m_new)
      l = l * alpha + jnp.sum(pexp, axis=-1)
      o = o * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
          "bhqk,bkhd->bqhd", pexp, vj.astype(jnp.float32))
      return (m_new, l, o), None

    (mx, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
    o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return o.astype(x.dtype)

  qn = q_nope.reshape(b, nq, bq, h, m.qk_nope_dim).transpose(1, 0, 2, 3, 4)
  qr = q_rope.reshape(b, nq, bq, h, m.qk_rope_dim).transpose(1, 0, 2, 3, 4)
  def outer(_, xs):
    i, a, r = xs
    return None, q_block(i, a, r)
  _, out = jax.lax.scan(outer, None, (jnp.arange(nq), qn, qr))
  out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, h * m.v_head_dim)
  return gemm(p["wo"], out, policy)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   stack: tuple[int, ...] = (), dtype=None) -> dict:
  m = cfg.mla
  dtype = dtype or cfg.dtype
  return {
      "c_kv": jnp.zeros(stack + (batch, max_len, m.kv_lora_rank), dtype),
      "k_rope": jnp.zeros(stack + (batch, max_len, m.qk_rope_dim), dtype),
  }


def mla_decode(p: dict, x: jax.Array, cache: dict, positions: jax.Array,
               cfg: ModelConfig, cs: Constraint = _id_cs, policy=None
               ) -> tuple[jax.Array, dict]:
  """Absorbed-form decode: score via the latent cache, rank-sized traffic.

  scores = (q_nope^T W_uk) c + q_rope^T k_rope;  out = W_uv^T (sum p c).
  """
  m, h = cfg.mla, cfg.num_heads
  b = x.shape[0]
  q_nope, q_rope = _queries(p, x, cfg, positions[:, None], policy)
  c_new, kr_new = _latents(p, x, cfg, positions[:, None], policy)
  bidx = jnp.arange(b)
  c_cache = cache["c_kv"].at[bidx, positions].set(
      c_new[:, 0].astype(cache["c_kv"].dtype))
  kr_cache = cache["k_rope"].at[bidx, positions].set(
      kr_new[:, 0].astype(cache["k_rope"].dtype))

  # absorb W_uk into the query: q_lat (b, h, r_kv)
  w_uk = _as_w(p["w_uk"]).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
  q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))
  sc = jnp.einsum("bhr,bsr->bhs", q_lat, c_cache.astype(jnp.float32))
  sc += jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                   kr_cache.astype(jnp.float32))
  sc *= 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)
  mask = jnp.arange(c_cache.shape[1])[None, None, :] <= \
      positions[:, None, None]
  sc = jnp.where(mask, sc, NEG_INF)
  pr = jax.nn.softmax(sc, axis=-1)
  ctx = jnp.einsum("bhs,bsr->bhr", pr, c_cache.astype(jnp.float32))
  # un-absorb into v-space: out_h = W_uv[:, h] ctx_h
  w_uv = _as_w(p["w_uv"]).reshape(m.kv_lora_rank, h, m.v_head_dim)
  out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
  out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
  y = gemm(p["wo"], out, policy)
  return y, {"c_kv": c_cache, "k_rope": kr_cache}


def mla_decode_window(p: dict, x: jax.Array, cache: dict,
                      positions: jax.Array, cfg: ModelConfig,
                      cs: Constraint = _id_cs, policy=None
                      ) -> tuple[jax.Array, dict]:
  """Batched W-token absorbed-form decode. x: (b, W, d); positions (b,).

  One weight pass over the window: query/latent projections run as
  (b*W)-row GEMMs, the W new latents scatter at absolute positions, and
  every window query scores the latent cache under its own causal mask
  (query t reads positions <= positions + t). Bit-identical per row to
  W sequential `mla_decode` steps — masked future-window cache rows
  contribute exactly 0 after the softmax, like unwritten rows do today.
  """
  m, h = cfg.mla, cfg.num_heads
  b, W, _ = x.shape
  pos2d = positions[:, None] + jnp.arange(W)[None, :]           # (b, W)
  q_nope, q_rope = _queries(p, x, cfg, pos2d, policy)
  c_new, kr_new = _latents(p, x, cfg, pos2d, policy)
  bidx = jnp.arange(b)[:, None]
  c_cache = cache["c_kv"].at[bidx, pos2d].set(
      c_new.astype(cache["c_kv"].dtype))
  kr_cache = cache["k_rope"].at[bidx, pos2d].set(
      kr_new.astype(cache["k_rope"].dtype))

  w_uk = _as_w(p["w_uk"]).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
  q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
  sc = jnp.einsum("bqhr,bsr->bqhs", q_lat, c_cache.astype(jnp.float32))
  sc += jnp.einsum("bqhr,bsr->bqhs", q_rope.astype(jnp.float32),
                   kr_cache.astype(jnp.float32))
  sc *= 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)
  mask = jnp.arange(c_cache.shape[1])[None, None, :] <= pos2d[:, :, None]
  sc = jnp.where(mask[:, :, None, :], sc, NEG_INF)
  pr = jax.nn.softmax(sc, axis=-1)
  ctx = jnp.einsum("bqhs,bsr->bqhr", pr, c_cache.astype(jnp.float32))
  w_uv = _as_w(p["w_uv"]).reshape(m.kv_lora_rank, h, m.v_head_dim)
  out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
  out = out.reshape(b, W, h * m.v_head_dim).astype(x.dtype)
  y = gemm(p["wo"], out, policy)
  return y, {"c_kv": c_cache, "k_rope": kr_cache}


def _as_w(leaf):
  return leaf.product() if hasattr(leaf, "product") else leaf
