"""Mamba2 (SSD) block — chunked state-space scan, O(S) in sequence length.

Training/prefill uses the chunkwise SSD algorithm (Dao & Gu 2024): quadratic
attention-like computation within chunks, linear state recurrence across
chunks. Decode carries a constant-size state (heads, head_dim, d_state) —
this is why zamba2/xlstm are the archs that run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.norms import rms_norm

HEAD_DIM = 64        # mamba2 default P
CONV_WIDTH = 4
CHUNK = 256


def init_mamba2(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
                stack: tuple[int, ...] = (), expand: int = 2) -> dict:
  d = cfg.d_model
  d_inner = expand * d
  nheads = d_inner // HEAD_DIM
  n = cfg.ssm_state
  ks = jax.random.split(key, 5)
  # The projection is split in two GEMMs: the big z/x one (TP-shardable on
  # its output dim) and the small B/C/dt one (replicated) — same math as a
  # single concatenated in_proj but with clean shard boundaries.
  return {
      "in_zx": dense(ks[0], d, 2 * d_inner, name=f"{layer_prefix}/ssm_in_zx",
                     dtype=cfg.dtype, stack=stack),
      "in_bcdt": dense(ks[4], d, 2 * n + nheads,
                       name=f"{layer_prefix}/ssm_in_bcdt",
                       dtype=cfg.dtype, stack=stack),
      "out_proj": dense(ks[1], d_inner, d, name=f"{layer_prefix}/ssm_out",
                        dtype=cfg.dtype, stack=stack),
      "conv_w": jax.random.normal(ks[2], stack + (CONV_WIDTH, d_inner),
                                  jnp.float32) * 0.1,
      "A_log": jnp.zeros(stack + (nheads,), jnp.float32),   # A = -exp(A_log)
      "D": jnp.ones(stack + (nheads,), jnp.float32),
      "dt_bias": jnp.zeros(stack + (nheads,), jnp.float32),
      "norm": jnp.ones(stack + (d_inner,), jnp.float32),
      "norm_in": jnp.ones(stack + (d,), jnp.float32),   # pre-norm (residual)
  }


def _split_proj(p, xin, cfg, expand=2, policy=None):
  d_inner = expand * cfg.d_model
  nheads = d_inner // HEAD_DIM
  n = cfg.ssm_state
  zx = gemm(p["in_zx"], xin, policy)
  bcdt = gemm(p["in_bcdt"], xin, policy)
  z = zx[..., :d_inner]
  x = zx[..., d_inner:]
  B = bcdt[..., :n]
  C = bcdt[..., n:2 * n]
  dt = bcdt[..., 2 * n:]
  return z, x, B, C, dt, d_inner, nheads


def _causal_conv(x, w, state=None):
  """Depthwise causal conv, width CONV_WIDTH. x: (b, s, c), w: (k, c).

  With `state` (b, k-1, c) performs the streaming update (decode)."""
  b, s, c = x.shape
  k = w.shape[0]
  if state is None:
    pad = jnp.zeros((b, k - 1, c), x.dtype)
  else:
    pad = state.astype(x.dtype)
  xp = jnp.concatenate([pad, x], axis=1)
  out = sum(xp[:, i:i + s, :] * w[i].astype(x.dtype) for i in range(k))
  new_state = xp[:, -(k - 1):, :]
  return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_state


def _segsum(log_a):
  """segsum(x)[..., i, j] = sum_{j < k <= i} x_k (lower-triangular)."""
  T = log_a.shape[-1]
  cs = jnp.cumsum(log_a, axis=-1)
  diff = cs[..., :, None] - cs[..., None, :]
  mask = jnp.tril(jnp.ones((T, T), bool), k=0)
  return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk=CHUNK):
  """Chunked SSD. x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n).

  Returns y: (b,s,h,p) and final state (b,h,p,n).
  """
  b, s, h, p = x.shape
  n = B.shape[-1]
  nc = s // chunk
  f32 = jnp.float32
  xc = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nc, chunk, h, p)
  da = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, chunk, h)  # log decay
  Bc = B.astype(f32).reshape(b, nc, chunk, n)
  Cc = C.astype(f32).reshape(b, nc, chunk, n)

  da_cs = jnp.cumsum(da, axis=2)                    # (b,nc,Q,h)
  da_total = da_cs[:, :, -1]                        # (b,nc,h)

  # intra-chunk: quadratic with decay kernel L = exp(segsum(da))
  L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))    # (b,nc,h,Q,Q)
  scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)    # (b,nc,Q,Q)
  y_intra = jnp.einsum("bchij,bcij,bcjhp->bcihp",
                       L, scores, xc)

  # per-chunk state contribution: S_c = sum_j exp(da_total - da_cs_j) B_j x_j
  decay_tail = jnp.exp(da_total[:, :, None] - da_cs)          # (b,nc,Q,h)
  S = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_tail, Bc, xc)

  # inter-chunk recurrence over the chunk axis
  def step(Hc, inp):
    Sc, dtot = inp
    Hn = Hc * jnp.exp(dtot)[..., None, None] + Sc
    return Hn, Hc                                   # emit state *entering* c
  H0 = jnp.zeros((b, h, n, p), f32)
  Hlast, Hin = jax.lax.scan(step, H0,
                            (S.transpose(1, 0, 2, 3, 4),
                             da_total.transpose(1, 0, 2)))
  Hin = Hin.transpose(1, 0, 2, 3, 4)                # (b,nc,h,n,p)

  decay_head = jnp.exp(da_cs)                       # decay from chunk start
  y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, decay_head, Hin)
  y = (y_intra + y_inter).reshape(b, s, h, p)
  return y, Hlast


def mamba2_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                   cs: Constraint = _id_cs, expand: int = 2,
                   policy=None) -> jax.Array:
  b, s, d = x.shape
  z, xi, B, C, dt, d_inner, nheads = _split_proj(p, x, cfg, expand, policy)
  xi, _ = _causal_conv(xi, p["conv_w"])
  xi = cs(xi, "bsi")
  dt = jax.nn.softplus(dt.astype(jnp.float32) +
                       p["dt_bias"].astype(jnp.float32))
  A = -jnp.exp(p["A_log"].astype(jnp.float32))
  xh = xi.reshape(b, s, nheads, HEAD_DIM)
  y, _ = ssd_chunked(xh, dt, A, B, C, chunk=min(CHUNK, s))
  y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :,
                                                              None]
  y = y.reshape(b, s, d_inner).astype(x.dtype)
  y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out_proj"], y, policy)


# -- decode ------------------------------------------------------------------


def init_mamba2_state(cfg: ModelConfig, batch: int,
                      stack: tuple[int, ...] = (), expand: int = 2) -> dict:
  d_inner = expand * cfg.d_model
  nheads = d_inner // HEAD_DIM
  return {
      "ssm": jnp.zeros(stack + (batch, nheads, cfg.ssm_state, HEAD_DIM),
                       jnp.float32),
      "conv": jnp.zeros(stack + (batch, CONV_WIDTH - 1, d_inner), cfg.dtype),
  }


def mamba2_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                  cs: Constraint = _id_cs, expand: int = 2,
                  policy=None) -> tuple[jax.Array, dict]:
  """One decode step. x: (b, 1, d). State is O(1) in context length."""
  b = x.shape[0]
  z, xi, B, C, dt, d_inner, nheads = _split_proj(p, x, cfg, expand, policy)
  xi, conv_state = _causal_conv(xi, p["conv_w"], state["conv"])
  dt = jax.nn.softplus(dt.astype(jnp.float32) +
                       p["dt_bias"].astype(jnp.float32))[:, 0]   # (b,h)
  A = -jnp.exp(p["A_log"].astype(jnp.float32))
  xh = xi[:, 0].reshape(b, nheads, HEAD_DIM).astype(jnp.float32)
  Bf = B[:, 0].astype(jnp.float32)                               # (b,n)
  Cf = C[:, 0].astype(jnp.float32)
  da = jnp.exp(dt * A)                                           # (b,h)
  # h' = exp(dt A) h + dt B (x)    (state (b,h,n,p))
  upd = jnp.einsum("bn,bhp->bhnp", Bf, xh * dt[..., None])
  ssm = state["ssm"] * da[..., None, None] + upd
  y = jnp.einsum("bn,bhnp->bhp", Cf, ssm)
  y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
  y = y.reshape(b, 1, d_inner).astype(x.dtype)
  y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out_proj"], y, policy), {"ssm": ssm, "conv": conv_state}


def mamba2_decode_window(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                         cs: Constraint = _id_cs, expand: int = 2,
                         policy=None) -> tuple[jax.Array, dict]:
  """Batched W-token decode window. x: (b, W, d).

  All weight GEMMs (in_zx / in_bcdt / out_proj), the streaming conv, and
  the per-position dt / decay / outer-product terms batch over the window
  in one pass; only the O(1)-state recurrence `h' = da*h + upd` stays a
  `lax.scan` of elementwise ops over the W positions, preserving the fp
  summation order of W sequential `mamba2_decode` calls bit-for-bit.
  """
  b, W, _ = x.shape
  z, xi, B, C, dt, d_inner, nheads = _split_proj(p, x, cfg, expand, policy)
  xi, conv_state = _causal_conv(xi, p["conv_w"], state["conv"])
  dt = jax.nn.softplus(dt.astype(jnp.float32) +
                       p["dt_bias"].astype(jnp.float32))      # (b,W,h)
  A = -jnp.exp(p["A_log"].astype(jnp.float32))
  xh = xi.reshape(b, W, nheads, HEAD_DIM).astype(jnp.float32)
  Bf = B.astype(jnp.float32)                                   # (b,W,n)
  Cf = C.astype(jnp.float32)
  da = jnp.exp(dt * A)                                         # (b,W,h)
  upd = jnp.einsum("bqn,bqhp->bqhnp", Bf, xh * dt[..., None])

  def step(ssm, inp):
    da_t, upd_t = inp
    ssm1 = ssm * da_t[..., None, None] + upd_t
    return ssm1, ssm1
  ssm_last, ssm_seq = jax.lax.scan(
      step, state["ssm"], (da.transpose(1, 0, 2),
                           upd.transpose(1, 0, 2, 3, 4)))
  ssm_seq = ssm_seq.transpose(1, 0, 2, 3, 4)                   # (b,W,h,n,p)
  y = jnp.einsum("bqn,bqhnp->bqhp", Cf, ssm_seq)
  y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
  y = y.reshape(b, W, d_inner).astype(x.dtype)
  y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out_proj"], y, policy), {"ssm": ssm_last, "conv": conv_state}
