"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential recurrence) — Beck et al. 2024, arXiv:2405.04517.

Mapping to the paper's recurrent/non-recurrent split (Appendix B.2):
  * mLSTM q/k/v/gate projections are *non-recurrent* GEMMs (batchable
    across time) -> group "nonrec";
  * sLSTM recurrent kernels R_{z,i,f,o} are *recurrent* GEMMs -> group
    "rec", regularized with lambda_rec exactly like the GRU's U matrices.

mLSTM uses a chunkwise form (quadratic intra-chunk with stabilized
exponential gating, recurrent matrix-memory state across chunks); sLSTM is
a time scan. Decode for both carries O(1)-size state — hence xlstm-350m is
a long_500k arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import FactoredLinear, dense
from repro.quant.leaf import QuantizedLinear
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.norms import rms_norm

CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
               stack: tuple[int, ...] = (), pf: float = 2.0) -> dict:
  d = cfg.d_model
  di = int(pf * d)
  h = cfg.num_heads
  ks = jax.random.split(key, 6)
  return {
      "up": dense(ks[0], d, 2 * di, name=f"{layer_prefix}/mlstm_up",
                  dtype=cfg.dtype, stack=stack),
      "qkv": dense(ks[1], di, 3 * di, name=f"{layer_prefix}/mlstm_qkv",
                   dtype=cfg.dtype, stack=stack),
      "ifg": dense(ks[2], di, 2 * h, name=f"{layer_prefix}/mlstm_ifg",
                   dtype=cfg.dtype, stack=stack),   # input & forget gates
      "down": dense(ks[3], di, d, name=f"{layer_prefix}/mlstm_down",
                    dtype=cfg.dtype, stack=stack),
      "norm": jnp.ones(stack + (di,), jnp.float32),
  }


def _mlstm_chunk(q, k, v, logf, logi, C0, n0, m0):
  """One chunk of the stabilized chunkwise mLSTM.

  q,k,v: (b,Q,h,p) f32; logf,logi: (b,Q,h); state C0 (b,h,p,p), n0 (b,h,p),
  m0 (b,h). Returns y (b,Q,h,p) and new state.
  """
  b, Q, h, p = q.shape
  F = jnp.cumsum(logf, axis=1)                      # (b,Q,h) within-chunk
  # intra-chunk decay: D[i,j] = exp(F_i - F_j + logi_j), j <= i
  dmat = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
  tri = jnp.tril(jnp.ones((Q, Q), bool))
  dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)   # (b,i,j,h)
  # inter-chunk carry decay for position i: exp(F_i + m0)
  carry_log = F + m0[:, None, :]                            # (b,Q,h)
  m_new = jnp.maximum(jnp.max(dmat, axis=2), carry_log)     # (b,Q,h)
  m_new = jnp.maximum(m_new, -1e30)

  dexp = jnp.exp(dmat - m_new[:, :, None, :])               # (b,i,j,h)
  s = jnp.einsum("bihp,bjhp->bijh", q, k) / (p ** 0.5)
  w = s * dexp
  y_intra = jnp.einsum("bijh,bjhp->bihp", w, v)
  l_intra = jnp.einsum("bijh->bih", w)

  cexp = jnp.exp(carry_log - m_new)                         # (b,Q,h)
  y_inter = jnp.einsum("bihp,bhpt->biht", q, C0) / (p ** 0.5) * \
      cexp[..., None]
  l_inter = jnp.einsum("bihp,bhp->bih", q, n0) / (p ** 0.5) * cexp

  norm = jnp.maximum(jnp.abs(l_intra + l_inter), jnp.exp(-m_new))
  y = (y_intra + y_inter) / jnp.maximum(norm[..., None], 1e-30)

  # state update to end of chunk
  Ftot = F[:, -1]                                           # (b,h)
  m_state = jnp.maximum(Ftot + m0, jnp.max(
      Ftot[:, None] - F + logi, axis=1))
  decay_tail = jnp.exp(Ftot[:, None] - F + logi - m_state[:, None])  # (b,Q,h)
  kx = k * decay_tail[..., None]
  C1 = C0 * jnp.exp(Ftot + m0 - m_state)[..., None, None] + \
      jnp.einsum("bjhp,bjht->bhpt", kx, v)
  n1 = n0 * jnp.exp(Ftot + m0 - m_state)[..., None] + \
      jnp.einsum("bjhp->bhp", kx)
  return y, C1, n1, m_state


def mlstm_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, pf: float = 2.0,
                  policy=None) -> jax.Array:
  b, s, d = x.shape
  di = int(pf * d)
  h = cfg.num_heads
  hd = di // h
  up = gemm(p["up"], x, policy)
  xin, z = up[..., :di], up[..., di:]
  qkv = gemm(p["qkv"], xin, policy)
  q, k, v = [t.reshape(b, s, h, hd).astype(jnp.float32)
             for t in jnp.split(qkv, 3, axis=-1)]
  gates = gemm(p["ifg"], xin, policy).astype(jnp.float32).reshape(b, s, 2, h)
  logi = gates[:, :, 0]
  logf = jax.nn.log_sigmoid(gates[:, :, 1])

  Q = min(CHUNK, s)
  nc = s // Q
  def chunk_step(carry, inp):
    C0, n0, m0 = carry
    qc, kc, vc, fc, ic = inp
    y, C1, n1, m1 = _mlstm_chunk(qc, kc, vc, fc, ic, C0, n0, m0)
    return (C1, n1, m1), y
  resh = lambda t: t.reshape(b, nc, Q, *t.shape[2:]).transpose(
      1, 0, *range(2, t.ndim + 1))
  C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
  n0 = jnp.zeros((b, h, hd), jnp.float32)
  m0 = jnp.full((b, h), -1e30, jnp.float32)
  _, ys = jax.lax.scan(chunk_step, (C0, n0, m0),
                       (resh(q), resh(k), resh(v), resh(logf), resh(logi)))
  y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, di)
  y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["down"], y, policy)


def init_mlstm_state(cfg: ModelConfig, batch: int,
                     stack: tuple[int, ...] = (), pf: float = 2.0) -> dict:
  di = int(pf * cfg.d_model)
  h = cfg.num_heads
  hd = di // h
  return {
      "C": jnp.zeros(stack + (batch, h, hd, hd), jnp.float32),
      "n": jnp.zeros(stack + (batch, h, hd), jnp.float32),
      "m": jnp.full(stack + (batch, h), -1e30, jnp.float32),
  }


def mlstm_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                 cs: Constraint = _id_cs, pf: float = 2.0,
                 policy=None) -> tuple[jax.Array, dict]:
  b = x.shape[0]
  d = cfg.d_model
  di = int(pf * d)
  h = cfg.num_heads
  hd = di // h
  up = gemm(p["up"], x, policy)
  xin, z = up[..., :di], up[..., di:]
  qkv = gemm(p["qkv"], xin, policy)
  q, k, v = [t.reshape(b, h, hd).astype(jnp.float32)
             for t in jnp.split(qkv[:, 0], 3, axis=-1)]
  gates = gemm(p["ifg"], xin, policy).astype(jnp.float32).reshape(b, 2, h)
  logi, logf = gates[:, 0], jax.nn.log_sigmoid(gates[:, 1])
  m1 = jnp.maximum(logf + state["m"], logi)
  fe = jnp.exp(logf + state["m"] - m1)
  ie = jnp.exp(logi - m1)
  C1 = state["C"] * fe[..., None, None] + \
      ie[..., None, None] * jnp.einsum("bhp,bht->bhpt", k, v)
  n1 = state["n"] * fe[..., None] + ie[..., None] * k
  num = jnp.einsum("bhp,bhpt->bht", q, C1) / (hd ** 0.5)
  den = jnp.abs(jnp.einsum("bhp,bhp->bh", q, n1)) / (hd ** 0.5)
  y = num / jnp.maximum(den, jnp.exp(-m1))[..., None]
  y = y.reshape(b, 1, di).astype(x.dtype) * \
      jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["down"], y, policy), {"C": C1, "n": n1, "m": m1}


def mlstm_decode_window(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                        cs: Constraint = _id_cs, pf: float = 2.0,
                        policy=None) -> tuple[jax.Array, dict]:
  """Batched W-token decode window. x: (b, W, d).

  The non-recurrent up/qkv/ifg/down GEMMs batch over the window in one
  weight pass; only the stabilized matrix-memory recurrence (C, n, m —
  pure elementwise ops plus activation-only einsums) stays a `lax.scan`
  over the W positions, so every position reproduces `mlstm_decode`'s fp
  operation order bit-for-bit."""
  b, W, _ = x.shape
  d = cfg.d_model
  di = int(pf * d)
  h = cfg.num_heads
  hd = di // h
  up = gemm(p["up"], x, policy)
  xin, z = up[..., :di], up[..., di:]
  qkv = gemm(p["qkv"], xin, policy)
  q, k, v = [t.reshape(b, W, h, hd).astype(jnp.float32)
             for t in jnp.split(qkv, 3, axis=-1)]
  gates = gemm(p["ifg"], xin, policy).astype(jnp.float32).reshape(b, W, 2, h)
  logi, logf = gates[:, :, 0], jax.nn.log_sigmoid(gates[:, :, 1])

  def step(carry, inp):
    C, n, m = carry
    qt, kt, vt, logit, logft = inp
    m1 = jnp.maximum(logft + m, logit)
    fe = jnp.exp(logft + m - m1)
    ie = jnp.exp(logit - m1)
    C1 = C * fe[..., None, None] + \
        ie[..., None, None] * jnp.einsum("bhp,bht->bhpt", kt, vt)
    n1 = n * fe[..., None] + ie[..., None] * kt
    num = jnp.einsum("bhp,bhpt->bht", qt, C1) / (hd ** 0.5)
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", qt, n1)) / (hd ** 0.5)
    yt = num / jnp.maximum(den, jnp.exp(-m1))[..., None]
    return (C1, n1, m1), yt
  t1 = lambda t: jnp.moveaxis(t, 1, 0)
  (C1, n1, m1), ys = jax.lax.scan(
      step, (state["C"], state["n"], state["m"]),
      (t1(q), t1(k), t1(v), t1(logi), t1(logf)))
  y = jnp.moveaxis(ys, 0, 1).reshape(b, W, di).astype(x.dtype) * \
      jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["down"], y, policy), {"C": C1, "n": n1, "m": m1}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
               stack: tuple[int, ...] = ()) -> dict:
  d = cfg.d_model
  h = cfg.num_heads
  hd = d // h
  ks = jax.random.split(key, 3)
  return {
      # non-recurrent: one GEMM for all four gates (paper's W_cat)
      "wx": dense(ks[0], d, 4 * d, name=f"{layer_prefix}/slstm_nonrec",
                  group="nonrec", dtype=cfg.dtype, stack=stack),
      # recurrent: block-diagonal per head, all four gates (paper's U_cat)
      "rh": dense(ks[1], hd, 4 * hd, name=f"{layer_prefix}/slstm_rec",
                  group="rec", dtype=cfg.dtype, stack=stack + (h,)),
      "bias": jnp.zeros(stack + (4 * d,), jnp.float32),
      "out": dense(ks[2], d, d, name=f"{layer_prefix}/slstm_out",
                   dtype=cfg.dtype, stack=stack),
      "norm": jnp.ones(stack + (d,), jnp.float32),
  }


def _head_rh(rh, i: int):
  """2-D per-head slice of the block-diagonal recurrent kernel
  (..., h, hd, 4hd) — the form `gemm`/dispatch can route."""
  if isinstance(rh, QuantizedLinear):
    if rh.is_factored:
      return QuantizedLinear(
          w_q=None, w_scale=None,
          u_q=rh.u_q[..., i, :, :], u_scale=rh.u_scale[..., i, :],
          v_q=rh.v_q[..., i, :, :], v_scale=rh.v_scale[..., i, :],
          act_scale=rh.act_scale, name=rh.name, group=rh.group,
          orig_dtype=rh.orig_dtype)
    return QuantizedLinear(
        w_q=rh.w_q[..., i, :, :], w_scale=rh.w_scale[..., i, :],
        u_q=None, u_scale=None, v_q=None, v_scale=None,
        act_scale=rh.act_scale, name=rh.name, group=rh.group,
        orig_dtype=rh.orig_dtype)
  if rh.is_factored:
    return FactoredLinear(w=None, u=rh.u[..., i, :, :], v=rh.v[..., i, :, :],
                          name=rh.name, group=rh.group)
  return FactoredLinear(w=rh.w[..., i, :, :], u=None, v=None,
                        name=rh.name, group=rh.group)


def _slstm_cell(xg, hcnm, rh, h_, hd, policy=None):
  """One sLSTM time step. xg: (b, 4d) precomputed Wx; state tuple.

  The block-diagonal recurrent kernel (the paper's U_cat, group "rec")
  applies head-by-head through `gemm`, so it routes through
  kernels.dispatch like every other model GEMM — dispatch_coverage sees
  it, and factored rh leaves run in their (x@U)@V inference form
  instead of materializing W = UV every step."""
  hprev, c, n, m = hcnm
  b = hprev.shape[0]
  hh = hprev.reshape(b, h_, hd).astype(jnp.float32)
  if isinstance(rh, (FactoredLinear, QuantizedLinear)):
    outs = [gemm(_head_rh(rh, i), hh[:, i, :], policy) for i in range(h_)]
    rg = jnp.stack(outs, axis=1).reshape(b, 4 * h_ * hd)
  else:
    rg = jnp.einsum("bhp,hpq->bhq", hh,
                    rh.astype(jnp.float32)).reshape(b, 4 * h_ * hd)
  g = xg.astype(jnp.float32) + rg
  gz, gi, gf, go = jnp.split(g.reshape(b, 4, h_ * hd), 4, axis=1)
  gz, gi, gf, go = gz[:, 0], gi[:, 0], gf[:, 0], go[:, 0]
  z = jnp.tanh(gz)
  logi = gi
  logf = jax.nn.log_sigmoid(gf)
  o = jax.nn.sigmoid(go)
  m1 = jnp.maximum(logf + m, logi)
  ie = jnp.exp(logi - m1)
  fe = jnp.exp(logf + m - m1)
  c1 = fe * c + ie * z
  n1 = fe * n + ie
  h1 = o * c1 / jnp.maximum(n1, 1e-6)
  return (h1, c1, n1, m1)


def slstm_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  cs: Constraint = _id_cs, policy=None) -> jax.Array:
  b, s, d = x.shape
  h_ = cfg.num_heads
  hd = d // h_
  # non-recurrent GEMM batched across time (paper §4's Wx batching)
  xg = gemm(p["wx"], x, policy) + p["bias"].astype(x.dtype)
  state = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
           jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30,
                                                    jnp.float32))
  def step(carry, xt):
    new = _slstm_cell(xt, carry, p["rh"], h_, hd, policy)
    return new, new[0]
  _, hs = jax.lax.scan(step, state, xg.transpose(1, 0, 2))
  y = hs.transpose(1, 0, 2).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out"], y, policy)


def init_slstm_state(cfg: ModelConfig, batch: int,
                     stack: tuple[int, ...] = ()) -> dict:
  d = cfg.d_model
  z = lambda: jnp.zeros(stack + (batch, d), jnp.float32)
  return {"h": z(), "c": z(), "n": z(),
          "m": jnp.full(stack + (batch, d), -1e30, jnp.float32)}


def slstm_decode(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                 cs: Constraint = _id_cs, policy=None
                 ) -> tuple[jax.Array, dict]:
  b = x.shape[0]
  d = cfg.d_model
  h_ = cfg.num_heads
  hd = d // h_
  xg = (gemm(p["wx"], x, policy) + p["bias"].astype(x.dtype))[:, 0]
  new = _slstm_cell(xg, (state["h"], state["c"], state["n"], state["m"]),
                    p["rh"], h_, hd, policy)
  y = new[0][:, None, :].astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out"], y, policy), {"h": new[0], "c": new[1], "n": new[2],
                                     "m": new[3]}


def slstm_decode_window(p: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                        cs: Constraint = _id_cs, policy=None
                        ) -> tuple[jax.Array, dict]:
  """Batched W-token decode window. x: (b, W, d).

  The non-recurrent W_cat GEMM (and out/norm) batches over the window;
  the recurrent U_cat application is a nonlinear recurrence in h, so the
  cell itself stays a `lax.scan` — exactly `slstm_forward`'s split, seeded
  from the decode carry. Each position matches `slstm_decode` bit-for-bit."""
  h_ = cfg.num_heads
  hd = cfg.d_model // h_
  xg = gemm(p["wx"], x, policy) + p["bias"].astype(x.dtype)
  def step(carry, xt):
    new = _slstm_cell(xt, carry, p["rh"], h_, hd, policy)
    return new, new[0]
  (h1, c1, n1, m1), hs = jax.lax.scan(
      step, (state["h"], state["c"], state["n"], state["m"]),
      xg.transpose(1, 0, 2))
  y = hs.transpose(1, 0, 2).astype(x.dtype)
  y = rms_norm(y, p["norm"], cfg.norm_eps)
  return gemm(p["out"], y, policy), {"h": h1, "c": c1, "n": n1, "m": m1}
