"""GRU layer with the paper's *partially joint* factorization (Appendix B.2).

The three non-recurrent matrices W_{z,r,h} are concatenated into one GEMM
`nonrec` (batchable across time — paper §4), and the three recurrent
matrices U_{z,r,h} into one GEMM `rec` (sequential, batch = minibatch).
Each concatenated matrix is a FactoredLinear, so trace-norm regularization
and SVD truncation operate at exactly the paper's granularity, with the
lambda_rec / lambda_nonrec split attached to the right groups.

Cell (paper eq. 10):
    z_t = sigmoid(W_z x_t + U_z h_{t-1} + b_z)
    r_t = sigmoid(W_r x_t + U_r h_{t-1} + b_r)
    hcand = f(W_h x_t + r_t * (U_h h_{t-1}) + b_h)
    h_t = (1 - z_t) h_{t-1} + z_t hcand
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import FactoredLinear, dense
from repro.layers.common import (Constraint, gemm,
                                 identity_constraint as _id_cs)

def init_gru(key: jax.Array, in_dim: int, hidden: int, *, layer_prefix: str,
             dtype=jnp.float32) -> dict:
  ks = jax.random.split(key, 2)
  return {
      "nonrec": dense(ks[0], in_dim, 3 * hidden,
                      name=f"{layer_prefix}/nonrec", group="nonrec",
                      dtype=dtype),
      "rec": dense(ks[1], hidden, 3 * hidden,
                   name=f"{layer_prefix}/rec", group="rec", dtype=dtype),
      "bias": jnp.zeros((3 * hidden,), jnp.float32),
  }


def gru_cell(xw: jax.Array, h: jax.Array, rec: FactoredLinear,
             bias: jax.Array, hidden: int, policy=None) -> jax.Array:
  """One step given the precomputed non-recurrent projection xw (b, 3h).

  Under a Pallas `policy` the whole step (recurrent GEMM + gates) lowers
  through the fused kernels.gru_cell; when the kernel declines (factored
  recurrent weight, degenerate hidden) the reference gate math below runs,
  with its inner recurrent GEMM still consulting the policy."""
  if policy is not None:
    from repro.kernels import dispatch
    fused = dispatch.maybe_gru_cell(xw, h, rec, bias, policy)
    if fused is not None:
      return fused
  hu = gemm(rec, h, policy)                           # (b, 3h) — the
  # sequential batch-1-per-step GEMM the paper's kernels target
  g = xw.astype(jnp.float32) + hu.astype(jnp.float32) + bias
  gz, gr, gh_ = g[:, :hidden], g[:, hidden:2 * hidden], g[:, 2 * hidden:]
  hu_h = hu.astype(jnp.float32)[:, 2 * hidden:]
  z = jax.nn.sigmoid(gz)
  r = jax.nn.sigmoid(gr)
  # r gates the recurrent contribution only (paper eq. 10)
  hcand = jnp.tanh(gh_ - hu_h + r * hu_h)
  h1 = (1.0 - z) * h.astype(jnp.float32) + z * hcand
  return h1.astype(h.dtype)


def gru_forward(p: dict, x: jax.Array, cs: Constraint = _id_cs,
                policy=None) -> jax.Array:
  """Forward-only GRU over a sequence. x: (b, t, in) -> (b, t, hidden)."""
  b, t, _ = x.shape
  # FactoredLinear and QuantizedLinear both expose in_dim; raw arrays don't
  hidden = p["rec"].in_dim if hasattr(p["rec"], "in_dim") \
      else p["rec"].shape[0]
  # batch the non-recurrent GEMM across time (paper §4)
  xw = gemm(p["nonrec"], x, policy)
  xw = cs(xw, "bt3h")
  h0 = jnp.zeros((b, hidden), x.dtype)
  def step(h, xwt):
    h1 = gru_cell(xwt, h, p["rec"], p["bias"], hidden, policy)
    return h1, h1
  _, hs = jax.lax.scan(step, h0, xw.transpose(1, 0, 2))
  return hs.transpose(1, 0, 2)


def gru_decode(p: dict, x_t: jax.Array, h: jax.Array,
               cs: Constraint = _id_cs, policy=None) -> jax.Array:
  """Streaming step: x_t (b, in), h (b, hidden) -> h' (b, hidden)."""
  hidden = h.shape[-1]
  xw = gemm(p["nonrec"], x_t, policy)
  return gru_cell(xw, h, p["rec"], p["bias"], hidden, policy)
