"""Shared config dataclasses and the GEMM application helper.

All model weights that are "large GEMMs" in the paper's sense are
FactoredLinear nodes; `gemm()` applies them uniformly whether factored or
not, so the whole model zoo is compressible by core.compress plans.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.factored import FactoredLinear, matmul_ref
from repro.quant.leaf import QuantizedLinear

# The sharding-constraint contract every model function threads through its
# layers: cs(x, logical_name) -> x. Hosted here (the leaf module all layer
# and model code already imports) so model code never depends on repro.dist;
# dist.sharding re-exports both names and its make_constraint returns
# identity_constraint when called without a mesh.
Constraint = Callable[[jax.Array, str], jax.Array]


def identity_constraint(x, name: str):
  """The no-mesh constraint: every `cs` call is a pass-through."""
  return x


def gemm(leaf, x: jax.Array, policy=None) -> jax.Array:
  """y[..., n] = x[..., m] @ W(m, n); factored path = (x @ U) @ V.

  `leaf` is a FactoredLinear, a quant.QuantizedLinear, or a raw array.
  Leaf nodes delegate to `leaf.apply(x)` — the factored math AND the
  accumulation-dtype policy live in exactly one place
  (core.factored.acc_dtype); raw arrays follow the same policy here.
  QuantizedLinear leaves apply their w8a8 oracle (quant.leaf.ref_apply),
  so a PTQ'd tree serves correctly even with no policy at all.

  `policy` is the kernel-side sibling of `cs`: a
  `kernels.dispatch.KernelPolicy` that classifies this GEMM by regime
  (decode batch -> decode_matvec, factored leaf -> lowrank_gemm,
  quantized leaf / w8a8 override -> int8_gemm) and lowers it through the
  Pallas kernels. None — the default everywhere — is the exact
  historical jnp path."""
  if policy is not None:
    from repro.kernels import dispatch
    return dispatch.gemm(leaf, x, policy)
  if isinstance(leaf, (FactoredLinear, QuantizedLinear)):
    return leaf.apply(x)
  return matmul_ref(x, leaf)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
  num_experts: int = 0          # routed experts
  num_shared: int = 0           # always-on shared experts
  top_k: int = 2
  d_expert: int = 0             # per-expert FFN hidden dim
  capacity_factor: float = 1.25
  router_aux_weight: float = 1e-3   # load-balance auxiliary loss
  first_dense_layers: int = 0   # leading layers use dense FFN (deepseek)
  dispatch_groups: int = 1      # token groups aligned with the dp sharding


@dataclasses.dataclass(frozen=True)
class MLAConfig:
  kv_lora_rank: int = 512
  q_lora_rank: int = 0          # 0 => dense q projection
  qk_nope_dim: int = 128
  qk_rope_dim: int = 64
  v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
  """One config object covers the whole assigned-arch zoo; family selects
  the model implementation, optional sub-configs select layer variants."""
  name: str
  family: str                   # transformer|zamba|xlstm|whisper|deepspeech
  num_layers: int
  d_model: int
  num_heads: int
  num_kv_heads: int
  d_ff: int
  vocab_size: int
  head_dim: Optional[int] = None          # default d_model // num_heads
  qk_norm: bool = False                   # qwen3
  rope_theta: float = 10000.0
  tie_embeddings: bool = False
  norm_eps: float = 1e-5
  dtype: Any = jnp.bfloat16
  # -- MoE / MLA (deepseek) --
  moe: Optional[MoEConfig] = None
  mla: Optional[MLAConfig] = None
  mtp: bool = False                       # multi-token prediction head (dsv3)
  # -- hybrid / ssm --
  ssm_state: int = 0                      # mamba2 state dim (zamba2)
  attn_every: int = 0                     # zamba: shared attn block period
  # -- enc-dec (whisper) --
  encoder_layers: int = 0
  max_source_positions: int = 1500
  # -- speech (deepspeech2) --
  feat_dim: int = 80                      # mel bins (paper B.3)
  gru_dims: tuple = ()                    # growing sizes (paper B.1)
  fc_dim: int = 0
  conv_channels: int = 32
  time_stride: int = 2
  # -- attention implementation knobs (perf) --
  attn_block_q: int = 512
  attn_block_kv: int = 512
  # wedge scheduling halves prefill attention FLOPs (see EXPERIMENTS §Perf)
  causal_wedge: bool = False
  # remat policy for the layer scan: "full" | "dots" | "none"
  remat: str = "full"

  @property
  def resolved_head_dim(self) -> int:
    return self.head_dim if self.head_dim else self.d_model // self.num_heads

  def with_(self, **kw) -> "ModelConfig":
    return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
  """One assigned input-shape cell."""
  name: str                     # train_4k | prefill_32k | decode_32k | long_500k
  kind: str                     # "train" | "prefill" | "decode"
  seq_len: int
  global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
