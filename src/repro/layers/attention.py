"""GQA attention: flash-style blockwise training/prefill + cached decode.

Training/prefill uses an online-softmax blockwise implementation (scan over
query blocks, inner scan over KV blocks) so 32k-sequence prefill never
materializes an S x S score matrix. This is also the exact blocking scheme
of kernels/flash_attention.py — the jnp version here is its oracle and the
form the dry-run lowers.

`causal_wedge=True` switches the outer loop to a statically unrolled wedge
(query block i only visits KV blocks 0..i), halving attention FLOPs for
long prefill at the cost of a larger HLO — a §Perf hillclimb lever.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.factored import dense
from repro.layers.common import (Constraint, ModelConfig, gemm,
                                 identity_constraint as _id_cs)
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope

NEG_INF = -2.0 ** 30  # large-negative in fp32, safe under bf16 rounding


def init_attention(key: jax.Array, cfg: ModelConfig, *, layer_prefix: str,
                   stack: tuple[int, ...] = ()) -> dict:
  d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
  hd = cfg.resolved_head_dim
  ks = jax.random.split(key, 4)
  p = {
      "wq": dense(ks[0], d, h * hd, name=f"{layer_prefix}/attn_q",
                  dtype=cfg.dtype, stack=stack),
      "wk": dense(ks[1], d, kv * hd, name=f"{layer_prefix}/attn_k",
                  dtype=cfg.dtype, stack=stack),
      "wv": dense(ks[2], d, kv * hd, name=f"{layer_prefix}/attn_v",
                  dtype=cfg.dtype, stack=stack),
      "wo": dense(ks[3], h * hd, d, name=f"{layer_prefix}/attn_o",
                  dtype=cfg.dtype, stack=stack),
  }
  if cfg.qk_norm:  # qwen3-style per-head RMSNorm on q and k
    p["q_norm"] = jnp.ones(stack + (hd,), jnp.float32)
    p["k_norm"] = jnp.ones(stack + (hd,), jnp.float32)
  return p


def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, cs: Constraint, policy=None):
  b, s, _ = x.shape
  h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
  q = gemm(p["wq"], x, policy).reshape(b, s, h, hd)
  k = gemm(p["wk"], x, policy).reshape(b, s, kv, hd)
  v = gemm(p["wv"], x, policy).reshape(b, s, kv, hd)
  if cfg.qk_norm:
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k = rms_norm(k, p["k_norm"], cfg.norm_eps)
  q = apply_rope(q, positions, cfg.rope_theta)
  k = apply_rope(k, positions, cfg.rope_theta)
  q = cs(q, "bshd_q")
  k = cs(k, "bshd_kv")
  v = cs(v, "bshd_kv")
  return q, k, v


def _block_attend(q_blk, k, v, q_start, kv_start, kv_len, scale):
  """One (q-block x kv-block) online-softmax tile.

  q_blk: (b, bq, h, hd); k/v: (b, bkv, h, hd) [already GQA-repeated].
  Returns unnormalized (o, m, l) updates for the running softmax.
  """
  s = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                 k.astype(jnp.float32)) * scale
  qpos = q_start + jnp.arange(q_blk.shape[1])[:, None]
  kpos = kv_start + jnp.arange(k.shape[1])[None, :]
  mask = (kpos <= qpos) & (kpos < kv_len)
  return jnp.where(mask[None, None], s, NEG_INF)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
                    cs: Constraint = _id_cs) -> jax.Array:
  """Causal blockwise attention. q: (b, s, h, hd); k, v: (b, s, kv, hd)."""
  b, s, h, hd = q.shape
  kvh = k.shape[2]
  if h != kvh:  # GQA: repeat kv heads (replicated kv + head-sharded q is fine)
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
  bq = min(cfg.attn_block_q, s)
  bkv = min(cfg.attn_block_kv, s)
  nq, nk = s // bq, s // bkv
  scale = 1.0 / (hd ** 0.5)

  kb = k.reshape(b, nk, bkv, h, hd)
  vb = v.reshape(b, nk, bkv, h, hd)

  def q_block_body(i, q_blk, n_kv_blocks):
    """Online softmax over kv blocks 0..n_kv_blocks-1 for query block i."""
    m0 = jnp.full((b, h, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, bq), jnp.float32)
    o0 = jnp.zeros((b, bq, h, hd), jnp.float32)

    def kv_step(carry, j):
      m, l, o = carry
      kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
      vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
      sc = _block_attend(q_blk, kj, vj, i * bq, j * bkv, s, scale)
      m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
      p = jnp.exp(sc - m_new[..., None])
      alpha = jnp.exp(m - m_new)
      l = l * alpha + jnp.sum(p, axis=-1)
      o = o * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
          "bhqk,bkhd->bqhd", p, vj.astype(jnp.float32))
      return (m_new, l, o), None

    (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                jnp.arange(n_kv_blocks))
    o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return o.astype(q.dtype)

  qb = q.reshape(b, nq, bq, h, hd)
  if cfg.causal_wedge:
    # Statically unrolled wedge: query block i visits kv blocks 0..i only.
    # Halves prefill attention FLOPs (sum_{i<nq} (i+1) vs nq*nk tiles).
    outs = [q_block_body(i, qb[:, i],
                         min(((i + 1) * bq + bkv - 1) // bkv, nk))
            for i in range(nq)]
    out = jnp.stack(outs, axis=1)
  else:
    def outer(_, xs):
      i, q_blk = xs
      return None, q_block_body(i, q_blk, nk)
    _, out = jax.lax.scan(outer, None,
                          (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    out = out.transpose(1, 0, 2, 3, 4)
  return out.reshape(b, s, h, hd)


def attention_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                      cs: Constraint = _id_cs, policy=None) -> jax.Array:
  """Full-sequence causal self-attention (train / prefill)."""
  b, s, _ = x.shape
  positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
  q, k, v = _project_qkv(p, x, cfg, positions, cs, policy)
  out = flash_attention(q, k, v, cfg, cs)
  h, hd = cfg.num_heads, cfg.resolved_head_dim
  return gemm(p["wo"], out.reshape(b, s, h * hd), policy)


# ----------------------------------------------------------------------------
# Decode path (single new token against a KV cache).
# ----------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  stack: tuple[int, ...] = (), dtype=None) -> dict:
  kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
  dtype = dtype or cfg.dtype
  return {
      "k": jnp.zeros(stack + (batch, max_len, kv, hd), dtype),
      "v": jnp.zeros(stack + (batch, max_len, kv, hd), dtype),
  }


def attention_decode(p: dict, x: jax.Array, cache: dict,
                     positions: jax.Array, cfg: ModelConfig,
                     cs: Constraint = _id_cs, policy=None
                     ) -> tuple[jax.Array, dict]:
  """One decode step. x: (b, 1, d); positions: (b,) write offsets."""
  b = x.shape[0]
  h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
  q, k_new, v_new = _project_qkv(p, x, cfg, positions[:, None], cs, policy)
  # scatter the new kv at per-sequence positions
  bidx = jnp.arange(b)
  k_cache = cache["k"].at[bidx, positions].set(
      k_new[:, 0].astype(cache["k"].dtype))
  v_cache = cache["v"].at[bidx, positions].set(
      v_new[:, 0].astype(cache["v"].dtype))
  k = k_cache
  v = v_cache
  if h != kvh:
    # repeat via reshape-free einsum grouping: fold group dim into score calc
    group = h // kvh
    qg = q[:, 0].reshape(b, kvh, group, hd)              # (b, kv, g, hd)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.arange(k.shape[1])[None, None, None, :] <= \
        positions[:, None, None, None]
    sc = jnp.where(mask, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, v.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
  else:
    sc = jnp.einsum("bhd,bshd->bhs", q[:, 0].astype(jnp.float32),
                    k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.arange(k.shape[1])[None, None, :] <= positions[:, None, None]
    sc = jnp.where(mask, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", pr, v.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
  y = gemm(p["wo"], out, policy)
  return y, {"k": k_cache, "v": v_cache}


def attention_decode_window(p: dict, x: jax.Array, cache: dict,
                            positions: jax.Array, cfg: ModelConfig,
                            cs: Constraint = _id_cs, policy=None
                            ) -> tuple[jax.Array, dict]:
  """Batched W-token decode window. x: (b, W, d); positions: (b,) start.

  The speculative-verify forward: all W tokens go through the q/k/v/o
  GEMMs in one (b*W)-row pass — ONE weight read for the whole window,
  the paper's §4 amortization — then attend causally against the KV
  cache with per-query masks (query t sees absolute positions <=
  positions + t). Each output row is bit-identical to running
  `attention_decode` W times: the GEMM rows are independent dots, the
  new KV rows land at the same absolute slots in the same cache dtype,
  and masked (future-window) cache rows contribute exactly 0 after the
  softmax — the same way unwritten rows already do in the single step.
  Out-of-bounds window writes at the max_len boundary drop, as before.
  """
  b, W, _ = x.shape
  h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
  pos2d = positions[:, None] + jnp.arange(W)[None, :]           # (b, W)
  q, k_new, v_new = _project_qkv(p, x, cfg, pos2d, cs, policy)
  bidx = jnp.arange(b)[:, None]
  k_cache = cache["k"].at[bidx, pos2d].set(k_new.astype(cache["k"].dtype))
  v_cache = cache["v"].at[bidx, pos2d].set(v_new.astype(cache["v"].dtype))
  mask = jnp.arange(k_cache.shape[1])[None, None, :] <= pos2d[:, :, None]
  if h != kvh:
    group = h // kvh
    qg = q.reshape(b, W, kvh, group, hd)
    sc = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) / (hd ** 0.5)
    sc = jnp.where(mask[:, :, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", pr,
                     v_cache.astype(jnp.float32))
    out = out.reshape(b, W, h * hd).astype(x.dtype)
  else:
    sc = jnp.einsum("bqhd,bshd->bqhs", q.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) / (hd ** 0.5)
    sc = jnp.where(mask[:, :, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqhs,bshd->bqhd", pr, v_cache.astype(jnp.float32))
    out = out.reshape(b, W, h * hd).astype(x.dtype)
  y = gemm(p["wo"], out, policy)
  return y, {"k": k_cache, "v": v_cache}
