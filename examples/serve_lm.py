"""Serve a small LM through the continuous-batching decode engine —
mixed-length requests sharing a few slots (the paper's low-batch regime),
including the compressed-inference path: the same model served (a) dense
and (b) stage-2 factored, comparing weight bytes per decode step (the
quantity the farm kernels stream).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan, to_stage1, to_stage2
from repro.core.factored import count_params
from repro.core.svd import TruncationSpec
from repro.models.api import get_model
from repro.serving import LMEngine


def serve(tag, cfg, params, requests):
  eng = LMEngine(cfg, params, batch_size=4, max_len=64)
  for prompt, budget in requests:
    eng.submit(prompt, max_new_tokens=budget)
  t0 = time.perf_counter()
  finished = eng.run(temperature=0.7)
  dt = time.perf_counter() - t0
  tokens = sum(len(f.tokens) for f in finished)
  print(f"  params {count_params(params):,}; {tokens} tokens over "
        f"{len(finished)} requests, {tokens / dt:.1f} tok/s (CPU), "
        f"occupancy {eng.occupancy:.2f}; "
        f"sample {finished[0].tokens[:6].tolist()}")
  return finished


def main():
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=512,
                                            dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  rng = np.random.RandomState(0)
  # 8 mixed-length requests through 4 slots: retired slots refill mid-run
  requests = [(rng.randint(1, 512, size=(rng.randint(3, 12),)),
               int(rng.randint(4, 16))) for _ in range(8)]

  print("== dense serving ==")
  serve("dense", cfg, params, requests)

  print("== stage-2 factored serving (paper's compressed path) ==")
  plan = FactorizationPlan(min_dim=64)
  factored = to_stage2(to_stage1(params, plan), plan,
                       TruncationSpec(variance_threshold=0.8, round_to=8))
  # kernel_policy="pallas" would route eligible decode GEMMs through the
  # shape-specialized kernels (factored leaves -> fused lowrank_gemm);
  # tiny smoke dims fall back to jnp, so this stays the jnp path on CPU
  serve("factored", cfg, factored, requests)
  p0, p1 = count_params(params), count_params(factored)
  print(f"  {100 * (1 - p1 / p0):.0f}% fewer weight bytes to stream "
        f"per decode step")


if __name__ == "__main__":
  main()
