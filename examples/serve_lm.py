"""Serve a small LM with batched requests through the decode engine —
including the paper's compressed-inference path: the same model served
(a) dense and (b) stage-2 factored, comparing weight bytes per decode
step (the quantity the farm kernels stream).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan, to_stage1, to_stage2
from repro.core.factored import count_params
from repro.core.svd import TruncationSpec
from repro.models.api import get_model
from repro.serving import LMEngine


def main():
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=512,
                                            dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = np.random.RandomState(0).randint(1, 512, size=(4, 8))

  print("== dense serving ==")
  eng = LMEngine(cfg, params, batch_size=4, max_len=64)
  t0 = time.perf_counter()
  out = eng.generate(prompts, steps=12, temperature=0.7)
  dt = time.perf_counter() - t0
  print(f"  params {count_params(params):,}; "
        f"{12 * 4 / dt:.1f} tok/s (CPU); sample {out.tokens[0][:6]}")

  print("== stage-2 factored serving (paper's compressed path) ==")
  plan = FactorizationPlan(min_dim=64)
  factored = to_stage2(to_stage1(params, plan), plan,
                       TruncationSpec(variance_threshold=0.8, round_to=8))
  # kernel_policy="pallas" routes eligible decode GEMMs through the
  # shape-specialized kernels (factored leaves -> fused lowrank_gemm);
  # tiny smoke dims fall back to jnp, so this is a pure API demo on CPU
  eng2 = LMEngine(cfg, factored, batch_size=4, max_len=64,
                  kernel_policy="pallas")
  t0 = time.perf_counter()
  out2 = eng2.generate(prompts, steps=12, temperature=0.7)
  dt2 = time.perf_counter() - t0
  p0, p1 = count_params(params), count_params(factored)
  print(f"  params {p1:,} ({100 * (1 - p1 / p0):.0f}% fewer weight bytes "
        f"to stream per decode step); {12 * 4 / dt2:.1f} tok/s (CPU); "
        f"sample {out2.tokens[0][:6]}")


if __name__ == "__main__":
  main()
