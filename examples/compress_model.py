"""Offline compression pipeline: take trained (unfactored) weights, apply
the paper's stage-2 truncated-SVD warmstart at several thresholds, and
print the accuracy-vs-parameters trade-off table (the Fig. 4 workflow as
a tool). Works on any arch in the registry.

    PYTHONPATH=src python examples/compress_model.py --arch xlstm-350m
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan, to_stage2
from repro.core.factored import count_params
from repro.core.svd import TruncationSpec
from repro.data.lm import LMDataConfig, batch_at
from repro.models.api import get_model
from repro.training import TrainConfig, Trainer


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="xlstm-350m",
                  choices=configs.ARCH_NAMES)
  ap.add_argument("--pretrain-steps", type=int, default=40)
  args = ap.parse_args()

  cfg = configs.get_smoke(args.arch).with_(vocab_size=128,
                                           dtype=jnp.float32)
  dc = LMDataConfig(vocab_size=128, seq_len=32, global_batch=8)
  api = get_model(cfg)

  # "pretrained" model: a short unregularized training run
  trainer = Trainer(cfg, TrainConfig(lr=1e-3))
  for i in range(args.pretrain_steps):
    trainer.train_step(batch_at(dc, i))
  base = trainer.params
  base_loss = trainer.metrics_history[-1]["loss"]

  def eval_loss(params):
    b = batch_at(dc, 900)
    loss, _ = api.loss_fn(params, {k: jnp.asarray(v) for k, v in b.items()},
                          cfg)
    return float(loss)

  plan = FactorizationPlan(min_dim=48)
  print(f"{'threshold':>10} {'params':>12} {'reduction':>10} "
        f"{'eval loss':>10}")
  print(f"{'dense':>10} {count_params(base):>12,} {'-':>10} "
        f"{eval_loss(base):>10.3f}")
  # NOTE: without stage-1 trace-norm regularization the weights are near
  # full rank, so high thresholds can *grow* the model (rank r costs
  # r(m+n) > mn params once r > mn/(m+n)) — exactly the paper's argument
  # for regularizing before truncating (Figs. 2-4).
  for thr in (0.99, 0.95, 0.9, 0.8, 0.6):
    comp = to_stage2(base, plan, TruncationSpec(variance_threshold=thr,
                                                round_to=8))
    p = count_params(comp)
    red = 100 * (1 - p / count_params(base))
    print(f"{thr:>10} {p:>12,} {red:>9.1f}% {eval_loss(comp):>10.3f}")


if __name__ == "__main__":
  main()
