"""Quickstart: compress any model with the paper's two-stage recipe.

    PYTHONPATH=src python examples/quickstart.py

1. Build a small LM (llama3-family smoke config).
2. Stage 1: factor every large GEMM (W = UV, full rank) and train with the
   variational trace-norm penalty (paper eq. 3).
3. Stage 2: warmstart from the truncated SVD at a 90% explained-variance
   threshold and fine-tune without regularization.
4. Report the parameter reduction and per-GEMM rank/nu diagnostics.
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.compress import FactorizationPlan, compression_report
from repro.core.factored import count_params
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data.lm import LMDataConfig, batch_at
from repro.training import TrainConfig, Trainer


def main():
  cfg = configs.get_smoke("llama3-8b").with_(vocab_size=256,
                                             dtype=jnp.float32)
  data = LMDataConfig(vocab_size=256, seq_len=64, global_batch=8)

  schedule = TwoStageSchedule(
      total_steps=60, transition_step=40,
      regularizer=RegularizerConfig(kind="trace", lambda_rec=1e-4,
                                    lambda_nonrec=1e-4),
      truncation=TruncationSpec(variance_threshold=0.9, round_to=8))
  plan = FactorizationPlan(min_dim=64)

  trainer = Trainer(cfg, TrainConfig(lr=1e-3), schedule=schedule, plan=plan)
  p0 = count_params(trainer.params)
  print(f"stage-1 (full-rank factored) params: {p0:,}")

  for step in range(60):
    m = trainer.train_step(batch_at(data, step))
    if step % 10 == 0 or step == 59:
      print(f"  step {step:3d} stage {m['stage']} loss {m['loss']:.3f}")

  p1 = count_params(trainer.params)
  print(f"stage-2 (rank-truncated) params:     {p1:,}  "
        f"({100 * (1 - p1 / p0):.0f}% smaller)")

  print("\nper-GEMM diagnostics (nu, rank @ 90% variance):")
  for name, r in list(trainer.tracenorm_report().items())[:6]:
    print(f"  {name:28s} nu={r['nu']:.3f} rank90={int(r['rank90'])}")


if __name__ == "__main__":
  main()
