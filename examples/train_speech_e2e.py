"""End-to-end driver: train the paper's DS2 acoustic model with the
two-stage trace-norm recipe on the synthetic speech task, with
checkpointing and a supervised (fault-tolerant) step loop, then report
CER before/after and the compression achieved.

    PYTHONPATH=src python examples/train_speech_e2e.py [--steps 300]

This is the ~100M-class configuration scaled for CPU; on a pod the same
driver runs the full deepspeech2-wsj config (launch/train.py --full).
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan
from repro.core.factored import count_params
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data.speech import SpeechDataConfig, batch_at, cer
from repro.models import deepspeech
from repro.models.ctc import ctc_greedy_decode
from repro.runtime import Supervisor
from repro.training import TrainConfig, Trainer


def evaluate(trainer, cfg, dc, batches=3):
  scores = []
  for j in range(batches):
    b = batch_at(dc, 5000 + j)
    lp = deepspeech.forward(trainer.params, jnp.asarray(b["feats"]), cfg)
    ol = deepspeech.output_lengths(jnp.asarray(b["feat_lengths"]), cfg)
    scores.append(cer(np.asarray(ctc_greedy_decode(lp, ol)), b["labels"],
                      b["label_lengths"]))
  return float(np.mean(scores))


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--steps", type=int, default=300)
  ap.add_argument("--transition", type=int, default=180)
  args = ap.parse_args()

  cfg = configs.get_smoke("deepspeech2-wsj").with_(dtype=jnp.float32)
  dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                        global_batch=8, max_label_len=12, noise=0.2)
  ckpt_dir = tempfile.mkdtemp(prefix="ds2_ckpt_")

  schedule = TwoStageSchedule(
      total_steps=args.steps, transition_step=args.transition,
      regularizer=RegularizerConfig(kind="trace", lambda_rec=3e-5,
                                    lambda_nonrec=3e-5),
      truncation=TruncationSpec(variance_threshold=0.9, round_to=8))
  trainer = Trainer(
      cfg, TrainConfig(lr=1e-3, checkpoint_dir=ckpt_dir,
                       checkpoint_every=50, async_checkpoint=True),
      schedule=schedule, plan=FactorizationPlan(min_dim=48))
  supervisor = Supervisor(restore=trainer.restore)

  print(f"stage-1 params {count_params(trainer.params):,}; "
        f"CER before training: {evaluate(trainer, cfg, dc):.3f}")
  step = 0
  while step < args.steps:
    m = supervisor.run_step(
        step, lambda: trainer.train_step(batch_at(dc, trainer.step)))
    if step % 50 == 0 or step == args.steps - 1:
      print(f"  step {m['step']:4d} stage {m['stage']} "
            f"loss {m['loss']:7.3f} wall {m['wall_s']:.2f}s")
    step = trainer.step
  trainer.save(blocking=True)

  print(f"stage-2 params {count_params(trainer.params):,}; "
        f"CER after training: {evaluate(trainer, cfg, dc):.3f}")
  print(f"stragglers flagged: {len(supervisor.events.stragglers)}; "
        f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
  main()
