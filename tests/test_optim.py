"""Optimizers: AdamW exactness, int8-state Adam fidelity, clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, q_adam
from repro.optim.adamw import AdamWConfig, clip_by_global_norm, global_norm


def quad_problem():
  params = {"w": jnp.full((16, 32), 2.0), "b": jnp.full((32,), -1.5)}
  def loss(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
  return params, loss


def test_adamw_converges():
  params, loss = quad_problem()
  st = adamw.init(params)
  cfg = AdamWConfig()
  for _ in range(400):
    g = jax.grad(loss)(params)
    params, st, _ = adamw.apply(params, g, st, 0.05, cfg)
  assert float(loss(params)) < 1e-6


def test_q_adam_tracks_adamw():
  """int8 moments stay within a small relative error of exact AdamW."""
  params, loss = quad_problem()
  pa, pq = params, params
  sa, sq = adamw.init(params), q_adam.init(params)
  cfg = AdamWConfig()
  for _ in range(100):
    ga = jax.grad(loss)(pa)
    gq = jax.grad(loss)(pq)
    pa, sa, _ = adamw.apply(pa, ga, sa, 0.02, cfg)
    pq, sq, _ = q_adam.apply(pq, gq, sq, 0.02, cfg)
  ra = float(loss(pa))
  rq = float(loss(pq))
  assert rq < 4 * ra + 1e-4, (ra, rq)
  for a, q in zip(jax.tree.leaves(pa), jax.tree.leaves(pq)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(q), atol=0.05)


def test_q_adam_state_bytes():
  """The fit argument for dsv3: int8 moments are 4x smaller than f32."""
  params = {"w": jnp.zeros((256, 1024), jnp.bfloat16)}
  sq = q_adam.init(params)
  sa = adamw.init(params)
  q_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(sq))
  f_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(sa))
  assert q_bytes < 0.3 * f_bytes


def test_clip_by_global_norm():
  g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
  clipped, norm = clip_by_global_norm(g, 1.0)
  np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
  np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
  # under the cap: untouched
  same, _ = clip_by_global_norm(g, 100.0)
  np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_weight_decay_skips_1d():
  params = {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}
  st = adamw.init(params)
  cfg = AdamWConfig(weight_decay=0.1)
  zero_g = jax.tree.map(jnp.zeros_like, params)
  p1, _, _ = adamw.apply(params, zero_g, st, 0.1, cfg)
  assert float(jnp.max(jnp.abs(p1["b"] - 1.0))) < 1e-6   # no decay on bias
  assert float(jnp.max(p1["w"])) < 1.0                   # decayed
