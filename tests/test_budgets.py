"""Budget auditor: jaxpr liveness, tolerance-band diffs, the compression
ledger's strict-smaller guarantees, and the budgets CLI exit-code contract.

Seeded-regression tests prove the gates actually fire: an f32-widened
decode state must trip memory_budget red, a doctored committed number
must trip cost_budget red, and an improvement beyond the band must
surface as a ratchet-stale warning (not a finding).
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import budgets, compression, liveness, targets

F32 = jnp.float32


def _liveness(fn, args, **kw):
  return liveness.analyze_jaxpr(jax.make_jaxpr(fn)(*args), **kw)


# ---------------------------------------------------------------------------
# Liveness: last-use walk, donation credit, control-flow descent.
# ---------------------------------------------------------------------------


def test_liveness_chain_exact():
  """y and z overlap for exactly one equation: peak transient is 2 bufs."""
  x = jax.ShapeDtypeStruct((1024,), F32)

  def f(x):
    y = x * 2.0
    z = y + 1.0
    return z.sum()

  rep = _liveness(f, (x,))
  assert rep.input_bytes == 4096
  assert rep.transient_bytes == 8192       # y (4096) live while z allocates
  assert rep.peak_bytes == 4096 + 8192
  assert rep.output_bytes == 4
  assert rep.donated_bytes == rep.credited_bytes == 0


def test_liveness_donation_credit():
  """An output aliasing a donated input allocates nothing; the same
  program without donation pays for the output buffer."""
  s = jax.ShapeDtypeStruct((1024,), F32)
  t = jax.ShapeDtypeStruct((1024,), F32)

  def step(s, t):
    return s + t

  donated = _liveness(step, (s, t), n_params=0, n_donated=1)
  assert donated.donated_bytes == 4096
  assert donated.credited_bytes == 4096    # s' writes into s's buffer
  assert donated.transient_bytes == 0
  assert donated.peak_bytes == donated.input_bytes == 8192

  plain = _liveness(step, (s, t))
  assert plain.credited_bytes == 0
  assert plain.transient_bytes == 4096
  assert plain.peak_bytes == 8192 + 4096


def test_liveness_scan_counts_one_iteration():
  """A scan body's transient peak counts once (carries reuse buffers),
  not multiplied by the trip count."""
  h0 = jax.ShapeDtypeStruct((1024,), F32)

  def f(h0):
    def body(h, _):
      return h * 2.0 + 1.0, None
    h, _ = jax.lax.scan(body, h0, None, length=10)
    return h

  rep = _liveness(f, (h0,))
  assert rep.input_bytes == 4096
  # inner peak: mul result live while add allocates = 8192; the outer
  # scan outvar (the carry out) adds its own 4096 on top of nothing
  assert rep.transient_bytes == 8192
  assert rep.transient_bytes < 10 * 4096   # no trip-count multiplication


# ---------------------------------------------------------------------------
# Tolerance-band diff semantics.
# ---------------------------------------------------------------------------

_COORD = dict(config="c", policy="jnp", quant="float", program="decode")
_KEY = "c|jnp|float|decode"


def _committed(**over):
  base = dict(flops=1000.0, hbm_bytes=1000.0, peak_live_bytes=1000,
              input_bytes=100, dominant="memory")
  base.update(over)
  return {_KEY: base}


def test_band_inside_is_silent():
  led = dict(flops=1040.0, hbm_bytes=1090.0, peak_live_bytes=1040,
             input_bytes=100, dominant="memory")
  f, w = budgets.diff_program(_COORD, led, _committed())
  assert f == [] and w == []


def test_band_regression_is_red():
  led = dict(flops=1060.0, hbm_bytes=1200.0, peak_live_bytes=1060,
             input_bytes=101, dominant="memory")
  f, w = budgets.diff_program(_COORD, led, _committed())
  assert w == []
  assert {x.key for x in f} == {
      "over-budget:flops", "over-budget:hbm_bytes",
      "over-budget:peak_live_bytes", "over-budget:input_bytes"}
  assert {x.check for x in f} == {"cost_budget", "memory_budget"}
  by_key = {x.key: x for x in f}
  assert by_key["over-budget:flops"].check == "cost_budget"
  assert by_key["over-budget:input_bytes"].check == "memory_budget"


def test_band_improvement_is_ratchet_stale_not_red():
  led = dict(flops=900.0, hbm_bytes=800.0, peak_live_bytes=900,
             input_bytes=100, dominant="memory")
  f, w = budgets.diff_program(_COORD, led, _committed())
  assert f == []
  assert {x["metric"] for x in w} == {"flops", "hbm_bytes",
                                      "peak_live_bytes"}
  assert all("--update" in x["note"] for x in w)


def test_dominant_flip_is_red():
  led = dict(flops=1000.0, hbm_bytes=1000.0, peak_live_bytes=1000,
             input_bytes=100, dominant="compute")
  f, _ = budgets.diff_program(_COORD, led, _committed())
  assert [x.key for x in f] == ["dominant-flip:memory->compute"]
  assert f[0].check == "cost_budget"


def test_unbudgeted_coordinate_is_red_per_check():
  led = dict(flops=1.0, hbm_bytes=1.0, peak_live_bytes=1, input_bytes=1)
  f, _ = budgets.diff_program(_COORD, led, {})
  assert sorted(x.check for x in f) == ["cost_budget", "memory_budget"]
  assert all(x.key == "unbudgeted" for x in f)
  # a memory-only ledger (shallow, uncompiled) only owes a memory budget
  f2, _ = budgets.diff_program(_COORD, dict(peak_live_bytes=1,
                                            input_bytes=1), {})
  assert [x.check for x in f2] == ["memory_budget"]


def test_merge_budgets_is_fieldwise():
  """A shallow refresh (memory metrics only) must not drop the committed
  cost metrics of the same coordinate."""
  committed = {"meta": {"jax_version": "old"},
               "programs": {_KEY: dict(flops=5.0, peak_live_bytes=10)},
               "compression": {"c": {"variants": {}}}}
  fresh = {"meta": {"jax_version": "new"},
           "programs": {_KEY: dict(peak_live_bytes=12),
                        "d|jnp|float|decode": dict(peak_live_bytes=1)},
           "compression": {}}
  out = budgets.merge_budgets(committed, fresh)
  assert out["programs"][_KEY] == dict(flops=5.0, peak_live_bytes=12)
  assert "d|jnp|float|decode" in out["programs"]
  assert out["compression"] == {"c": {"variants": {}}}
  assert out["meta"]["jax_version"] == "new"


def test_budgets_io_roundtrip(tmp_path):
  path = str(tmp_path / "b.json")
  assert budgets.load_budgets(path) == {"meta": {}, "programs": {},
                                        "compression": {}}
  budgets.write_budgets({"meta": {}, "programs": {_KEY: {"flops": 1}},
                         "compression": {}}, path)
  assert budgets.load_budgets(path)["programs"][_KEY] == {"flops": 1}
  (tmp_path / "bad.json").write_text('{"programs": []}')
  with pytest.raises(ValueError, match="programs"):
    budgets.load_budgets(str(tmp_path / "bad.json"))


# ---------------------------------------------------------------------------
# Seeded regression: a widened decode state must trip the gate red.
# ---------------------------------------------------------------------------


def _seeded_decode(dtype):
  """A toy decode step whose state dominates the footprint."""
  w = jax.ShapeDtypeStruct((64, 64), F32)
  state = jax.ShapeDtypeStruct((256, 64), dtype)

  def step(p, s):
    s2 = (s.astype(F32) @ p).astype(dtype)
    return s2, s2.sum(axis=-1)

  closed, log, low, comp = targets._trace(
      step, (w, state), donate=(1,), lower=True, compile_=True)
  return targets.TraceTarget(
      config="seeded", family="test", policy="jnp", quant="float",
      program="decode", jaxpr=closed, dispatch_log=log, n_params=1,
      int8_param_idx=frozenset(), n_donated=1, lowered_text=low,
      compiled_text=comp)


def test_widened_state_trips_memory_budget_red():
  narrow = budgets.program_ledger(_seeded_decode(jnp.bfloat16))
  committed = {"seeded|jnp|float|decode": narrow}
  wide = budgets.program_ledger(_seeded_decode(F32))
  assert wide["input_bytes"] > narrow["input_bytes"]
  f, _ = budgets.diff_program(
      dict(config="seeded", policy="jnp", quant="float",
           program="decode"), wide, committed)
  assert f, "f32-widened state did not trip the budget gate"
  assert {x.check for x in f} <= {"cost_budget", "memory_budget"}
  assert "over-budget:input_bytes" in {x.key for x in f}
  assert any(x.check == "memory_budget" for x in f)


def test_doctored_committed_number_trips_cost_budget_red():
  t = _seeded_decode(F32)
  ledger = budgets.program_ledger(t)
  doctored = dict(ledger, hbm_bytes=int(ledger["hbm_bytes"] * 0.8))
  f, _ = budgets.diff_program(t.coord, ledger,
                              {"seeded|jnp|float|decode": doctored})
  assert [x.key for x in f] == ["over-budget:hbm_bytes"]
  assert f[0].check == "cost_budget"
  # and the mirror image is a ratchet warning, not a finding
  inflated = dict(ledger, hbm_bytes=int(ledger["hbm_bytes"] * 1.25))
  f2, w2 = budgets.diff_program(t.coord, ledger,
                                {"seeded|jnp|float|decode": inflated})
  assert f2 == []
  assert [x["metric"] for x in w2] == ["hbm_bytes"]


# ---------------------------------------------------------------------------
# Compression ledger: strictly smaller across all five families, and
# drift-free against the committed numbers.
# ---------------------------------------------------------------------------


def test_ledger_rank_is_structurally_compressive():
  for m, n in ((128, 128), (2048, 512), (4096, 11008), (129, 257)):
    r = compression.ledger_rank(m, n)
    assert r % 8 == 0 or r == 8
    assert r * (m + n) < m * n


@pytest.mark.parametrize("config", targets.DEFAULT_CONFIGS)
def test_compression_strictly_smaller_every_family(config):
  ledger = compression.compression_ledger(config)
  assert compression.strictness_violations(ledger) == []
  assert ledger["n_factored_gemms"] >= 1
  v = ledger["variants"]
  for small, big in (("int8", "float"), ("lowrank", "float"),
                     ("lowrank_int8", "lowrank")):
    assert v[small]["param_bytes"] < v[big]["param_bytes"]
    assert v[small]["device_bytes"] < v[big]["device_bytes"]
  assert all(0.0 < r < 1.0 for r in ledger["ratios"].values())
  # drift-free against the committed ledger
  committed = budgets.load_budgets()["compression"]
  assert budgets.diff_compression(config, ledger, committed) == []


def test_strictness_violation_surfaces():
  ledger = compression.compression_ledger("xlstm-350m")
  broken = json.loads(json.dumps(ledger))        # deep copy
  broken["variants"]["int8"]["param_bytes"] = \
      broken["variants"]["float"]["param_bytes"]
  broken["variants"]["int8"]["device_bytes"] = \
      broken["variants"]["float"]["device_bytes"]
  found = budgets.diff_compression("xlstm-350m", broken,
                                   budgets.load_budgets()["compression"])
  keys = {f.key for f in found}
  assert "not-smaller:int8-vs-float:param_bytes" in keys
  assert "not-smaller:int8-vs-float:device_bytes" in keys
  assert all(f.check == "compression_ledger" for f in found)


# ---------------------------------------------------------------------------
# Green path + CLI exit codes against the committed budgets.json.
# ---------------------------------------------------------------------------


def test_committed_budgets_green_scoped():
  """Regenerating one real coordinate reproduces the committed numbers
  within the bands (the same invariant CI's budgets step gates on)."""
  audit = budgets.BudgetAudit(budgets.load_budgets())
  (target,) = list(targets.iter_targets(
      ["xlstm-350m"], ["jnp"], ["float"], ["decode"]))
  ledger = audit.add_target(target)
  assert audit.findings == [], [f.ident for f in audit.findings]
  assert audit.warnings == []
  assert ledger["credited_bytes"] == ledger["donated_bytes"]
  assert ledger["dominant"] in ("compute", "memory", "collective")


def test_budgets_cli_exit_codes(tmp_path, capsys):
  from repro.analysis.__main__ import main
  scoped = ["budgets", "--configs", "xlstm_350m", "--policies", "jnp",
            "--quants", "float", "--programs", "decode", "--shallow"]
  # green against the committed file
  rep_path = str(tmp_path / "budgets_report.json")
  assert main(scoped + ["--report", rep_path]) == 0
  saved = json.loads(open(rep_path).read())
  assert saved["ok"] and saved["programs"] and saved["compression"]
  assert saved["findings"] == []
  # bootstrap state: an empty budgets file turns the exit code red
  empty = str(tmp_path / "empty.json")
  assert main(scoped + ["--budgets", empty]) == 1
  assert "unbudgeted" in capsys.readouterr().out
  # --update admits the current numbers; the same run then passes
  assert main(scoped + ["--budgets", empty, "--update"]) == 0
  assert main(scoped + ["--budgets", empty]) == 0
