"""repro.quant end-to-end: PTQ pass, QuantizedLinear math, dispatch
routing, calibration, and the quantized-serving parity acceptance —
LMEngine under the pallas policy with PTQ'd params must match the f32
jnp_only engine token-for-token on greedy decode, and the two policies
must agree on a PTQ'd tree exactly (same w8a8 arithmetic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import FactorizationPlan, to_stage1
from repro.core.factored import (FactoredLinear, count_params, dense,
                                 factored, is_gemm_leaf, iter_gemm_leaves)
from repro.kernels import dispatch
from repro.layers.common import ModelConfig, gemm
from repro.models.api import get_model
from repro.quant import (QuantizedLinear, calibrate_activation_ranges,
                         is_quantized, quantize_leaf, quantize_params)
from repro.serving import LMEngine

KEY = jax.random.PRNGKey(0)


def rnd(seed, shape, scale=1.0):
  return jax.random.normal(jax.random.PRNGKey(seed), shape,
                           jnp.float32) * scale


LM_CFG = ModelConfig(
    name="quant-lm", family="transformer", num_layers=2, d_model=128,
    num_heads=1, num_kv_heads=1, d_ff=256, vocab_size=128,
    dtype=jnp.float32, remat="none")


# ---------------------------------------------------------------------------
# The leaf + PTQ pass.
# ---------------------------------------------------------------------------


def test_quantize_leaf_unfactored():
  leaf = dense(KEY, 96, 160, name="fc", group="nonrec")
  q = quantize_leaf(leaf)
  assert q.name == "fc" and q.group == "nonrec" and not q.is_factored
  assert q.w_q.dtype == jnp.int8 and q.w_scale.shape == (160,)
  assert (q.in_dim, q.out_dim) == (96, 160)
  assert q.num_params == leaf.num_params
  # dequantized product inside half a per-column step of the original
  err = jnp.abs(q.product() - leaf.w)
  assert bool(jnp.all(err <= q.w_scale[None, :] * 0.5 + 1e-6))


def test_quantize_leaf_factored():
  leaf = factored(KEY, 128, 256, r=64, name="lr")
  q = quantize_leaf(leaf)
  assert q.is_factored and q.u_q.dtype == jnp.int8
  assert q.u_scale.shape == (64,) and q.v_scale.shape == (256,)
  assert q.rank == 64 and q.num_params == leaf.num_params
  x = rnd(1, (4, 128))
  rel = jnp.linalg.norm(q.apply(x) - leaf.apply(x)) / \
      jnp.linalg.norm(leaf.apply(x))
  assert float(rel) < 0.05


def test_all_zero_weight_degenerate():
  """Plain-test analog of the hypothesis degenerate-case property (runs
  even without hypothesis installed)."""
  leaf = FactoredLinear(w=jnp.zeros((32, 48)), u=None, v=None, name="z")
  q = quantize_leaf(leaf)
  assert bool(jnp.all(q.w_q == 0)) and bool(jnp.all(q.w_scale > 0))
  y = q.apply(jnp.ones((2, 32), jnp.float32))
  assert bool(jnp.all(y == 0.0)) and bool(jnp.all(jnp.isfinite(y)))


def test_quantize_params_plan_scoping():
  params = {
      "fc": dense(KEY, 128, 128, name="fc"),
      "out": dense(KEY, 128, 64, name="out"),
      "emb": dense(KEY, 64, 128, name="tok_embed"),
  }
  q = quantize_params(params, FactorizationPlan(
      include=("*",), exclude=("*embed*",), min_dim=1))
  assert isinstance(q["fc"], QuantizedLinear)
  assert isinstance(q["out"], QuantizedLinear)
  assert isinstance(q["emb"], FactoredLinear)      # excluded, untouched
  assert is_quantized(q) and not is_quantized(params)
  # name-keyed traversal still sees every GEMM leaf whole
  names = {l.name for l in iter_gemm_leaves(q)}
  assert names == {"fc", "out", "tok_embed"}
  assert all(is_gemm_leaf(l) for l in iter_gemm_leaves(q))
  assert count_params(q) == count_params(params)


def test_quantize_params_stacked_leaves_per_layer():
  """A scanned (L, m, n) stack quantizes per (layer, column); slicing
  the fields — what lax.scan does with the params pytree — recovers
  exactly the leaf 2-D quantization would have produced."""
  w = rnd(3, (2, 64, 64))
  stacked = FactoredLinear(w=w, u=None, v=None, name="layers/scan")
  q = quantize_params({"s": stacked, "fc": dense(KEY, 64, 64, name="fc")})
  assert isinstance(q["s"], QuantizedLinear)
  assert isinstance(q["fc"], QuantizedLinear)
  assert q["s"].w_q.shape == (2, 64, 64) and q["s"].w_q.dtype == jnp.int8
  assert q["s"].w_scale.shape == (2, 64)
  for i in range(2):
    per_layer = quantize_params(
        {"s": FactoredLinear(w=w[i], u=None, v=None, name="layers/scan")})
    np.testing.assert_array_equal(np.asarray(q["s"].w_q[i]),
                                  np.asarray(per_layer["s"].w_q))
    np.testing.assert_allclose(np.asarray(q["s"].w_scale[i]),
                               np.asarray(per_layer["s"].w_scale))


def test_static_activation_scale_calibration():
  params = {"fc": dense(KEY, 128, 128, name="fc")}
  x = rnd(7, (4, 128), 2.0)
  calib = calibrate_activation_ranges(
      lambda b: gemm(params["fc"], b, dispatch.JNP_ONLY), [x])
  assert calib.keys() == {"fc"}
  assert abs(calib["fc"] - float(jnp.max(jnp.abs(x)))) < 1e-6
  q = quantize_params(params, calib=calib)
  assert q["fc"].act_scale is not None
  # the static-scale path stays close to the dynamic one on in-range data
  y_static = q["fc"].apply(x)
  y_dynamic = quantize_params(params)["fc"].apply(x)
  rel = jnp.linalg.norm(y_static - y_dynamic) / jnp.linalg.norm(y_dynamic)
  assert float(rel) < 0.02
  # out-of-range activations saturate instead of overflowing
  y_sat = q["fc"].apply(100.0 * x)
  assert bool(jnp.all(jnp.isfinite(y_sat)))


# ---------------------------------------------------------------------------
# Dispatch routing.
# ---------------------------------------------------------------------------


def test_quantized_leaf_classifies_int8():
  pol = dispatch.decode_policy(4)
  q = quantize_leaf(dense(KEY, 128, 256, name="fc"))
  x = rnd(2, (2, 128))
  assert dispatch.classify(q, x, pol) == "int8_gemm"
  # also above the decode batch bound and for sub-LANE shapes: quantized
  # storage has no float weight, int8 is the only regime
  assert dispatch.classify(q, rnd(3, (64, 128)), pol) == "int8_gemm"
  tiny = quantize_leaf(dense(KEY, 32, 48, name="tiny"))
  assert dispatch.classify(tiny, rnd(4, (2, 32)), pol) == "int8_gemm"
  # jnp_only / no policy -> the leaf's own w8a8 oracle (same math)
  assert dispatch.classify(q, x, dispatch.JNP_ONLY) == "jnp"
  assert dispatch.classify(q, x, None) == "jnp"
  # an explicit "jnp" override is honored (reference path)
  jpol = dispatch.decode_policy(4, overrides=(("fc", "jnp"),))
  assert dispatch.classify(q, x, jpol) == "jnp"


def test_quantized_gemm_policy_invariant():
  """pallas and jnp paths run the same w8a8 arithmetic bit-for-bit (the
  interpret-mode kernel IS the oracle's blocking)."""
  pol = dispatch.decode_policy(4)
  for leaf in (quantize_leaf(dense(KEY, 128, 256, name="fc")),
               quantize_leaf(factored(KEY, 128, 256, r=128, name="lr"))):
    x = rnd(5, (3, 128))
    np.testing.assert_array_equal(np.asarray(gemm(leaf, x, pol)),
                                  np.asarray(gemm(leaf, x)))
  # 3D activations flatten their leading dims through the kernel
  q = quantize_leaf(dense(KEY, 128, 256, name="fc"))
  x3 = rnd(6, (2, 2, 128))
  np.testing.assert_array_equal(np.asarray(gemm(q, x3, pol)),
                                np.asarray(gemm(q, x3)))


# ---------------------------------------------------------------------------
# Serving (the acceptance criterion).
# ---------------------------------------------------------------------------


def _greedy_tokens(cfg, params, prompts, *, steps, **kw):
  eng = LMEngine(cfg, params, batch_size=prompts.shape[0], max_len=32,
                 **kw)
  return eng.generate(prompts, steps=steps).tokens


def test_quantized_serving_parity():
  """LMEngine under the pallas policy with PTQ'd params matches the f32
  jnp_only engine token-for-token on greedy decode, and the two policies
  agree on the PTQ'd tree exactly."""
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  qparams = quantize_params(params)
  prompts = np.array([[1, 2], [3, 4]])
  want = _greedy_tokens(LM_CFG, params, prompts, steps=8)
  with dispatch.record_dispatch() as log:
    got_pallas = _greedy_tokens(LM_CFG, qparams, prompts, steps=8,
                                kernel_policy="pallas")
  assert "int8_gemm" in {r for _, r in log}
  got_jnp = _greedy_tokens(LM_CFG, qparams, prompts, steps=8)
  np.testing.assert_array_equal(got_pallas, got_jnp)   # policy-invariant
  np.testing.assert_array_equal(got_pallas, want)      # f32 parity


def test_quantized_logits_close_to_f32():
  """The quantization error itself stays at the bench tolerance on the
  engine's comparison surface (prefill logits)."""
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  qparams = quantize_params(params)
  prompts = np.array([[5, 6, 7], [8, 9, 10]])
  ref_eng = LMEngine(LM_CFG, params, batch_size=2, max_len=16)
  q_eng = LMEngine(LM_CFG, qparams, batch_size=2, max_len=16,
                   kernel_policy="pallas")
  want = np.asarray(ref_eng.prefill(prompts), np.float32)
  got = np.asarray(q_eng.prefill(prompts), np.float32)
  rel = np.linalg.norm(got - want) / np.linalg.norm(want)
  assert rel < 0.05


def test_factored_quantized_serving():
  """Stage-2-style factored params survive PTQ and serve policy-
  invariantly (u/v quantized separately, rank intermediate requantized)."""
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  fparams = to_stage1(params, FactorizationPlan(include=("*",),
                                                min_dim=128))
  qparams = quantize_params(fparams)
  assert any(l.is_factored for l in iter_gemm_leaves(qparams)
             if isinstance(l, QuantizedLinear))
  prompts = np.array([[11, 12], [13, 14]])
  got = _greedy_tokens(LM_CFG, qparams, prompts, steps=4,
                       kernel_policy="pallas")
  want = _greedy_tokens(LM_CFG, qparams, prompts, steps=4)
  np.testing.assert_array_equal(got, want)


def test_quantized_target_with_lowrank_draft():
  """Quantization x speculation composes token-for-token: a PTQ'd int8
  target verified against a float low-rank draft (built from the float
  weights BEFORE PTQ — int8 leaves can't be SVD'd) under the pallas
  policy emits exactly the vanilla quantized engine's greedy tokens,
  with both the int8 and lowrank kernels on the hot path."""
  from repro.serving import make_draft_params
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  qparams = quantize_params(params)
  draft = make_draft_params(params, rank=128)
  prompts = np.array([[1, 2], [3, 4], [5, 6]])

  want = _greedy_tokens(LM_CFG, qparams, prompts, steps=8,
                        kernel_policy="pallas")
  with dispatch.record_dispatch() as log:
    eng = LMEngine(LM_CFG, qparams, batch_size=3, max_len=32,
                   kernel_policy="pallas", speculate=2,
                   draft_params=draft)
    out = eng.generate(prompts, steps=8)
  regimes = {r for _, r in log}
  assert "int8_gemm" in regimes         # quantized target
  assert "lowrank_gemm" in regimes      # factored draft
  np.testing.assert_array_equal(out.tokens, want)
  # the draft never saw the quantization error, so acceptance is NOT
  # trivially 1 here — losslessness must hold regardless
  assert out.accept_rate is not None


def test_quantized_params_cannot_seed_a_draft():
  """Auto-building a draft from a fully-quantized tree must fail loudly
  instead of silently speculating with the target itself (int8 leaves
  can't be SVD'd; the LM tree above only dodges this because its stacked
  scan leaves stay float)."""
  from repro.serving import make_draft_params
  params = {"fc": dense(KEY, 128, 128, name="fc"),
            "out": dense(KEY, 128, 256, name="out")}
  q = quantize_params(params)
  assert all(isinstance(l, QuantizedLinear) for l in iter_gemm_leaves(q))
  with pytest.raises(ValueError, match="matched no GEMM leaf"):
    make_draft_params(q)


def test_speech_server_accepts_quantized_params():
  from repro.data.speech import SpeechDataConfig, batch_at
  from repro.serving import StreamingSpeechServer
  cfg = ModelConfig(
      name="quant-ds2", family="deepspeech", num_layers=2, d_model=128,
      num_heads=1, num_kv_heads=1, d_ff=128, vocab_size=32, feat_dim=80,
      gru_dims=(128, 128), fc_dim=128, conv_channels=8, time_stride=2,
      dtype=jnp.float32, remat="none")
  params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
  qparams = quantize_params(params)
  dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                        global_batch=2)
  chunk = batch_at(dc, 0)["feats"][:, :24]
  srv_jnp = StreamingSpeechServer(cfg, qparams, batch_size=2)
  want = srv_jnp.process_chunk(chunk)
  with dispatch.record_dispatch() as log:
    srv_pal = StreamingSpeechServer(cfg, qparams, batch_size=2,
                                    kernel_policy="pallas")
    got = srv_pal.process_chunk(chunk)
  assert "int8_gemm" in {r for _, r in log}
  assert got == want
