"""Continuous-batching speech fleet tests.

Parity is the whole contract, at two strictnesses:

* chunked streaming == full-utterance `deepspeech.forward` for ANY
  utterance length — including lengths that are NOT multiples of the
  conv time stride (the old frontend asserted stride alignment at
  flush). Pinned on a verified seed: per-frame `decode_step` and the
  time-batched training scan are independently-associated float
  programs, so greedy argmax can legitimately flip on near-tie frames
  at random init; the grid pins seeds/lengths where the two agree so a
  failure means a REAL frontend/state bug, not float noise.

* fleet scheduling == serial decoding, bitwise. Both sides run the
  same masked `frame_step` program, so continuous batching (staggered
  admits, retires, refills, masked dead slots) must be token-for-token
  identical to a dedicated batch-1 server — for any length mix, any
  chunking, both kernel policies, float and PTQ int8.

Plus the jit-signature pins (`compile_stats`): one masked frame-step
signature ever, slot insertion traced once, conv windows bucketed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import deepspeech
from repro.models.api import get_model
from repro.serving import StreamingSpeechServer

#: deliberately stride-hostile lengths (time_stride totals 4 across the
#: two convs): primes, pow2±1, and exact multiples mixed together
PARITY_LENS = (1, 3, 4, 7, 9, 16, 17, 23, 31, 33, 40, 47, 48)


@pytest.fixture(scope="module")
def speech():
  cfg = configs.get_smoke("deepspeech2-wsj")
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  return cfg, params


def _collapse(best_row):
  prev, out = -1, []
  for lab in best_row:
    if lab != 0 and lab != prev:
      out.append(int(lab))
    prev = lab
  return out


def _full_forward_labels(params, feats, cfg):
  lp = deepspeech.forward(params, jnp.asarray(feats[None]), cfg)
  return _collapse(np.asarray(jnp.argmax(lp, -1))[0])


def _serial_labels(cfg, params, utts, *, policy=None, chunk=7):
  """Oracle: each utterance alone through a batch-1 fleet."""
  srv = StreamingSpeechServer(cfg, params, batch_size=1,
                              kernel_policy=policy)
  for u in utts:
    srv.submit(u)
  return {r.uid: list(r.labels) for r in srv.run(chunk_frames=chunk)}


# ---------------------------------------------------------------------------
# chunked == full forward, every length class
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_chunked_matches_full_forward_any_length(speech):
  """The fixed-left-pad frontend + pad-and-mask flush make streamed CTC
  labels equal the full-utterance forward for lengths that are NOT
  stride multiples (the old flush asserted `t % (2 * time_stride) == 0`
  and crashed on them)."""
  cfg, params = speech
  rng = np.random.RandomState(0)
  for t in PARITY_LENS:
    feats = rng.randn(1, t, cfg.feat_dim).astype(np.float32)
    ref = _full_forward_labels(params, feats[0], cfg)
    srv = StreamingSpeechServer(cfg, params, batch_size=1)
    srv.submit(feats[0])
    (res,) = srv.run(chunk_frames=5)
    assert list(res.labels) == ref, f"t={t}"
    assert res.frames == t            # input mel frames, fully consumed


@pytest.mark.slow
def test_lockstep_flush_non_multiple_length(speech):
  """The legacy lockstep surface handles a non-stride-multiple tail the
  same way: flush pads the residual window instead of asserting."""
  cfg, params = speech
  rng = np.random.RandomState(0)
  t = 23                                   # 23 % 4 != 0
  feats = rng.randn(2, t, cfg.feat_dim).astype(np.float32)
  ref = [_full_forward_labels(params, feats[i], cfg) for i in range(2)]
  srv = StreamingSpeechServer(cfg, params, batch_size=2)
  got = [[], []]
  for chunk in np.split(feats, [9, 16], axis=1):   # uneven chunking too
    for i, e in enumerate(srv.process_chunk(chunk)):
      got[i].extend(e)
  for i, e in enumerate(srv.flush()):
    got[i].extend(e)
  assert got == ref
  # flush is idempotent and terminal until reset()
  assert srv.flush() == [[], []]
  with pytest.raises(RuntimeError, match="reset"):
    srv.process_chunk(feats[:, :4])


# ---------------------------------------------------------------------------
# fleet == serial, bitwise
# ---------------------------------------------------------------------------

#: more utterances than slots, mixed stride-hostile lengths: admits are
#: staggered (each retire refills mid-decode of the survivors)
FLEET_LENS = (17, 9, 31, 4, 23, 40)


@pytest.mark.slow
@pytest.mark.parametrize("policy", [None, "pallas"])
def test_fleet_matches_serial(speech, policy):
  cfg, params = speech
  rng = np.random.RandomState(0)
  utts = [rng.randn(t, cfg.feat_dim).astype(np.float32)
          for t in FLEET_LENS]
  serial = _serial_labels(cfg, params, utts, policy=policy)

  srv = StreamingSpeechServer(cfg, params, batch_size=2,
                              kernel_policy=policy)
  uids = [srv.submit(u) for u in utts]
  results = {r.uid: r for r in srv.run(chunk_frames=7)}
  assert sorted(results) == sorted(uids)
  for uid in uids:
    assert list(results[uid].labels) == serial[uid]

  # per-stream CTC collapse state: stream i's labels must also equal a
  # fleet where it is the ONLY utterance (no cross-stream prev leakage,
  # no stale prev on the slot its retire freed for a refill)
  solo = _serial_labels(cfg, params, [utts[4]], policy=policy)
  assert list(results[uids[4]].labels) == solo[0]


@pytest.mark.slow
def test_fleet_matches_serial_int8(speech):
  """Continuous batching composes with PTQ: the masked frame step runs
  the int8_gemm regime and fleet == serial still holds bitwise."""
  from repro.quant import quantize_params
  cfg, params = speech
  qparams = quantize_params(params)
  rng = np.random.RandomState(0)
  utts = [rng.randn(t, cfg.feat_dim).astype(np.float32)
          for t in (17, 23, 9)]
  serial = _serial_labels(cfg, qparams, utts)
  srv = StreamingSpeechServer(cfg, qparams, batch_size=2)
  for u in utts:
    srv.submit(u)
  got = {r.uid: list(r.labels) for r in srv.run(chunk_frames=7)}
  assert got == serial


# ---------------------------------------------------------------------------
# jit-signature pins
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fleet_compile_stats_pin(speech):
  """One masked frame-step signature across an admit/retire/refill
  cycle with mixed lengths; slot surgery traced once; each conv stage
  exactly one signature per pow2 window bucket."""
  cfg, params = speech
  rng = np.random.RandomState(0)
  srv = StreamingSpeechServer(cfg, params, batch_size=2)
  for t in FLEET_LENS:
    srv.submit(rng.randn(t, cfg.feat_dim).astype(np.float32))
  results = srv.run(chunk_frames=7)
  assert len(results) == len(FLEET_LENS)
  stats = srv.compile_stats()
  if stats["frame_step"] < 0:
    pytest.skip("runtime does not expose jit cache sizes")
  assert stats["frame_step"] == 1
  assert stats["insert"] <= 1
  assert stats["conv1"] == len(stats["conv1_buckets"])
  assert stats["conv2"] == len(stats["conv2_buckets"])

  # a SECOND wave through the same server must add no signatures
  for t in (13, 29):
    srv.submit(rng.randn(t, cfg.feat_dim).astype(np.float32))
  srv.run(chunk_frames=4)
  stats2 = srv.compile_stats()
  assert stats2["frame_step"] == 1
  assert stats2["insert"] <= 1
  assert stats2["conv1"] == len(stats2["conv1_buckets"])


# ---------------------------------------------------------------------------
# lifecycle / surface hygiene (fast tier)
# ---------------------------------------------------------------------------


def test_submit_validates_and_modes_are_exclusive(speech):
  cfg, params = speech
  srv = StreamingSpeechServer(cfg, params, batch_size=2)
  with pytest.raises(ValueError):
    srv.submit(np.zeros((4, cfg.feat_dim + 1), np.float32))
  with pytest.raises(ValueError):
    srv.submit(np.zeros((cfg.feat_dim,), np.float32))   # missing time axis
  # lockstep engages the batch group; fleet submit must refuse
  srv2 = StreamingSpeechServer(cfg, params, batch_size=2)
  srv2.process_chunk(np.zeros((2, 8, cfg.feat_dim), np.float32))
  with pytest.raises(RuntimeError):
    srv2.submit(np.zeros((8, cfg.feat_dim), np.float32))
  # and a fleet-mode server must refuse lockstep chunks mid-run
  srv3 = StreamingSpeechServer(cfg, params, batch_size=1)
  srv3.submit(np.zeros((6, cfg.feat_dim), np.float32))
  srv3.run(chunk_frames=4)                  # run() completes -> mode clears
  srv3.process_chunk(np.zeros((1, 8, cfg.feat_dim), np.float32))


def test_conv_time_pads_convention():
  """pad_l fixed at (k - s) // 2, pad_r completes ceil(t / s) output
  frames — for every (t, k, s) the padded valid conv emits exactly
  ceil(t / s) frames, which is what makes streaming exact."""
  for k, s in ((5, 2), (11, 2), (3, 1), (7, 3)):
    for t in range(1, 40):
      pl, pr = deepspeech.conv_time_pads(t, k, s)
      assert pl == (k - s) // 2 and pr >= 0
      out = (t + pl + pr - k) // s + 1
      assert out == -(-t // s), (t, k, s)
