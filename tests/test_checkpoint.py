"""Checkpoint save/restore: roundtrip, async, latest-step, GC."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.factored import dense, factored


def make_tree(key):
  k1, k2 = jax.random.split(key)
  return {
      "layer": {"w": dense(k1, 8, 8, name="w"),
                "fac": factored(k2, 8, 8, 4, name="fac")},
      "step_scale": jnp.float32(0.5),
      "counts": jnp.arange(5),
  }


def _assert_tree_equal(a, b):
  for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  tree = make_tree(jax.random.PRNGKey(0))
  mgr.save(7, tree, extra={"stage": 2})
  restored, extra = mgr.restore(jax.eval_shape(lambda: tree))
  _assert_tree_equal(tree, restored)
  assert extra["stage"] == 2
  assert mgr.latest_step() == 7


def test_async_save(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  tree = make_tree(jax.random.PRNGKey(1))
  mgr.save(1, tree, blocking=False)
  mgr.wait()
  restored, _ = mgr.restore(tree)
  _assert_tree_equal(tree, restored)


def test_gc_keeps_latest(tmp_path):
  mgr = CheckpointManager(str(tmp_path), keep=2)
  tree = {"x": jnp.zeros((2,))}
  for s in (1, 2, 3, 4):
    mgr.save(s, tree)
  assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  mgr.save(0, {"x": jnp.zeros((4,))})
  with pytest.raises(ValueError):
    mgr.restore({"x": jnp.zeros((5,))})


def test_missing_leaf_rejected(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  mgr.save(0, {"x": jnp.zeros((4,))})
  with pytest.raises(KeyError):
    mgr.restore({"x": jnp.zeros((4,)), "y": jnp.zeros((1,))})
