"""Checkpoint save/restore: roundtrip, async, latest-step, GC."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.factored import dense, factored


def make_tree(key):
  k1, k2 = jax.random.split(key)
  return {
      "layer": {"w": dense(k1, 8, 8, name="w"),
                "fac": factored(k2, 8, 8, 4, name="fac")},
      "step_scale": jnp.float32(0.5),
      "counts": jnp.arange(5),
  }


def _assert_tree_equal(a, b):
  for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  tree = make_tree(jax.random.PRNGKey(0))
  mgr.save(7, tree, extra={"stage": 2})
  restored, extra = mgr.restore(jax.eval_shape(lambda: tree))
  _assert_tree_equal(tree, restored)
  assert extra["stage"] == 2
  assert mgr.latest_step() == 7


def test_async_save(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  tree = make_tree(jax.random.PRNGKey(1))
  mgr.save(1, tree, blocking=False)
  mgr.wait()
  restored, _ = mgr.restore(tree)
  _assert_tree_equal(tree, restored)


def test_gc_keeps_latest(tmp_path):
  mgr = CheckpointManager(str(tmp_path), keep=2)
  tree = {"x": jnp.zeros((2,))}
  for s in (1, 2, 3, 4):
    mgr.save(s, tree)
  assert mgr.all_steps() == [3, 4]


def test_quantized_tree_roundtrips_bit_identical(tmp_path):
  """A PTQ'd tree is a first-class checkpoint artifact: int8 weights and
  f32 scales restore with exact bytes and dtypes through an eval_shape
  template (the acceptance criterion's storage half)."""
  from repro.quant import QuantizedLinear, quantize_params
  mgr = CheckpointManager(str(tmp_path))
  k1, k2 = jax.random.split(jax.random.PRNGKey(3))
  tree = quantize_params({
      "fc": dense(k1, 32, 48, name="fc"),
      "lr": factored(k2, 32, 48, 16, name="lr"),
  })
  assert isinstance(tree["fc"], QuantizedLinear)
  mgr.save(11, tree, extra={"quantized": True})
  restored, extra = mgr.restore(jax.eval_shape(lambda: tree))
  assert extra["quantized"]
  for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
    assert a.dtype == b.dtype
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
  assert restored["fc"].w_q.dtype == jnp.int8
  # static metadata (name/group) comes from the template, not disk
  assert restored["lr"].name == "lr" and restored["lr"].is_factored


def test_unreferenced_checkpoint_leaves_warn(tmp_path):
  """A calibration-quantized checkpoint restored with an uncalibrated
  template must not drop the act_scale leaves silently — serving
  numerics would change with no signal."""
  from repro.quant import quantize_params
  mgr = CheckpointManager(str(tmp_path))
  params = {"fc": dense(jax.random.PRNGKey(4), 16, 24, name="fc")}
  calibrated = quantize_params(params, calib={"fc": 3.0})
  mgr.save(0, calibrated)
  uncalibrated = jax.eval_shape(lambda: quantize_params(params))
  with pytest.warns(UserWarning, match="act_scale"):
    restored, _ = mgr.restore(uncalibrated)
  assert restored["fc"].act_scale is None
  # the matching template stays warning-free
  import warnings as _w
  with _w.catch_warnings():
    _w.simplefilter("error")
    mgr.restore(jax.eval_shape(lambda: calibrated))


def test_shape_mismatch_rejected(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  mgr.save(0, {"x": jnp.zeros((4,))})
  with pytest.raises(ValueError):
    mgr.restore({"x": jnp.zeros((5,))})


def test_missing_leaf_rejected(tmp_path):
  mgr = CheckpointManager(str(tmp_path))
  mgr.save(0, {"x": jnp.zeros((4,))})
  with pytest.raises(KeyError):
    mgr.restore({"x": jnp.zeros((4,)), "y": jnp.zeros((1,))})
