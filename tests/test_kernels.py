"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rnd(seed, shape, scale=1.0, dtype=jnp.float32):
  x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
  return (x * scale).astype(dtype)


def tol(dtype):
  return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
      dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,m,r,n", [
    (1, 128, 128, 128), (4, 512, 128, 1024), (8, 1024, 256, 512),
    (16, 384, 128, 640), (3, 300, 130, 700),        # unaligned -> padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_gemm(b, m, r, n, dtype):
  x = rnd(b + m, (b, m), dtype=dtype)
  u = rnd(m + r, (m, r), 0.05, dtype)
  v = rnd(r + n, (r, n), 0.05, dtype)
  got = ops.lowrank_gemm(x, u, v, block_m=256, block_n=256)
  want = ref.lowrank_gemm(x, u, v)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,m,n", [
    (1, 128, 128), (2, 512, 1024), (8, 1024, 384), (16, 320, 6144),
])
def test_int8_gemm(b, m, n):
  x = rnd(b + m, (b, m))
  w = rnd(m + n, (m, n), 0.05)
  xq, xs = ref.quantize_rowwise(x)
  wq, ws = ref.quantize_colwise(w)
  got = ops.int8_gemm(xq, wq, xs, ws, block_m=256, block_n=256)
  want = ref.int8_gemm(xq, wq, xs, ws)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=1e-5, rtol=1e-5)
  # end-to-end quantized matmul approximates the f32 product
  approx = ops.quantized_matmul(x, w)
  dense = x @ w
  rel = float(jnp.linalg.norm(approx - dense) / jnp.linalg.norm(dense))
  assert rel < 0.05


@pytest.mark.parametrize("b", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("m,n", [(320, 6144), (1024, 1024), (640, 2048)])
def test_decode_matvec(b, m, n):
  """The paper's Fig. 6 regime: batch 1..16 against a big weight matrix."""
  x = rnd(b, (b, m))
  w = rnd(m + n, (m, n), 0.05)
  got = ops.decode_matvec(x, w, block_m=256, block_n=256)
  want = ref.decode_matvec(x, w)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,h", [(1, 128), (4, 256), (8, 512), (2, 1280)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gru_cell(b, h, dtype):
  xw = rnd(1, (b, 3 * h), dtype=dtype)
  hid = rnd(2, (b, h), dtype=dtype)
  u = rnd(3, (h, 3 * h), 0.05, dtype)
  bias = rnd(4, (3 * h,), 0.1)
  got = ops.gru_cell(xw, hid, u, bias, block_h=128)
  want = ref.gru_cell(xw, hid, u, bias)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,s,h,d", [
    (1, 256, 2, 128), (2, 512, 4, 128), (1, 1024, 1, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, s, h, d, causal):
  q = rnd(1, (b, s, h, d))
  k = rnd(2, (b, s, h, d))
  v = rnd(3, (b, s, h, d))
  got = ops.flash_attention(q, k, v, causal=causal, block_q=128,
                            block_k=128)
  want = ref.flash_attention(q, k, v, causal=causal)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=2e-4, rtol=2e-4)


def test_flash_matches_model_attention():
  """kernels/flash_attention vs the jnp blockwise path in layers/attention
  (the model's oracle) — same math, two implementations."""
  from repro.layers.attention import flash_attention as jnp_flash
  from repro.layers.common import ModelConfig
  cfg = ModelConfig(name="t", family="transformer", num_layers=1,
                    d_model=256, num_heads=2, num_kv_heads=2, d_ff=512,
                    vocab_size=64, attn_block_q=128, attn_block_kv=128)
  q = rnd(1, (2, 256, 2, 128))
  k = rnd(2, (2, 256, 2, 128))
  v = rnd(3, (2, 256, 2, 128))
  got = ops.flash_attention(q, k, v, block_q=128, block_k=128)
  want = jnp_flash(q, k, v, cfg)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=2e-4, rtol=2e-4)


def test_lowrank_vs_dense_weight_bytes():
  """The bandwidth argument (paper §4): factored streaming reads
  r(m+n) << mn bytes. Structural check on the kernel's working set."""
  m, n, r = 1280, 3840, 256
  dense_bytes = m * n
  factored_bytes = r * (m + n)
  assert factored_bytes < 0.3 * dense_bytes


# ---------------------------------------------------------------------------
# Parity grid: every Pallas kernel vs its ref oracle across one shared
# shape x dtype grid (interpret mode), including non-multiple-of-block
# edge shapes that exercise the pad/slice + block-halving paths.
# ---------------------------------------------------------------------------

# (b, m, n): aligned, rectangular, and deliberately awkward (odd dims,
# dims that halve below the block table, sub-SUBLANE batches)
PARITY_GRID = [
    (1, 128, 128),       # minimal aligned
    (4, 512, 1024),      # rectangular aligned
    (3, 300, 700),       # odd everything -> padding
    (7, 130, 258),       # barely past one lane
    (16, 384, 136),      # boundary batch, narrow odd output
]


@pytest.mark.parametrize("b,m,n", PARITY_GRID)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_grid_decode_matvec(b, m, n, dtype):
  x = rnd(b + m, (b, m), dtype=dtype)
  w = rnd(m + n, (m, n), 0.05, dtype)
  got = ops.decode_matvec(x, w)
  want = ref.decode_matvec(x, w)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,m,n", PARITY_GRID)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_grid_lowrank_gemm(b, m, n, dtype):
  r = max(128, min(m, n) // 2)
  x = rnd(b + m, (b, m), dtype=dtype)
  u = rnd(m + r, (m, r), 0.05, dtype)
  v = rnd(r + n, (r, n), 0.05, dtype)
  got = ops.lowrank_gemm(x, u, v)
  want = ref.lowrank_gemm(x, u, v)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("b,m,n", PARITY_GRID)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_grid_int8_gemm(b, m, n, dtype):
  """int8 operands carry no dtype, but the pre-quant input sweeps the
  same dtype grid (bf16 weights are what PTQ actually quantizes)."""
  x = rnd(b + m, (b, m), dtype=dtype)
  w = rnd(m + n, (m, n), 0.05, dtype)
  xq, xs = ref.quantize_rowwise(x)
  wq, ws = ref.quantize_colwise(w)
  got = ops.int8_gemm(xq, wq, xs, ws)
  want = ref.int8_gemm(xq, wq, xs, ws)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,h", [(1, 128), (3, 256), (16, 512), (5, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_parity_grid_gru_cell(b, h, dtype):
  xw = rnd(1 + h, (b, 3 * h), dtype=dtype)
  hid = rnd(2 + h, (b, h), dtype=dtype)
  u = rnd(3 + h, (h, 3 * h), 0.05, dtype)
  bias = rnd(4 + h, (3 * h,), 0.1)
  got = ops.gru_cell(xw, hid, u, bias)
  want = ref.gru_cell(xw, hid, u, bias)
  np.testing.assert_allclose(np.asarray(got, np.float32),
                             np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_quantization_error_bound(seed):
  """Symmetric per-channel int8: |w - deq(q(w))| <= scale/2 elementwise,
  and scale = col_amax/127 — the §4 quantization claim's error model."""
  w = rnd(seed, (64, 96), 0.3)
  q, s = ref.quantize_colwise(w)
  deq = q.astype(jnp.float32) * s[None, :]
  err = jnp.abs(w - deq)
  assert bool(jnp.all(err <= s[None, :] * 0.5 + 1e-7))
  amax = jnp.max(jnp.abs(w), axis=0)
  np.testing.assert_allclose(np.asarray(s), np.asarray(amax) / 127.0,
                             rtol=1e-5)
