"""Activation-calibrated low-rank truncation (LiteASR-style) tests.

The chain under test, end to end:

  dispatch.observe_gemm_moments + calibration_layer   (per-GEMM Grams,
      layer-tagged for scan-stacked leaves)
  -> quant.calibrate_activation_stats                 (assembled
      ActivationStats; (L, m, m) stacks for layered keys)
  -> svd.activation_split / truncate_leaf(cov=...)    (whitened SVD:
      rank and factors from output-reconstruction energy)
  -> compress.to_stage2(calib=...) + compression_report (wiring and the
      calibrated-vs-spectrum ledger)

plus `whisper.encode_unrolled`, the eager forward that makes the
encoder's scan-stacked GEMMs observable at all.

The load-bearing assertion throughout: under a correlated input
distribution, the calibrated split strictly beats the spectrum-only
split at EQUAL rank on weighted (output) reconstruction error — that
inequality is the whole point of calibrating.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compress, svd
from repro.core.factored import FactoredLinear
from repro.kernels import dispatch
from repro.quant import (ActivationStats, calibrate_activation_ranges,
                         calibrate_activation_stats)


def _correlated_cov(m, dim, seed=0):
  """E[x x^T] of x = z @ P + noise: energy concentrated in `dim` dirs."""
  rng = np.random.RandomState(seed)
  proj = rng.randn(dim, m)
  cov = proj.T @ proj + 0.01 * np.eye(m)
  return cov


def _weighted_err(w, u, v, cov):
  """E||x W - x U V||^2 = tr(D^T cov D), D = W - UV."""
  d = np.asarray(w, np.float64) - np.asarray(u, np.float64) @ np.asarray(
      v, np.float64)
  return float(np.trace(d.T @ cov @ d))


# ---------------------------------------------------------------------------
# the math: whitened SVD beats the weight spectrum under correlation
# ---------------------------------------------------------------------------


def test_activation_split_beats_spectrum_at_equal_rank():
  m, n, r = 48, 40, 8
  rng = np.random.RandomState(0)
  w = jnp.asarray(rng.randn(m, n).astype(np.float32))
  cov = _correlated_cov(m, dim=12)
  spec = svd.TruncationSpec(fixed_rank=r, round_to=1)
  u_c, v_c, svals = svd.activation_split(w, cov, spec)
  u_s, v_s = svd.balanced_split(w, r)
  err_c = _weighted_err(w, u_c, v_c, cov)
  err_s = _weighted_err(w, u_s, v_s, cov)
  assert err_c < err_s * 0.9          # strict, with margin
  assert u_c.shape == (m, r) and v_c.shape == (r, n)
  assert len(svals) == min(m, n) and np.all(np.diff(svals) <= 0)
  # optimality: err_c equals the tail energy of the whitened spectrum
  assert err_c == pytest.approx(float(np.sum(svals[r:] ** 2)), rel=1e-3)


def test_activation_split_identity_cov_is_plain_svd():
  """White inputs carry no information: the calibrated split must then
  reproduce the spectrum-only product (same subspace, same error)."""
  m, n, r = 32, 24, 6
  rng = np.random.RandomState(1)
  w = jnp.asarray(rng.randn(m, n).astype(np.float32))
  spec = svd.TruncationSpec(fixed_rank=r, round_to=1)
  u_c, v_c, _ = svd.activation_split(w, np.eye(m), spec)
  u_s, v_s = svd.balanced_split(w, r)
  np.testing.assert_allclose(np.asarray(u_c @ v_c), np.asarray(u_s @ v_s),
                             atol=1e-4)


def test_truncate_leaf_calibrated_2d_and_rank_from_whitened_spectrum():
  m, n = 64, 48
  rng = np.random.RandomState(2)
  # weight energy spread; input energy concentrated -> the whitened
  # spectrum decays much faster than the weight spectrum, so the
  # variance rule must pick a SMALLER rank when calibrated
  w = jnp.asarray(rng.randn(m, n).astype(np.float32))
  cov = _correlated_cov(m, dim=4, seed=2)
  leaf = FactoredLinear(w=w, u=None, v=None, name="fc")
  spec = svd.TruncationSpec(variance_threshold=0.9, round_to=1)
  cal = svd.truncate_leaf(leaf, spec, cov=cov)
  plain = svd.truncate_leaf(leaf, spec)
  assert cal.is_factored and plain.is_factored
  assert cal.rank < plain.rank
  assert cal.name == "fc" and cal.group == leaf.group


def test_truncate_leaf_stacked_per_layer_cov():
  L, m, n, r = 3, 32, 24, 5
  rng = np.random.RandomState(3)
  w = jnp.asarray(rng.randn(L, m, n).astype(np.float32))
  covs = np.stack([_correlated_cov(m, dim=6, seed=10 + i)
                   for i in range(L)])
  leaf = FactoredLinear(w=w, u=None, v=None, name="enc/fc")
  spec = svd.TruncationSpec(fixed_rank=r, round_to=1)
  cal = svd.truncate_leaf(leaf, spec, cov=covs)
  plain = svd.truncate_leaf(leaf, spec)
  assert cal.u.shape == (L, m, r) and cal.v.shape == (L, r, n)
  for i in range(L):      # every layer whitened with ITS OWN Gram
    err_c = _weighted_err(w[i], cal.u[i], cal.v[i], covs[i])
    err_s = _weighted_err(w[i], plain.u[i], plain.v[i], covs[i])
    assert err_c < err_s, f"layer {i}"
  # an (m, m) Gram broadcasts over the stack
  b = svd.truncate_leaf(leaf, spec, cov=covs[0])
  assert b.u.shape == (L, m, r)
  # a layer-count mismatch is a hard error, not a silent broadcast
  with pytest.raises(ValueError, match="calibration_layer"):
    svd.truncate_leaf(leaf, spec, cov=covs[:2])


# ---------------------------------------------------------------------------
# the observers: Gram collection + layer tagging
# ---------------------------------------------------------------------------


def _gemm_leaf(m, n, name, seed):
  rng = np.random.RandomState(seed)
  return FactoredLinear(w=jnp.asarray(rng.randn(m, n).astype(np.float32)),
                        u=None, v=None, name=name)


def test_observe_gemm_moments_accumulates_grams():
  leaf = _gemm_leaf(8, 4, "fc", 4)
  rng = np.random.RandomState(5)
  xs = [rng.randn(3, 8).astype(np.float32) for _ in range(2)]
  with dispatch.observe_gemm_moments() as log:
    for x in xs:
      dispatch.gemm(leaf, jnp.asarray(x), dispatch.JNP_ONLY)
  rows = np.concatenate(xs).astype(np.float64)
  assert set(log) == {"fc"}
  np.testing.assert_allclose(log["fc"]["xtx"], rows.T @ rows, rtol=1e-6)
  assert log["fc"]["count"] == 6
  assert log["fc"]["amax"] == pytest.approx(np.abs(rows).max(), rel=1e-5)


def test_calibration_layer_tags_and_stats_assembly():
  leaf = _gemm_leaf(8, 4, "blk/fc", 6)
  rng = np.random.RandomState(7)
  xs = [rng.randn(2, 8).astype(np.float32) for _ in range(2)]

  def apply_fn(_):
    for i, x in enumerate(xs):
      with dispatch.calibration_layer(i):
        dispatch.gemm(leaf, jnp.asarray(x), dispatch.JNP_ONLY)

  stats = calibrate_activation_stats(apply_fn, [None])
  assert set(stats) == {"blk/fc"}
  st = stats["blk/fc"]
  assert isinstance(st, ActivationStats)
  assert st.second_moment.shape == (2, 8, 8)       # stacked per layer
  for i, x in enumerate(xs):
    r = x.astype(np.float64)
    np.testing.assert_allclose(st.second_moment[i], r.T @ r / 2, rtol=1e-6)
  assert st.count == 4


def test_calibrate_activation_stats_rejects_layer_gaps():
  leaf = _gemm_leaf(4, 4, "blk/fc", 8)

  def apply_fn(_):
    for i in (0, 2):                               # layer 1 never ran
      with dispatch.calibration_layer(i):
        dispatch.gemm(leaf, jnp.ones((1, 4), jnp.float32),
                      dispatch.JNP_ONLY)

  with pytest.raises(RuntimeError, match="contiguous"):
    calibrate_activation_stats(apply_fn, [None])


def test_activation_ranges_fold_layer_keys():
  """PTQ's amax calibration stays layer-agnostic: "name@L{i}" entries
  fold into the base name by max, and the base name is what
  quantize_params looks up."""
  leaf = _gemm_leaf(4, 4, "blk/fc", 9)

  def apply_fn(_):
    for i, scale in enumerate((1.0, 3.0)):
      with dispatch.calibration_layer(i):
        dispatch.gemm(leaf, scale * jnp.ones((1, 4), jnp.float32),
                      dispatch.JNP_ONLY)

  log = calibrate_activation_ranges(apply_fn, [None])
  assert log["blk/fc"] == pytest.approx(3.0)
  assert log["blk/fc@L0"] == pytest.approx(1.0)
  assert log["blk/fc@L1"] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# whisper: the eager unrolled encoder that makes calibration possible
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_whisper_encode_unrolled_matches_encode_and_calibrates():
  from repro.models import whisper
  cfg = dataclasses.replace(configs.get_smoke("whisper-small"),
                            dtype=jnp.float32)
  params = whisper.init_model(jax.random.PRNGKey(0), cfg)
  rng = np.random.RandomState(0)
  frames = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))

  ref = whisper.encode(params, frames, cfg)
  unrolled = whisper.encode_unrolled(params, frames, cfg)
  np.testing.assert_allclose(np.asarray(unrolled), np.asarray(ref),
                             atol=2e-4, rtol=1e-4)

  stats = calibrate_activation_stats(
      lambda b: whisper.encode_unrolled(params, b, cfg,
                                        policy=dispatch.JNP_ONLY),
      [frames])
  n_layers = jax.tree.leaves(params["enc_layers"])[0].shape[0]
  assert {"enc/attn_q", "enc/attn_o", "enc/ffn_in",
          "enc/ffn_out"} <= set(stats)
  for name in ("enc/attn_q", "enc/ffn_in"):
    assert stats[name].second_moment.shape[0] == n_layers

  # the assembled stats drive the stacked truncation directly
  plan = compress.FactorizationPlan(
      include=("enc/*",), min_dim=1,
      truncation=svd.TruncationSpec(fixed_rank=8, round_to=1))
  trunc = compress.to_stage2(params, plan, calib=stats)
  leaf = {l.name: l for l in compress.iter_factored_leaves(trunc)}
  assert leaf["enc/attn_q"].is_factored
  assert leaf["enc/attn_q"].u.shape[0] == n_layers


# ---------------------------------------------------------------------------
# the driver: to_stage2 wiring + the ledger's calibrated column
# ---------------------------------------------------------------------------


def test_to_stage2_calib_and_compression_report():
  rng = np.random.RandomState(10)
  params = {
      "a": FactoredLinear(w=jnp.asarray(rng.randn(64, 48), jnp.float32),
                          u=None, v=None, name="fc"),
      "b": FactoredLinear(w=jnp.asarray(rng.randn(64, 48), jnp.float32),
                          u=None, v=None, name="out"),
  }
  cov = _correlated_cov(64, dim=8, seed=11)
  calib = {"fc": ActivationStats(second_moment=cov, count=32,
                                 amax=float(np.abs(cov).max()))}
  plan = compress.FactorizationPlan(
      min_dim=1, truncation=svd.TruncationSpec(fixed_rank=8, round_to=1))
  after = compress.to_stage2(params, plan, calib=calib)
  assert after["a"].is_factored and after["b"].is_factored
  # "fc" got the whitened split, "out" the plain spectrum: their u
  # factors came from different programs
  err_cal = _weighted_err(params["a"].w, after["a"].u, after["a"].v, cov)
  u_s, v_s = svd.balanced_split(params["a"].w, 8)
  assert err_cal < _weighted_err(params["a"].w, u_s, v_s, cov)

  report = compress.compression_report(params, after, calib=calib)
  by_name = {r["name"]: r for r in report["gemms"]}
  assert by_name["fc"]["calibrated"] is True
  assert by_name["out"]["calibrated"] is False
  assert report["calibrated_gemms"] == ["fc"]
  assert report["total_params_after"] < report["total_params_before"]
  # and without calib the column reads uncalibrated everywhere
  plain = compress.compression_report(params, after)
  assert all(not r["calibrated"] for r in plain["gemms"])
  assert plain["calibrated_gemms"] == []
