"""Data pipelines: determinism, learnable structure, CER metric."""
import numpy as np

from repro.data.lm import LMDataConfig, batch_at
from repro.data.speech import (SpeechDataConfig, batch_at as speech_at, cer,
                               edit_distance)


def test_lm_batches_deterministic():
  cfg = LMDataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=7)
  a = batch_at(cfg, 5)
  b = batch_at(cfg, 5)
  np.testing.assert_array_equal(a["tokens"], b["tokens"])
  c = batch_at(cfg, 6)
  assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_has_bigram_structure():
  cfg = LMDataConfig(vocab_size=16, seq_len=256, global_batch=8,
                     structure=0.9)
  b = batch_at(cfg, 0)
  toks, tgts = b["tokens"], b["targets"]
  # the modal successor of each token should be hit ~90% of the time
  hits = 0
  total = 0
  succ = {}
  for t, n in zip(toks.ravel(), tgts.ravel()):
    succ.setdefault(t, []).append(n)
  for t, ns in succ.items():
    vals, counts = np.unique(ns, return_counts=True)
    hits += counts.max()
    total += counts.sum()
  assert hits / total > 0.7


def test_speech_batches_deterministic_and_valid():
  cfg = SpeechDataConfig(global_batch=4, seed=3)
  a = speech_at(cfg, 2)
  b = speech_at(cfg, 2)
  np.testing.assert_array_equal(a["feats"], b["feats"])
  assert (a["label_lengths"] >= cfg.min_label_len).all()
  assert (a["feat_lengths"] <= cfg.max_frames).all()
  # labels never use the blank id 0
  for i in range(4):
    lab = a["labels"][i][:a["label_lengths"][i]]
    assert (lab > 0).all()


def test_edit_distance():
  assert edit_distance(np.array([1, 2, 3]), np.array([1, 2, 3])) == 0
  assert edit_distance(np.array([1, 2, 3]), np.array([1, 3])) == 1
  assert edit_distance(np.array([]), np.array([1, 2])) == 2
  assert edit_distance(np.array([1, 2]), np.array([2, 1])) == 2


def test_cer_perfect_and_empty():
  labels = np.array([[1, 2, 3, 0]])
  lens = np.array([3])
  perfect = np.array([[1, 2, 3, -1]])
  assert cer(perfect, labels, lens) == 0.0
  empty = np.full((1, 4), -1)
  assert cer(empty, labels, lens) == 1.0
