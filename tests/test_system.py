"""End-to-end system test: the paper's full pipeline in miniature.

Trains the reduced DS2 model on the synthetic speech task with the
two-stage trace-norm recipe and checks (a) CTC loss falls substantially,
(b) greedy-decode CER improves over the untrained model, (c) the stage-2
model is smaller, (d) trace-norm diagnostics are well-formed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.compress import FactorizationPlan
from repro.core.factored import count_params
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data.speech import SpeechDataConfig, batch_at, cer
from repro.models import deepspeech
from repro.models.ctc import ctc_greedy_decode
from repro.training import TrainConfig, Trainer


def _eval_cer(trainer, cfg, dc, step=999):
  batch = batch_at(dc, step)
  log_probs = deepspeech.forward(trainer.params,
                                 jnp.asarray(batch["feats"]), cfg)
  out_lens = deepspeech.output_lengths(
      jnp.asarray(batch["feat_lengths"]), cfg)
  decoded = np.asarray(ctc_greedy_decode(log_probs, out_lens))
  return cer(decoded, batch["labels"], batch["label_lengths"])


@pytest.mark.slow
def test_speech_two_stage_end_to_end():
  cfg = configs.get_smoke("deepspeech2-wsj").with_(dtype=jnp.float32)
  dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                        global_batch=8, max_label_len=12, noise=0.2)
  sched = TwoStageSchedule(
      total_steps=200, transition_step=120,
      regularizer=RegularizerConfig(kind="trace", lambda_rec=3e-5,
                                    lambda_nonrec=3e-5),
      truncation=TruncationSpec(variance_threshold=0.95, round_to=8))
  plan = FactorizationPlan(min_dim=48)
  trainer = Trainer(cfg, TrainConfig(lr=1e-3), schedule=sched, plan=plan)

  cer_before = _eval_cer(trainer, cfg, dc)
  first_loss = trainer.train_step(batch_at(dc, 0))["loss"]
  p_stage1 = count_params(trainer.params)
  for i in range(1, 200):
    m = trainer.train_step(batch_at(dc, i))
  assert trainer.stage == 2
  p_stage2 = count_params(trainer.params)

  # ~40 s on CPU: loss 42 -> ~1, CER 0.97 -> ~0.06 on held-out batches
  assert m["loss"] < first_loss * 0.2, (first_loss, m["loss"])
  cer_after = _eval_cer(trainer, cfg, dc)
  assert cer_after < 0.3 < cer_before, (cer_before, cer_after)
  assert p_stage2 < p_stage1

  report = trainer.tracenorm_report()
  assert len(report) >= 4           # per factored GEMM
  for name, r in report.items():
    assert 0.0 <= r["nu"] <= 1.0
    assert r["rank90"] >= 1
