"""Property tests for the paper's core math (Definition 1, Lemma 1)."""
import pytest

# hypothesis is not part of the runtime image; CI installs it, local runs skip
hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svd as svd_lib
from repro.core.factored import FactoredLinear, dense, factored
from repro.core.tracenorm import (RegularizerConfig, nu_coefficient,
                                  rank_for_variance, regularization_loss,
                                  singular_values,
                                  variational_trace_norm_penalty)

matrices = hnp.arrays(
    np.float64, hnp.array_shapes(min_dims=2, max_dims=2, min_side=2,
                                 max_side=24),
    elements=st.floats(-10, 10, allow_nan=False))


def _nonzero(w):
  return np.linalg.norm(w) > 1e-6


@hypothesis.given(matrices, st.floats(0.1, 100.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_nu_scale_invariant(w, c):
  hypothesis.assume(_nonzero(w))
  n1 = float(nu_coefficient(jnp.asarray(w)))
  n2 = float(nu_coefficient(jnp.asarray(c * w)))
  assert abs(n1 - n2) < 1e-3


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=50, deadline=None)
def test_nu_in_unit_interval(w):
  hypothesis.assume(_nonzero(w))
  nu = float(nu_coefficient(jnp.asarray(w)))
  assert -1e-5 <= nu <= 1.0 + 1e-5


def test_nu_rank_one_is_zero():
  u = np.random.RandomState(0).randn(8, 1)
  v = np.random.RandomState(1).randn(1, 12)
  assert float(nu_coefficient(jnp.asarray(u @ v))) < 1e-5


def test_nu_orthogonal_is_one():
  # equal singular values at max rank -> nu = 1 (paper Prop. 1 iv)
  q, _ = np.linalg.qr(np.random.RandomState(0).randn(8, 8))
  assert abs(float(nu_coefficient(jnp.asarray(q))) - 1.0) < 1e-5


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=30, deadline=None)
def test_variational_penalty_upper_bounds_trace_norm(w):
  """Lemma 1: ||W||_T = min over W=UV of (|U|_F^2+|V|_F^2)/2; any balanced
  SVD split attains it, any other factorization is >=."""
  hypothesis.assume(_nonzero(w))
  w = jnp.asarray(w, jnp.float32)
  trace_norm = float(jnp.sum(singular_values(w)))
  u, v = svd_lib.balanced_split(w)
  attained = float(variational_trace_norm_penalty(u, v))
  assert attained <= trace_norm * 1.01 + 1e-4
  assert attained >= trace_norm * 0.99 - 1e-4
  # a perturbed (unbalanced) factorization can only increase the penalty
  u2 = u * 2.0
  v2 = v / 2.0
  assert float(variational_trace_norm_penalty(u2, v2)) >= attained - 1e-5


@hypothesis.given(st.integers(2, 16), st.floats(0.1, 0.99),
                  st.booleans())
@hypothesis.settings(max_examples=30, deadline=None)
def test_rank_for_variance_monotone(d, thresh, degenerate):
  # degenerate=True exercises the all-zero singular-value vector (a zero
  # matrix): rank must clamp into [1, d], not report d + 1
  sigma = (jnp.zeros((d,)) if degenerate else
           jnp.sort(jnp.abs(jax.random.normal(
               jax.random.PRNGKey(d), (d,))))[::-1])
  r = int(rank_for_variance(sigma, thresh))
  assert 1 <= r <= d
  r2 = int(rank_for_variance(sigma, min(thresh + 0.009, 0.999)))
  assert r2 >= r


def test_regularization_loss_groups():
  """lambda_rec applies to 'rec' GEMMs, lambda_nonrec to the rest."""
  k = jax.random.PRNGKey(0)
  tree = {
      "a": factored(k, 16, 16, name="gru/rec", group="rec"),
      "b": factored(k, 16, 16, name="gru/nonrec", group="nonrec"),
  }
  only_rec = regularization_loss(tree, RegularizerConfig(
      kind="trace", lambda_rec=1.0, lambda_nonrec=0.0))
  only_non = regularization_loss(tree, RegularizerConfig(
      kind="trace", lambda_rec=0.0, lambda_nonrec=1.0))
  pen_a = variational_trace_norm_penalty(tree["a"].u, tree["a"].v)
  pen_b = variational_trace_norm_penalty(tree["b"].u, tree["b"].v)
  np.testing.assert_allclose(float(only_rec), float(pen_a), rtol=1e-6)
  np.testing.assert_allclose(float(only_non), float(pen_b), rtol=1e-6)


def test_trace_penalty_shrinks_nu_vs_l2_baseline():
  """Trace-norm training (factored + Frobenius penalties, paper eq. 3)
  reaches a lower nondimensional trace norm nu than the paper's baseline:
  l2 regularization of the UNfactored weight (Fig. 2 mechanism). Note l2
  on the factors would be the *same* penalty as trace norm by Lemma 1 —
  the baseline must be unfactored."""
  key = jax.random.PRNGKey(3)
  w_true = (jax.random.normal(key, (12, 2)) @
            jax.random.normal(key, (2, 12)))          # rank-2 target
  x = jax.random.normal(jax.random.PRNGKey(1), (64, 12))
  y = x @ w_true

  def run_trace():
    leaf = factored(jax.random.PRNGKey(2), 12, 12, name="w")
    cfg = RegularizerConfig(kind="trace", lambda_nonrec=2e-3)
    def loss(l):
      pred = x @ (l.u @ l.v)
      return jnp.mean((pred - y) ** 2) + regularization_loss({"w": l}, cfg)
    for _ in range(400):
      g = jax.grad(loss)(leaf)
      leaf = FactoredLinear(w=None, u=leaf.u - 0.05 * g.u,
                            v=leaf.v - 0.05 * g.v, name="w")
    return float(nu_coefficient(leaf.u @ leaf.v))

  def run_l2_unfactored():
    leaf = dense(jax.random.PRNGKey(2), 12, 12, name="w")
    cfg = RegularizerConfig(kind="l2", lambda_nonrec=2e-3)
    def loss(l):
      pred = x @ l.w
      return jnp.mean((pred - y) ** 2) + regularization_loss({"w": l}, cfg)
    for _ in range(400):
      g = jax.grad(loss)(leaf)
      leaf = FactoredLinear(w=leaf.w - 0.05 * g.w, u=None, v=None, name="w")
    return float(nu_coefficient(leaf.w))

  assert run_trace() < run_l2_unfactored()
