"""MoE: grouped dispatch equivalence, capacity semantics, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers.common import MoEConfig, ModelConfig
from repro.layers.moe import init_moe, moe_forward


def make_cfg(groups=1, experts=8, top_k=2, cap=8.0):
  return ModelConfig(
      name="m", family="transformer", num_layers=1, d_model=32,
      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
      dtype=jnp.float32,
      moe=MoEConfig(num_experts=experts, num_shared=1, top_k=top_k,
                    d_expert=16, capacity_factor=cap,
                    dispatch_groups=groups))


def test_grouped_dispatch_matches_global():
  """With ample capacity, G=2 grouped dispatch == G=1 global dispatch
  (the routing is per-token; only the scatter layout differs)."""
  cfg1, cfg2 = make_cfg(1), make_cfg(2)
  p = init_moe(jax.random.PRNGKey(0), cfg1, layer_prefix="l")
  x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
  y1, a1 = moe_forward(p, x, cfg1)
  y2, a2 = moe_forward(p, x, cfg2)
  np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_capacity_drops_tokens():
  """Tiny capacity drops tokens -> output differs from ample capacity."""
  cfg_small = make_cfg(cap=0.05)
  cfg_big = make_cfg(cap=8.0)
  p = init_moe(jax.random.PRNGKey(0), cfg_big, layer_prefix="l")
  x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
  y_small, _ = moe_forward(p, x, cfg_small)
  y_big, _ = moe_forward(p, x, cfg_big)
  assert float(jnp.max(jnp.abs(y_small - y_big))) > 1e-4


def test_aux_loss_balanced_router():
  """A uniform router gives aux ~ 1 (the switch-loss optimum)."""
  cfg = make_cfg(experts=4, top_k=1)
  p = init_moe(jax.random.PRNGKey(0), cfg, layer_prefix="l")
  p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform probs
  x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
  _, aux = moe_forward(p, x, cfg)
  # f_e from argmax of uniform logits is degenerate (all ties -> expert 0),
  # so just check finiteness and scale
  assert np.isfinite(float(aux))


def test_moe_grads_flow_to_experts():
  cfg = make_cfg()
  p = init_moe(jax.random.PRNGKey(0), cfg, layer_prefix="l")
  x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
  def loss(p):
    y, aux = moe_forward(p, x, cfg)
    return jnp.sum(y ** 2) + 0.01 * aux
  g = jax.grad(loss)(p)
  gw = g["w_gate"].w if hasattr(g["w_gate"], "w") else g["w_gate"]
  assert float(jnp.sum(jnp.abs(gw))) > 0
  assert float(jnp.sum(jnp.abs(g["router"]))) > 0
