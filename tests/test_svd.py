"""Truncated-SVD warmstart (stage 1 -> 2) correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svd as svd_lib
from repro.core.compress import (FactorizationPlan, compression_report,
                                 to_stage1, to_stage2)
from repro.core.factored import FactoredLinear, count_params, dense
from repro.core.svd import TruncationSpec


def test_balanced_split_reconstructs():
  w = jax.random.normal(jax.random.PRNGKey(0), (24, 16))
  u, v = svd_lib.balanced_split(w)
  np.testing.assert_allclose(np.asarray(u @ v), np.asarray(w), atol=1e-4)
  # balance: ||u||_F^2 == ||v||_F^2 (Lemma 1 equality choice)
  np.testing.assert_allclose(float(jnp.sum(u * u)), float(jnp.sum(v * v)),
                             rtol=1e-4)


def test_truncation_preserves_low_rank_exactly():
  """A rank-r matrix survives truncation at any threshold losslessly."""
  k = jax.random.PRNGKey(1)
  w = (jax.random.normal(k, (32, 4)) @ jax.random.normal(k, (4, 32)))
  leaf = FactoredLinear(w=w, u=None, v=None, name="t")
  out = svd_lib.truncate_leaf(leaf, TruncationSpec(variance_threshold=0.999,
                                                   round_to=1))
  assert out.rank <= 8     # 4 rounded up at most
  np.testing.assert_allclose(np.asarray(out.product()), np.asarray(w),
                             atol=1e-3)


def test_explained_variance_rank():
  s = np.array([10.0, 1.0, 0.1, 0.01])
  var = s ** 2 / np.sum(s ** 2)
  assert svd_lib.explained_variance_rank(s, 0.98) == 1
  assert svd_lib.explained_variance_rank(s, 0.999) == 2
  assert svd_lib.explained_variance_rank(s, 1.0) == 4


def test_rank_for_variance_degenerate_matrix():
  """All-zero singular values (a zero matrix) must report a rank in
  [1, d] — regression: the 1e-30 guard made every cumulative fraction
  fall below the threshold, returning d + 1."""
  from repro.core.tracenorm import rank_for_variance
  for d in (1, 2, 7):
    sigma = jnp.zeros((d,))
    r = int(rank_for_variance(sigma, 0.9))
    assert 1 <= r <= d
  # near-zero but nonzero stays exact: one singular value explains all
  assert int(rank_for_variance(jnp.array([1e-20, 0.0]), 0.9)) <= 2


def test_stage1_stage2_param_counts():
  k = jax.random.PRNGKey(2)
  tree = {"fc": dense(k, 64, 64, name="fc"),
          "small": dense(k, 8, 8, name="small")}
  plan = FactorizationPlan(min_dim=32, truncation=TruncationSpec(
      fixed_rank=4, round_to=4))
  s1 = to_stage1(tree, plan)
  assert s1["fc"].is_factored and not s1["small"].is_factored
  assert s1["fc"].rank == 64                     # full-rank stage-1 form
  s2 = to_stage2(s1, plan)
  assert s2["fc"].rank == 4
  assert count_params(s2) < count_params(tree)
  rep = compression_report(tree, s2)
  assert rep["total_params_after"] < rep["total_params_before"]


def test_stacked_leaf_truncation():
  """Scanned (L, m, n) weights truncate to one homogeneous rank."""
  k = jax.random.PRNGKey(3)
  w = jax.random.normal(k, (3, 16, 16)) * 0.1
  leaf = FactoredLinear(w=w, u=None, v=None, name="stack")
  out = svd_lib.truncate_leaf(leaf, TruncationSpec(variance_threshold=0.9,
                                                   round_to=2))
  assert out.u.shape[0] == 3 and out.v.shape[0] == 3
  assert out.u.shape[-1] == out.v.shape[-2]


def test_factorize_collapse_roundtrip():
  k = jax.random.PRNGKey(4)
  tree = {"w": dense(k, 20, 12, name="w")}
  s1 = svd_lib.factorize_tree(tree)
  back = svd_lib.collapse_tree(s1)
  np.testing.assert_allclose(np.asarray(back["w"].w),
                             np.asarray(tree["w"].w), atol=1e-4)
