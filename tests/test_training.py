"""Trainer: loss decreases, two-stage transition, microbatching, resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.compress import FactorizationPlan
from repro.core.factored import count_params
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data.lm import LMDataConfig, batch_at
from repro.training import TrainConfig, Trainer


def _cfg():
  return configs.get_smoke("llama3-8b").with_(vocab_size=64,
                                              dtype=jnp.float32)


def _dc():
  return LMDataConfig(vocab_size=64, seq_len=32, global_batch=8)


def test_loss_decreases():
  trainer = Trainer(_cfg(), TrainConfig(lr=2e-3))
  dc = _dc()
  first = trainer.train_step(batch_at(dc, 0))["loss"]
  for i in range(1, 25):
    last = trainer.train_step(batch_at(dc, i))["loss"]
  assert last < first - 0.3, (first, last)


def test_microbatching_matches_full_batch():
  """k microbatches average to the same gradient as the full batch."""
  cfg = _cfg()
  dc = _dc()
  t1 = Trainer(cfg, TrainConfig(lr=1e-3, microbatches=1))
  t4 = Trainer(cfg, TrainConfig(lr=1e-3, microbatches=4))
  b = batch_at(dc, 0)
  m1 = t1.train_step(b)
  m4 = t4.train_step(b)
  np.testing.assert_allclose(m1["loss"], m4["loss"], rtol=1e-4)
  for a, c in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t4.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-4)


def test_two_stage_transition_shrinks_and_trains():
  sched = TwoStageSchedule(
      total_steps=12, transition_step=6,
      regularizer=RegularizerConfig(kind="trace", lambda_rec=1e-4,
                                    lambda_nonrec=1e-4),
      truncation=TruncationSpec(variance_threshold=0.85, round_to=4))
  plan = FactorizationPlan(min_dim=64)
  trainer = Trainer(_cfg(), TrainConfig(lr=1e-3), schedule=sched, plan=plan)
  dc = _dc()
  p_before = count_params(trainer.params)
  for i in range(8):
    m = trainer.train_step(batch_at(dc, i))
  assert trainer.stage == 2
  assert count_params(trainer.params) < p_before
  assert np.isfinite(m["loss"])


def test_checkpoint_resume(tmp_path):
  tcfg = TrainConfig(lr=1e-3, checkpoint_dir=str(tmp_path),
                     checkpoint_every=3, async_checkpoint=False)
  dc = _dc()
  t1 = Trainer(_cfg(), tcfg)
  for i in range(6):
    t1.train_step(batch_at(dc, i))
  # fresh trainer restores step 6 and continues identically
  t2 = Trainer(_cfg(), tcfg)
  t2.restore()
  assert t2.step == 6
  m1 = t1.train_step(batch_at(dc, 6))
  m2 = t2.train_step(batch_at(dc, 6))
  np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=1e-5)


def test_l2_baseline_runs():
  """The paper's l2-regularized unfactored baseline trains too."""
  trainer = Trainer(_cfg(), TrainConfig(
      lr=1e-3, regularizer=RegularizerConfig(kind="l2", lambda_rec=1e-4,
                                             lambda_nonrec=1e-4)))
  m = trainer.train_step(batch_at(_dc(), 0))
  assert "reg" in m and m["reg"] > 0
