"""repro.analysis: the static hot-path auditor.

Seeded-violation tests prove each check actually fires (an auditor that
never fails is decoration); green-path tests prove the real serving
programs audit clean against the committed baseline; plus the satellite
surfaces this PR hardened — dispatch recorder reentrancy, the eager-only
calibration contract, hlo_cost's unknown-op accounting.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.analysis as analysis
from repro.analysis import checks, lifecycle, report, targets
from repro.dist import hlo_cost
from repro.kernels import dispatch
from repro.quant.ptq import calibrate_activation_ranges

KEY = jax.random.PRNGKey(0)


def _target(fn, args, *, n_params, int8_idx=frozenset(), quant="float",
            policy="jnp", program="decode", lower=False):
  """Hand-built TraceTarget over an arbitrary function (seeded programs)."""
  with dispatch.record_dispatch() as log:
    closed = jax.make_jaxpr(fn)(*args)
  low = jax.jit(fn).lower(*args).as_text() if lower else None
  return targets.TraceTarget(
      config="seeded", family="test", policy=policy, quant=quant,
      program=program, jaxpr=closed, dispatch_log=list(log),
      n_params=n_params, int8_param_idx=int8_idx, n_donated=0,
      lowered_text=low, compiled_text=None)


# ---------------------------------------------------------------------------
# Seeded violations: every check must fire on a program built to violate it.
# ---------------------------------------------------------------------------


def test_unrouted_param_gemm_is_flagged():
  w = jnp.zeros((16, 32))
  x = jnp.zeros((4, 16))
  t = _target(lambda w, x: x @ w, (w, x), n_params=1)
  findings, _ = checks.run_target_checks(t)
  assert [f.check for f in findings] == ["dispatch_coverage"]
  assert findings[0].key.startswith("unrouted:")
  # activation x activation contractions are intrinsic math, not GEMMs
  t2 = _target(lambda w, x: x @ x.T, (w, x), n_params=1)
  assert checks.run_target_checks(t2)[0] == []


def test_routed_gemm_via_dispatch_is_clean():
  from repro.core.factored import dense
  from repro.layers.common import gemm
  leaf = dense(KEY, 128, 256, name="fc")
  x = jnp.zeros((4, 128))
  # jnp regime: the dot_general itself sits under the dispatch scope
  t = _target(lambda lf, x: gemm(lf, x, dispatch.JNP_ONLY), (leaf, x),
              n_params=2)
  findings, info = checks.run_target_checks(t)
  assert findings == []
  assert info["n_dots_scoped"] == 1
  assert info["regimes"] == ["jnp"]
  # pallas regime: the GEMM becomes a pallas_call (no dot at jaxpr
  # level) — still clean, still recorded
  t2 = _target(lambda lf, x: gemm(lf, x, dispatch.decode_policy(8)),
               (leaf, x), n_params=2)
  findings2, info2 = checks.run_target_checks(t2)
  assert findings2 == []
  assert info2["n_dispatch_records"] >= 1


def test_dequantize_of_int8_weight_is_flagged():
  w8 = jnp.zeros((16, 32), jnp.int8)
  x = jnp.zeros((4, 16))
  t = _target(lambda w, x: x @ w.astype(jnp.float32), (w8, x),
              n_params=1, int8_idx=frozenset({0}), quant="int8")
  findings, _ = checks.run_target_checks(t)
  assert any(f.check == "quant_integrity" and
             f.key.startswith("dequantize:") for f in findings)
  # int8 -> int32 accumulation is the legitimate widening, not a dequant
  t2 = _target(lambda w, x: w.astype(jnp.int32).sum(), (w8, x),
               n_params=1, int8_idx=frozenset({0}), quant="int8")
  assert not any(f.check == "quant_integrity"
                 for f in checks.run_target_checks(t2)[0])


def test_host_callback_is_flagged():
  def fn(x):
    y = jax.pure_callback(lambda a: np.asarray(a),
                          jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    return y + 1.0
  t = _target(fn, (jnp.zeros((4,)),), n_params=0)
  findings, _ = checks.run_target_checks(t)
  assert any(f.check == "transfer_lint" and "pure_callback" in f.key
             for f in findings)


def test_dropped_donation_is_flagged():
  t = _target(lambda s: s + 1.0, (jnp.zeros((4,)),), n_params=0,
              lower=True)
  t.n_donated = 3          # claim 3 donated leaves; none alias
  findings, _ = checks.run_target_checks(t)
  assert any(f.key.startswith("donation-dropped:") for f in findings)


def test_retrace_instability_is_observable():
  """A shape that escapes bucketing shows up in compile_stats — the
  exact signal the lifecycle check gates on."""
  cfg = analysis.configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  from repro.models.api import get_model
  from repro.serving.engine import LMEngine
  params = get_model(cfg).init(KEY, cfg)
  eng = LMEngine(cfg, params, batch_size=2, max_len=16)
  eng.generate(np.array([[1, 2], [3, 4]]), steps=2)
  stats = eng.compile_stats()
  if stats["step"] < 0:
    pytest.skip("runtime does not expose jit cache sizes")
  assert stats["step"] == 1
  # seed the violation: feed the donated step a rogue batch-3 signature
  rogue = eng._init_state(3)
  eng._step(params, rogue, jnp.zeros((3, 1), jnp.int32),
            jnp.zeros((3,), jnp.int32))
  assert eng.compile_stats()["step"] == 2


# ---------------------------------------------------------------------------
# Green path: the real serving programs audit clean against the baseline.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["jnp", "pallas"])
@pytest.mark.parametrize("config", ["qwen3-4b", "zamba2-7b"])
def test_audit_green_against_baseline(config, policy):
  rep = analysis.run_audit([config], [policy],
                           run_lifecycle=False, run_sharding=False)
  rep.apply_baseline(analysis.load_baseline())
  assert rep.ok, "\n" + rep.summary()
  # the grid actually covered scoped GEMMs, not a vacuous pass
  decode = [t for t in rep.targets if t["program"] == "decode"]
  assert decode and all(t["n_dots_scoped"] > 0 for t in decode)
  assert any(t["quant"] == "int8" for t in decode)


def test_lifecycle_check_green():
  findings, infos = lifecycle.check_retrace_stability(["qwen3-4b"],
                                                      ["jnp"])
  assert findings == [], findings
  (info,) = infos
  stats = info["compile_stats"]
  if stats["step"] < 0:
    pytest.skip("runtime does not expose jit cache sizes")
  assert stats["step"] == 1
  # the serve cycle really hit two prompt buckets + the refill path
  assert len(stats["prefill_buckets"]) >= 2
  assert stats["insert"] == 1


def test_prefix_splice_check_green():
  findings, infos = lifecycle.check_prefix_splice_stability(["qwen3-4b"],
                                                            ["jnp"])
  assert findings == [], findings
  (info,) = infos
  # the scenario really exercised the splice path, not a vacuous pass
  assert info["cache_stats"]["hits"] >= 1
  stats = info["compile_stats"]
  if stats["step"] < 0:
    pytest.skip("runtime does not expose jit cache sizes")
  # warm set == cold set == the two designed buckets
  assert sorted(stats["prefill_buckets"]) == [(1, 4), (1, 8)]


def test_spec_window_check_green():
  findings, infos = lifecycle.check_spec_window_stability(["qwen3-4b"],
                                                          ["jnp"])
  assert findings == [], findings
  (info,) = infos
  stats = info["compile_stats"]
  if stats["window"] < 0:
    pytest.skip("runtime does not expose jit cache sizes")
  # one verify program across greedy + sampled cycles AND a rank walk
  assert stats["window"] == 1
  assert info["rank_walks"] >= 1


def test_sharding_coverage_flags_known_debt():
  rep = report.AuditReport()
  analysis._sharding_findings(["qwen3-4b"], rep)
  idents = {f.ident for f in rep.findings}
  base = {e["ident"] for e in analysis.load_baseline()["allow"]}
  assert idents <= base, idents - base
  # the quantized tree's path-matched leaves are the documented gap
  assert any(f.quant == "int8" for f in rep.findings)


# ---------------------------------------------------------------------------
# Report / baseline mechanics.
# ---------------------------------------------------------------------------


def test_stable_key_masks_call_ids():
  assert report.stable_key("dispatch:jnp:c42/dot") == "dispatch:jnp:c*/dot"
  f1 = report.Finding(check="dispatch_coverage", config="c",
                      key=report.stable_key("site:c7"))
  f2 = report.Finding(check="dispatch_coverage", config="c",
                      key=report.stable_key("site:c9001"))
  assert f1.ident == f2.ident


def test_finding_rejects_unknown_check():
  with pytest.raises(ValueError, match="unknown check"):
    report.Finding(check="vibes", config="c", key="k")


def test_baseline_partition_and_stale(tmp_path):
  f = report.Finding(check="transfer_lint", config="c", key="k")
  rep = report.AuditReport(findings=[f])
  rep.apply_baseline({"allow": []})
  assert not rep.ok and rep.new == [f]
  rep.apply_baseline({"allow": [{"ident": f.ident},
                                {"ident": "gone|-|-|-|transfer_lint|x"}]})
  assert rep.ok and rep.allowed == [f]
  assert rep.stale == ["gone|-|-|-|transfer_lint|x"]
  # round-trip through write/load
  path = str(tmp_path / "base.json")
  report.write_baseline(rep, path)
  loaded = report.load_baseline(path)
  assert {e["ident"] for e in loaded["allow"]} == {f.ident}
  assert report.load_baseline(str(tmp_path / "missing.json")) == \
      {"allow": []}


def test_cli_exit_codes(tmp_path, capsys):
  from repro.analysis.__main__ import main
  common = ["audit", "--configs", "qwen3_4b", "--policies", "jnp",
            "--quants", "float", "--programs", "decode",
            "--no-lifecycle", "--no-sharding"]
  rep_path = str(tmp_path / "report.json")
  assert main(common + ["--report", rep_path]) == 0
  saved = json.loads(open(rep_path).read())
  assert saved["ok"] and saved["targets"]
  # whisper's tied-head readout einsum is a known unrouted debt: against
  # an EMPTY baseline it must turn the exit code red
  empty = str(tmp_path / "empty.json")
  code = main(["audit", "--configs", "whisper_small", "--policies", "jnp",
               "--quants", "float", "--programs", "decode",
               "--no-lifecycle", "--no-sharding", "--baseline", empty])
  assert code == 1
  assert "NEW" in capsys.readouterr().out
  # --write-baseline accepts those debts; the same audit then passes
  assert main(["audit", "--configs", "whisper_small", "--policies", "jnp",
               "--quants", "float", "--programs", "decode",
               "--no-lifecycle", "--no-sharding", "--baseline", empty,
               "--write-baseline"]) == 0
  assert main(["audit", "--configs", "whisper_small", "--policies", "jnp",
               "--quants", "float", "--programs", "decode",
               "--no-lifecycle", "--no-sharding",
               "--baseline", empty]) == 0


# ---------------------------------------------------------------------------
# Satellite surfaces: recorder reentrancy, calibration contract, hlo_cost.
# ---------------------------------------------------------------------------


def test_record_dispatch_reentrant_and_exception_safe():
  with dispatch.record_dispatch() as outer:
    dispatch._record("a", "jnp")
    with dispatch.record_dispatch() as inner:
      dispatch._record("b", "int8_gemm")
    with pytest.raises(RuntimeError):
      with dispatch.record_dispatch():
        raise RuntimeError("boom")
    dispatch._record("c", "jnp")
  assert [(r.name, r.regime) for r in outer] == \
      [("a", "jnp"), ("b", "int8_gemm"), ("c", "jnp")]
  assert [(r.name, r.regime) for r in inner] == [("b", "int8_gemm")]
  assert not dispatch._RECORDERS


def test_observe_gemm_inputs_reentrant():
  x = jnp.ones((2, 4))
  with dispatch.observe_gemm_inputs() as outer:
    with dispatch.observe_gemm_inputs() as inner:
      dispatch._observe("fc", x)
    dispatch._observe("fc2", 2 * x)
  assert inner == {"fc": 1.0}
  assert outer == {"fc": 1.0, "fc2": 2.0}
  assert not dispatch._OBSERVERS


def test_dispatch_record_is_tuple_compatible():
  rec = dispatch.DispatchRecord("fc", "int8_gemm", 7)
  assert rec == ("fc", "int8_gemm")
  name, regime = rec
  assert (name, regime) == (rec.name, rec.regime)
  assert rec.call_id == 7


def test_calibration_rejects_jitted_apply_fn():
  from repro.core.factored import dense
  from repro.layers.common import gemm
  leaf = dense(KEY, 32, 16, name="fc")

  @jax.jit
  def jitted(x):
    return gemm(leaf, x, dispatch.JNP_ONLY)

  with pytest.raises(RuntimeError, match="EAGERLY"):
    calibrate_activation_ranges(jitted, [jnp.ones((2, 32))])
  # the eager version of the same apply_fn calibrates fine
  got = calibrate_activation_ranges(
      lambda x: gemm(leaf, x, dispatch.JNP_ONLY), [jnp.ones((2, 32))])
  assert got == {"fc": 1.0}
  # zero batches is vacuous, not an error
  assert calibrate_activation_ranges(jitted, []) == {}


def test_hlo_cost_counts_unknown_ops():
  hlo = """
HloModule m, entry_computation_layout={()->f32[4]{0}}

ENTRY %main () -> f32[4] {
  %c = f32[4]{0} constant({1, 2, 3, 4})
  %w = weird9[4]{0} bitcast(f32[4]{0} %c)
  %bad = f32[4]{0} mystery-op with no operand parens
  ROOT %r = f32[4]{0} add(f32[4]{0} %c, f32[4]{0} %c)
}
"""
  rep = hlo_cost.analyze_module(hlo)
  assert rep.unknown_ops.get("dtype:weird9") == 1
  assert rep.unknown_ops.get("<unparsed>") == 1
  assert rep.hbm_bytes >= 16       # the unparsed f32[4] counted as traffic
  # a clean module reports nothing unknown
  clean = hlo.replace("weird9", "f32").replace(
      "\n  %bad = f32[4]{0} mystery-op with no operand parens", "")
  assert hlo_cost.analyze_module(clean).unknown_ops == {}
