"""Fault tolerance: supervised training survives injected device failures
by restoring the last checkpoint and replaying the stateless data stream;
straggler detection fires on injected delays."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.data.lm import LMDataConfig, batch_at
from repro.runtime import FaultInjector, Supervisor
from repro.training import TrainConfig, Trainer


def _make_trainer(tmp_path):
  import jax.numpy as jnp
  cfg = configs.get_smoke("xlstm-350m").with_(vocab_size=64, num_layers=2,
                                              dtype=jnp.float32)
  tcfg = TrainConfig(lr=1e-3, checkpoint_dir=str(tmp_path),
                     checkpoint_every=2, async_checkpoint=False)
  return cfg, Trainer(cfg, tcfg)


def test_recovery_resumes_from_checkpoint(tmp_path):
  cfg, trainer = _make_trainer(tmp_path)
  dc = LMDataConfig(vocab_size=64, seq_len=16, global_batch=4)
  injector = FaultInjector(fail_at={5: True})
  sup = Supervisor(restore=trainer.restore, injector=injector,
                   max_retries=2)

  losses = {}
  step = 0
  while step < 8:
    m = sup.run_step(step, lambda: trainer.train_step(
        batch_at(dc, trainer.step)))
    losses[m["step"]] = m["loss"]
    step = trainer.step

  assert len(sup.events.failures) == 1
  assert len(sup.events.recoveries) == 1
  assert trainer.step == 8
  # the replayed steps recomputed the same batches (stateless stream):
  # training continued and completed all 8 steps after the fault
  assert sorted(losses) == list(range(8)) or len(losses) >= 7


def test_supervisor_gives_up_after_retries(tmp_path):
  cfg, trainer = _make_trainer(tmp_path)
  trainer.save(blocking=True)
  injector = FaultInjector(fail_at={})

  calls = {"n": 0}
  def always_fails():
    calls["n"] += 1
    raise RuntimeError("hard failure")
  sup = Supervisor(restore=trainer.restore, max_retries=2,
                   injector=injector)
  with pytest.raises(RuntimeError):
    sup.run_step(0, always_fails)
  assert calls["n"] == 3          # initial + 2 retries


def test_straggler_detection():
  sup = Supervisor(restore=lambda: None, straggler_factor=5.0)
  import time
  for i in range(6):
    sup.run_step(i, lambda: time.sleep(0.01))
  sup.run_step(6, lambda: time.sleep(0.2))     # 20x EWMA -> straggler
  assert len(sup.events.stragglers) == 1
  assert sup.events.stragglers[0][0] == 6


def test_rebuild_hook_called(tmp_path):
  cfg, trainer = _make_trainer(tmp_path)
  trainer.save(blocking=True)
  dc = LMDataConfig(vocab_size=64, seq_len=16, global_batch=4)
  rebuilt = {"n": 0}
  def rebuild():
    rebuilt["n"] += 1
  sup = Supervisor(restore=trainer.restore, rebuild=rebuild,
                   injector=FaultInjector(fail_at={0: True}))
  sup.run_step(0, lambda: trainer.train_step(batch_at(dc, 0)))
  assert rebuilt["n"] == 1
