"""Property tests for repro.quant's quantize/dequantize (paper §4).

Hypothesis sweeps arbitrary weight matrices through the symmetric
per-column int8 quantizer and asserts the §4 error model: round-trip
error bounded by half a quantization step of the per-column max, strictly
positive scales (the all-zero column hits the 1e-8 amax floor, never a
zero divide), and the sign / column-permutation equivariances that make
symmetric quantization composable with the factored W = UV form.
"""
import pytest

# hypothesis is not part of the runtime image; CI installs it, local runs
# skip (plain-test analogs of the critical properties live in test_quant.py)
hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
import jax.numpy as jnp
import numpy as np

from repro.core.factored import FactoredLinear
from repro.kernels import ref
from repro.quant import quantize_leaf

matrices = hnp.arrays(
    np.float64, hnp.array_shapes(min_dims=2, max_dims=2, min_side=1,
                                 max_side=24),
    elements=st.floats(-10, 10, allow_nan=False))


def _roundtrip(w):
  q, s = ref.quantize_colwise(jnp.asarray(w, jnp.float32))
  return np.asarray(q), np.asarray(s)


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=50, deadline=None)
def test_roundtrip_error_bounded_by_column_step(w):
  """|w - s*q| <= s/2 elementwise — half a quantization step of the
  per-column max (the §4 error model)."""
  q, s = _roundtrip(w)
  deq = q.astype(np.float32) * s[None, :]
  assert np.all(np.abs(w.astype(np.float32) - deq) <= s[None, :] / 2 + 1e-6)


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=50, deadline=None)
def test_scales_positive_and_match_amax(w):
  """Scales are strictly positive; nonzero columns get exactly amax/127."""
  q, s = _roundtrip(w)
  assert np.all(s > 0)
  amax = np.max(np.abs(w.astype(np.float32)), axis=0)
  nz = amax > 1e-6
  np.testing.assert_allclose(s[nz], amax[nz] / 127.0, rtol=1e-5)
  assert np.all(np.abs(q) <= 127)


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=50, deadline=None)
def test_sign_equivariance(w):
  """quantize(-w) == (-q, s): symmetric quantization has no zero point
  (jnp.round is half-to-even, which is odd-symmetric)."""
  q, s = _roundtrip(w)
  qn, sn = _roundtrip(-w)
  np.testing.assert_array_equal(qn, -q)
  np.testing.assert_allclose(sn, s, rtol=1e-7)


@hypothesis.given(matrices, st.randoms(use_true_random=False))
@hypothesis.settings(max_examples=50, deadline=None)
def test_column_permutation_equivariance(w, rnd):
  """Per-column quantization commutes with column permutation."""
  perm = list(range(w.shape[1]))
  rnd.shuffle(perm)
  q, s = _roundtrip(w)
  qp, sp = _roundtrip(w[:, perm])
  np.testing.assert_array_equal(qp, q[:, perm])
  np.testing.assert_allclose(sp, s[perm], rtol=1e-7)


@hypothesis.given(st.integers(1, 16), st.integers(1, 16))
@hypothesis.settings(max_examples=25, deadline=None)
def test_all_zero_column_degenerate(m, n):
  """An all-zero weight quantizes to q == 0 with the positive floor
  scale and dequantizes to exactly zero — no NaN/inf anywhere."""
  q, s = _roundtrip(np.zeros((m, n)))
  assert np.all(q == 0) and np.all(s > 0) and np.all(np.isfinite(s))
  leaf = quantize_leaf(FactoredLinear(
      w=jnp.zeros((m, n)), u=None, v=None, name="z"))
  y = leaf.apply(jnp.ones((2, m), jnp.float32))
  assert np.all(np.asarray(y) == 0.0)


@hypothesis.given(matrices)
@hypothesis.settings(max_examples=25, deadline=None)
def test_quantized_leaf_product_roundtrip(w):
  """quantize_leaf's dequantized product stays inside the elementwise
  bound — the leaf-level version of the round-trip property."""
  wf = jnp.asarray(w, jnp.float32)
  leaf = quantize_leaf(FactoredLinear(w=wf, u=None, v=None, name="w"))
  _, s = _roundtrip(w)
  err = np.abs(np.asarray(leaf.product()) - np.asarray(wf))
  assert np.all(err <= s[None, :] / 2 + 1e-6)
