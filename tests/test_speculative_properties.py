"""Property tests for the speculative-decoding core (hypothesis-gated,
mirroring test_quant_properties):

  * accept_longest_prefix against a per-row python oracle — accepted
    prefix + exactly one bonus token, never more than k+1, acceptance
    maximal;
  * accept_sampled's emitted-token marginal == the target distribution,
    for ARBITRARY drawn draft/target distributions (chi-square over a
    Monte Carlo; the deterministic fixed-seed version always runs in
    test_spec_window_parity);
  * rewind-then-redecode == never-having-drafted — for ARBITRARY accept
    lengths 0..k, a state assembled from post-window KV + pre-window
    carries and re-fed the accepted prefix continues bit-identically to
    a state that never saw the rejected suffix (both model classes:
    positional KV and SSM/recurrent carries).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models.api import get_model
from repro.serving.speculative import (accept_longest_prefix,
                                       accept_sampled, merge_rewind)

VOCAB = 32


def _oracle(draft_row, target_row):
  """Per-row reference: walk the window, accept while agreeing."""
  accept = 0
  for d, g in zip(draft_row, target_row):
    if d != g:
      break
    accept += 1
  out = list(draft_row[:accept]) + [target_row[accept]]
  return accept, out


@settings(deadline=None, max_examples=200)
@given(st.data())
def test_accept_longest_prefix_matches_oracle(data):
  b = data.draw(st.integers(1, 5), label="b")
  k = data.draw(st.integers(1, 6), label="k")
  # small alphabet so agreements actually happen
  toks = st.integers(0, 3)
  draft = np.array(data.draw(
      st.lists(st.lists(toks, min_size=k, max_size=k),
               min_size=b, max_size=b), label="draft"), np.int32)
  target = np.array(data.draw(
      st.lists(st.lists(toks, min_size=k + 1, max_size=k + 1),
               min_size=b, max_size=b), label="target"), np.int32)

  accept, out, out_len = accept_longest_prefix(draft, target)
  assert accept.shape == out_len.shape == (b,)
  assert out.shape == (b, k + 1)
  for i in range(b):
    want_accept, want_out = _oracle(draft[i], target[i])
    assert accept[i] == want_accept
    assert out_len[i] == want_accept + 1 <= k + 1
    assert list(out[i, :out_len[i]]) == want_out
    assert (out[i, out_len[i]:] == 0).all()
    # maximality: everything accepted agrees; the first rejection (if
    # any) disagrees — the bonus token is the target's own choice there
    assert (draft[i, :accept[i]] == target[i, :accept[i]]).all()
    if accept[i] < k:
      assert draft[i, accept[i]] != target[i, accept[i]]
    assert out[i, accept[i]] == target[i, accept[i]]


SAMP_VOCAB = 5
CHI2_CRIT_DF4 = 18.47     # alpha = 1e-3 (derandomized: fixed line)


def _norm(w):
  w = np.asarray(w, np.float64) + 0.25
  return w / w.sum()


@settings(deadline=None, max_examples=10, derandomize=True)
@given(st.data())
def test_accept_sampled_marginal_matches_target(data):
  """Rejection-sampling identity, property form: for drawn q/p the first
  emitted token's Monte Carlo marginal is chi-square-consistent with
  p_1 — speculation at temperature > 0 is vanilla sampling in
  distribution regardless of the draft."""
  k = data.draw(st.integers(1, 3), label="k")
  seed = data.draw(st.integers(0, 2 ** 16), label="seed")
  weights = data.draw(
      st.lists(st.lists(st.floats(0.0, 1.0), min_size=SAMP_VOCAB,
                        max_size=SAMP_VOCAB),
               min_size=2 * k + 1, max_size=2 * k + 1), label="w")
  q = np.stack([_norm(w) for w in weights[:k]])[None]
  p = np.stack([_norm(w) for w in weights[k:]])[None]

  rng = np.random.default_rng(seed)
  n = 2000
  counts = np.zeros(SAMP_VOCAB)
  for _ in range(n):
    draft = np.array(
        [[rng.choice(SAMP_VOCAB, p=q[0, j]) for j in range(k)]], np.int32)
    _, out, _ = accept_sampled(draft, q, p, rng)
    counts[out[0, 0]] += 1
  expected = n * p[0, 0]
  chi2 = ((counts - expected) ** 2 / expected).sum()
  assert chi2 < CHI2_CRIT_DF4, (chi2, counts, expected)


def test_accept_longest_prefix_validates_shapes():
  with pytest.raises(ValueError, match="b, k"):
    accept_longest_prefix(np.zeros((2, 3)), np.zeros((2, 3)))
  with pytest.raises(ValueError, match="b, k"):
    accept_longest_prefix(np.zeros((3,)), np.zeros((3, 4)))


# ---------------------------------------------------------------------------
# Rewind-then-redecode == never-having-drafted.
# ---------------------------------------------------------------------------


def _family_fixture(arch):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32, vocab_size=VOCAB)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  step = jax.jit(lambda p, s, t, q: api.decode_step(p, s, t, q, cfg))
  window = jax.jit(lambda p, s, t, q: api.decode_window(p, s, t, q, cfg))
  return cfg, api, params, step, window


_FIXTURES = {}


def _fixture(arch):
  if arch not in _FIXTURES:
    _FIXTURES[arch] = _family_fixture(arch)
  return _FIXTURES[arch]


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
@settings(deadline=None, max_examples=8)
@given(accept_len=st.integers(0, 3), seed=st.integers(0, 2 ** 16))
def test_rewind_then_redecode_equals_never_drafted(arch, accept_len, seed):
  """Window k = 3: decode a 4-token window, rewind to an arbitrary
  accepted length, re-feed the accepted prefix, then decode 2 probe
  tokens — logits and state must be BIT-identical to a run that fed only
  the accepted prefix sequentially (no window, no rejected suffix)."""
  cfg, api, params, step, window = _fixture(arch)
  b, k = 2, 3
  rng = np.random.RandomState(seed)
  state0 = api.init_decode_state(cfg, b, 16)
  pos = jnp.zeros((b,), jnp.int32)

  # consume a short committed history first (positions 0..1)
  for t in range(2):
    hist = jnp.asarray(rng.randint(1, VOCAB, size=(b, 1)), jnp.int32)
    _, state0 = step(params, state0, hist, pos + t)
  pos = pos + 2
  lens = accept_len + 1                 # window tokens consumed on commit

  toks = jnp.asarray(rng.randint(1, VOCAB, size=(b, k + 1)), jnp.int32)
  probes = jnp.asarray(rng.randint(1, VOCAB, size=(b, 2)), jnp.int32)

  # speculative path: full window, then rewind (post-window KV +
  # pre-window carries) and re-feed the accepted prefix sequentially
  _, state_w = window(params, state0, toks, pos)
  carry = api.decode_state_carry(cfg)
  st_spec = merge_rewind(state_w, state0, carry)
  for t in range(lens):
    lg_spec, st_spec = step(params, st_spec, toks[:, t:t + 1], pos + t)

  # reference path: only ever feeds the accepted prefix
  st_ref = state0
  for t in range(lens):
    lg_ref, st_ref = step(params, st_ref, toks[:, t:t + 1], pos + t)
  np.testing.assert_array_equal(np.asarray(lg_spec), np.asarray(lg_ref))

  # both continue identically: the rejected suffix left no trace
  p2 = pos + lens
  for t in range(2):
    lg_spec, st_spec = step(params, st_spec, probes[:, t:t + 1], p2 + t)
    lg_ref, st_ref = step(params, st_ref, probes[:, t:t + 1], p2 + t)
    np.testing.assert_array_equal(np.asarray(lg_spec), np.asarray(lg_ref))
