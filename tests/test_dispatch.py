"""KernelPolicy dispatch: regime classification, the decode_matvec batch
contract, and serving-through-kernels — LMEngine / StreamingSpeechServer
under a Pallas decode policy (interpret mode) must reproduce the jnp_only
policy while demonstrably routing through the shape-specialized kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import FactorizationPlan, to_stage1
from repro.core.factored import dense, factored
from repro.kernels import dispatch, ops, ref
from repro.layers.common import ModelConfig, gemm

KEY = jax.random.PRNGKey(0)


def rnd(seed, shape, scale=1.0):
  return jax.random.normal(jax.random.PRNGKey(seed), shape,
                           jnp.float32) * scale


# ---------------------------------------------------------------------------
# Classification.
# ---------------------------------------------------------------------------


def test_classify_regime_table():
  pol = dispatch.decode_policy(8)
  w = dense(KEY, 128, 256, name="fc")
  uv = factored(KEY, 128, 256, r=128, name="lr")
  x_small = rnd(1, (4, 128))
  x_big = rnd(2, (64, 128))
  assert dispatch.classify(w, x_small, pol) == "decode_matvec"
  assert dispatch.classify(w, x_big, pol) == "jnp"        # batch > max
  assert dispatch.classify(uv, x_small, pol) == "lowrank_gemm"
  assert dispatch.classify(uv, x_big, pol) == "lowrank_gemm"
  # degenerate shapes fall back regardless of regime
  tiny = dense(KEY, 64, 32, name="tiny")
  assert dispatch.classify(tiny, rnd(3, (4, 64)), pol) == "jnp"
  # jnp_only and no-policy are inert
  assert dispatch.classify(w, x_small, dispatch.JNP_ONLY) == "jnp"
  assert dispatch.classify(w, x_small, None) == "jnp"


def test_classify_per_name_overrides():
  pol = dispatch.decode_policy(
      4, overrides=(("*/rec", "jnp"), ("fc", "int8_gemm")))
  rec = dense(KEY, 128, 384, name="gru0/rec", group="rec")
  fc = dense(KEY, 128, 256, name="fc")
  x = rnd(1, (2, 128))
  assert dispatch.classify(rec, x, pol) == "jnp"
  assert dispatch.classify(fc, x, pol) == "int8_gemm"
  # a gru_cell override at a plain GEMM site means "reference path", not
  # a crash: the regime only exists at the recurrent-step call site
  gpol = dispatch.decode_policy(4, overrides=(("*/rec", "gru_cell"),))
  assert dispatch.classify(rec, x, gpol) == "jnp"
  frec = factored(KEY, 128, 384, r=128, name="gru1/rec", group="rec")
  got = gemm(frec, x, gpol)       # factored rec: maybe_gru_cell declines,
  np.testing.assert_allclose(     # the GEMM site must still route safely
      np.asarray(got), np.asarray(gemm(frec, x)), atol=2e-4, rtol=2e-4)
  with pytest.raises(ValueError):
    dispatch.KernelPolicy(mode="decode", overrides=(("x", "nonsense"),))
  with pytest.raises(ValueError):
    dispatch.KernelPolicy(mode="bogus")


def test_jnp_only_policy_is_bit_exact():
  """KernelPolicy() must reproduce the default path EXACTLY (the
  training-untouched guarantee)."""
  leaf = dense(KEY, 96, 160, name="w")
  x = rnd(4, (8, 96))
  assert bool(jnp.all(gemm(leaf, x) == gemm(leaf, x, dispatch.JNP_ONLY)))
  uv = factored(KEY, 96, 160, r=64, name="uv")
  assert bool(jnp.all(gemm(uv, x) == gemm(uv, x, dispatch.JNP_ONLY)))


def test_dispatch_gemm_matches_reference():
  pol = dispatch.decode_policy(8)
  w = dense(KEY, 128, 256, name="fc")
  uv = factored(KEY, 128, 256, r=128, name="lr")
  x = rnd(5, (4, 128))
  np.testing.assert_allclose(np.asarray(gemm(w, x, pol)),
                             np.asarray(gemm(w, x)), atol=2e-4, rtol=2e-4)
  np.testing.assert_allclose(np.asarray(gemm(uv, x, pol)),
                             np.asarray(gemm(uv, x)), atol=2e-4, rtol=2e-4)
  # 3D activations flatten their leading dims through the kernel
  x3 = rnd(6, (2, 2, 128))
  np.testing.assert_allclose(np.asarray(gemm(w, x3, pol)),
                             np.asarray(gemm(w, x3)), atol=2e-4, rtol=2e-4)


def test_int8_override_regime():
  """The w8a8 regime entry point (jitted quantized_matmul) via override."""
  pol = dispatch.decode_policy(4, overrides=(("fc", "int8_gemm"),))
  w = dense(KEY, 128, 256, name="fc")
  x = rnd(7, (2, 128))
  with dispatch.record_dispatch() as log:
    y = gemm(w, x, pol)
  assert ("fc", "int8_gemm") in log
  dense_y = np.asarray(gemm(w, x))
  rel = np.linalg.norm(np.asarray(y) - dense_y) / np.linalg.norm(dense_y)
  assert rel < 0.05


# ---------------------------------------------------------------------------
# decode_matvec batch contract (b <= 16).
# ---------------------------------------------------------------------------


def test_decode_matvec_batch_boundary():
  """b > DECODE_BATCH_MAX falls back to the jnp reference instead of being
  silently accepted; the kernel path still runs at the boundary."""
  w = rnd(8, (192, 256), 0.05)

  def kernel_boom(*a, **k):
    raise AssertionError("pallas kernel entered")

  orig = ops._decode_matvec
  ops._decode_matvec = kernel_boom
  try:
    # above the boundary: ref fallback, the pallas body is never traced
    y17 = ops.decode_matvec(rnd(9, (17, 192)), w)
    np.testing.assert_allclose(np.asarray(y17),
                               np.asarray(ref.decode_matvec(
                                   rnd(9, (17, 192)), w)),
                               atol=2e-4, rtol=2e-4)
    # at the boundary: the kernel path IS taken (fresh shape -> retrace)
    with pytest.raises(Exception):
      ops.decode_matvec(rnd(10, (16, 192)), w)
  finally:
    ops._decode_matvec = orig
  y16 = ops.decode_matvec(rnd(10, (16, 192)), w)
  np.testing.assert_allclose(np.asarray(y16),
                             np.asarray(ref.decode_matvec(
                                 rnd(10, (16, 192)), w)),
                             atol=2e-4, rtol=2e-4)


def test_decode_policy_window_widens_to_contract():
  """The speculative regime-table extension: a fused verify window
  presents batch x window rows to one GEMM, so decode_policy(window=w)
  widens the decode_matvec bound to min(16, b * w) — covering the window
  rows while never widening past the kernel's 16-row contract."""
  assert dispatch.decode_policy(2, window=3).decode_batch_max == 6
  assert dispatch.decode_policy(4, window=4).decode_batch_max == 16
  assert dispatch.decode_policy(8, window=4).decode_batch_max == 16  # cap
  assert dispatch.decode_policy(4).decode_batch_max == 4   # default w=1
  # resolve_policy threads the window through the engine's string form
  assert dispatch.resolve_policy("pallas", 2,
                                 window=3).decode_batch_max == 6

  # classification at the widened boundary: b*w rows stay decode_matvec,
  # one row more is outside the regime
  w = dense(KEY, 192, 256, name="fc")
  pol = dispatch.decode_policy(2, window=3)
  assert dispatch.classify(w, rnd(1, (6, 192)), pol) == "decode_matvec"
  assert dispatch.classify(w, rnd(2, (7, 192)), pol) == "jnp"


def test_quantized_matmul_is_jitted():
  assert hasattr(ops.quantized_matmul, "lower")  # jax.jit wrapper
  x = rnd(11, (4, 128))
  w = rnd(12, (128, 256), 0.05)
  got = ops.quantized_matmul(x, w)
  dense_y = np.asarray(x @ w)
  rel = np.linalg.norm(np.asarray(got) - dense_y) / np.linalg.norm(dense_y)
  assert rel < 0.05


def test_block_table_fitting():
  """The shared block-size selection: clamp to dim, halve to divisibility."""
  blocks = ops._fit_blocks("decode_matvec",
                           {"block_m": 384, "block_n": 1280})
  assert blocks == {"block_m": 384, "block_n": 256}   # clamp / table default
  odd = ops._fit_blocks("decode_matvec", {"block_n": 384})
  assert odd["block_n"] == 128                        # halve to divisibility
  req = ops._fit_blocks("lowrank_gemm", {"block_m": 512}, {"block_m": 768})
  assert req["block_m"] == 512                        # request clamped


# ---------------------------------------------------------------------------
# Serving through the kernels (the acceptance check).
# ---------------------------------------------------------------------------

LM_CFG = ModelConfig(
    name="dispatch-lm", family="transformer", num_layers=2, d_model=128,
    num_heads=1, num_kv_heads=1, d_ff=256, vocab_size=128,
    dtype=jnp.float32, remat="none")

DS_CFG = ModelConfig(
    name="dispatch-ds2", family="deepspeech", num_layers=2, d_model=128,
    num_heads=1, num_kv_heads=1, d_ff=128, vocab_size=32,
    feat_dim=80, gru_dims=(128, 128), fc_dim=128, conv_channels=8,
    time_stride=2, dtype=jnp.float32, remat="none")


def _engine_step_logits(eng, prompts, steps):
  """Greedy-decode `steps` tokens, returning every step's logits (the
  robust comparison surface: token ids can flip on float near-ties)."""
  logits = [np.asarray(eng.prefill(prompts), np.float32)]
  for _ in range(steps):
    tok = jnp.argmax(jnp.asarray(logits[-1][:, -1]), -1)[:, None]
    lg, eng.state = eng._step(eng.params, eng.state, tok.astype(jnp.int32),
                              eng.positions)
    eng.positions = eng.positions + 1
    logits.append(np.asarray(lg, np.float32))
  return np.concatenate(logits, axis=1)


def test_lm_engine_pallas_matches_jnp():
  """LMEngine decode under a Pallas KernelPolicy (interpret mode)
  reproduces the jnp_only logits step-for-step and routes through
  decode_matvec."""
  from repro.serving import LMEngine
  from repro.models.api import get_model
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  prompts = np.array([[1, 2], [3, 4]])
  ref_eng = LMEngine(LM_CFG, params, batch_size=2, max_len=16)
  want = _engine_step_logits(ref_eng, prompts, steps=4)
  with dispatch.record_dispatch() as log:
    pal_eng = LMEngine(LM_CFG, params, batch_size=2, max_len=16,
                       kernel_policy="pallas")
    got = _engine_step_logits(pal_eng, prompts, steps=4)
  assert "decode_matvec" in {r for _, r in log}
  np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_lm_engine_lowrank_regime():
  """Factored (stage-1) params decode through the fused lowrank kernel."""
  from repro.serving import LMEngine
  from repro.models.api import get_model
  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  fparams = to_stage1(params, FactorizationPlan(include=("*",),
                                                min_dim=128))
  prompts = np.array([[5, 6], [7, 8]])
  ref_eng = LMEngine(LM_CFG, fparams, batch_size=2, max_len=16)
  want = _engine_step_logits(ref_eng, prompts, steps=3)
  with dispatch.record_dispatch() as log:
    pal_eng = LMEngine(LM_CFG, fparams, batch_size=2, max_len=16,
                       kernel_policy="pallas")
    got = _engine_step_logits(pal_eng, prompts, steps=3)
  assert "lowrank_gemm" in {r for _, r in log}
  np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_speech_server_pallas_matches_jnp():
  """StreamingSpeechServer under the Pallas policy: identical emissions,
  and the frame step lowers through gru_cell + decode_matvec."""
  from repro.data.speech import SpeechDataConfig, batch_at
  from repro.serving import StreamingSpeechServer
  from repro.models.api import get_model
  params = get_model(DS_CFG).init(jax.random.PRNGKey(0), DS_CFG)
  dc = SpeechDataConfig(vocab_size=DS_CFG.vocab_size,
                        feat_dim=DS_CFG.feat_dim, global_batch=2)
  chunk = batch_at(dc, 0)["feats"][:, :24]
  ref_srv = StreamingSpeechServer(DS_CFG, params, batch_size=2)
  want = ref_srv.process_chunk(chunk)
  with dispatch.record_dispatch() as log:
    pal_srv = StreamingSpeechServer(DS_CFG, params, batch_size=2,
                                    kernel_policy="pallas")
    got = pal_srv.process_chunk(chunk)
  regimes = {r for _, r in log}
  assert {"gru_cell", "decode_matvec"} <= regimes
  assert got == want


def test_int8_regime_prequantized_zero_weight_requant(monkeypatch):
  """Pins the fix to the old dispatch.py TODO: with PTQ'd leaves the
  int8 regime consumes stored scales directly — ZERO weight quantize ops
  are traced into the decode step. (Activation row-quantization is
  inherent to w8a8 and allowed; `ref.quantize_colwise` is the one
  function that quantizes a weight.)"""
  from repro.quant import quantize_params
  from repro.serving import LMEngine
  from repro.models.api import get_model

  colwise_calls = []
  orig_colwise = ref.quantize_colwise
  monkeypatch.setattr(
      ref, "quantize_colwise",
      lambda w: colwise_calls.append(w.shape) or orig_colwise(w))

  # control: a float-leaf int8 override DOES requantize the weight at
  # trace time (ops.quantized_matmul); unique shape forces a fresh trace
  ops.quantized_matmul.lower(rnd(20, (2, 136)), rnd(21, (136, 264), 0.05))
  assert colwise_calls, "instrumentation failed to see the float path"

  params = get_model(LM_CFG).init(jax.random.PRNGKey(0), LM_CFG)
  qparams = quantize_params(params)     # the one-shot PTQ (outside trace)
  colwise_calls.clear()
  with dispatch.record_dispatch() as log:
    eng = LMEngine(LM_CFG, qparams, batch_size=2, max_len=16,
                   kernel_policy="pallas")
    eng.generate(np.array([[1, 2], [3, 4]]), steps=2)
  assert "int8_gemm" in {r for _, r in log}
  assert colwise_calls == [], (
      f"decode step re-quantized weights: {colwise_calls}")


def test_deepspeech_decode_step_allclose():
  """Direct frame-step numerics: Pallas policy vs jnp, tight tolerance."""
  from repro.models import deepspeech
  params = deepspeech.init_model(jax.random.PRNGKey(0), DS_CFG)
  gru_in = ((DS_CFG.feat_dim + 1) // 2 + 1) // 2 * DS_CFG.conv_channels
  x_t = rnd(13, (2, gru_in), 0.5)
  state = deepspeech.init_decode_state(DS_CFG, 2)
  want, _ = deepspeech.decode_step(params, state, x_t, DS_CFG)
  got, _ = deepspeech.decode_step(params, state, x_t, DS_CFG,
                                  policy=dispatch.decode_policy(2))
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=1e-4, rtol=1e-4)
