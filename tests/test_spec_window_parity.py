"""The speculative-path correctness sweep for the batched decode_window.

Three pillars:

  1. The parity grid — batched `decode_window` vs the sequential
     `decode_window_sequential` oracle across every decodable family x
     kernel policy (jnp / pallas) x storage (float / PTQ int8).
     Contract: token-for-token argmax equality EVERYWHERE (the invariant
     speculative acceptance rests on), plus bitwise equality where the
     backend delivers it. transformer (qwen3 GQA + deepseek MLA), zamba
     and deepspeech are bit-identical; xlstm and whisper run the same
     arithmetic but XLA's CPU fusion contexts differ between the two
     program shapes, leaving their accumulators a few ulp apart
     (~2e-6 relative for xlstm, ~2e-7 for whisper) — proven by
     bisection to appear only in the fully composed program, not in any
     isolated layer, so the pinned contract there is argmax + tight
     allclose.

  2. Rejection sampling (`accept_sampled`) distribution parity — a
     hypothesis-driven chi-square test that the emitted-token marginal
     matches vanilla sampling from the target exactly, for arbitrary
     draft/target distributions (tiny vocab, deterministic seeds).

  3. Sampled-path rewind — a temperature > 0 speculative engine's
     committed state is the never-drafted state: the prefix published at
     a full-accept retirement splices into a follow-up turn that decodes
     token-for-token like a cold vanilla engine (also the regression
     test for per-slot publish validity: a partial-accept retirement
     under the full-accept fast path must still DROP its publish).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import dispatch
from repro.models.api import get_model
from repro.serving import LMEngine, PrefixCache
from repro.serving.speculative import accept_sampled

# b * W = 8 <= 16: the fused window GEMMs stay inside decode_matvec's
# row contract under the pallas policy (dispatch.decode_policy(window=))
B, W = 2, 4

# the locked per-family contract: which archs are bit-identical in float
# storage. Token (argmax) parity holds EVERYWHERE; the bitwise set is
# empirical — where XLA happens to fuse the two program shapes the same.
ARCHS = {
    "qwen3-4b": True,
    "deepseek-v2-lite": True,
    "zamba2-7b": True,
    "xlstm-350m": False,
    "whisper-small": False,
    "deepspeech2-wsj": True,
}
# PTQ shifts the fusion landscape: the int8 w8a8 oracle makes whisper
# fully bitwise and deepspeech's logits bitwise (its GRU carries drift
# ~1e-8); xlstm stays ulp-level. (logits_bitwise, state_bitwise) per arch:
PTQ_ARCHS = {
    "qwen3-4b": (True, True),
    "deepseek-v2-lite": (True, True),
    "zamba2-7b": (True, True),
    "xlstm-350m": (False, False),
    "whisper-small": (True, True),
    "deepspeech2-wsj": (True, False),
}


def _build(arch, quantized):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32, vocab_size=48)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  if quantized:
    from repro.quant import quantize_params
    params = quantize_params(params)
  return cfg, api, params


def _window_inputs(cfg, api, rng):
  """(state, tokens-or-frames, positions) for one (B, W) window; frames
  for deepspeech (its decode surface streams post-frontend features)."""
  state = api.init_decode_state(cfg, B, 16)
  if cfg.family == "deepspeech":
    gru_in = (((cfg.feat_dim + 1) // 2 + 1) // 2) * cfg.conv_channels
    toks = jnp.asarray(rng.randn(B, W, gru_in).astype(np.float32) * 0.1)
  else:
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, size=(B, W)),
                       jnp.int32)
  return state, toks, jnp.zeros((B,), jnp.int32)


def _policy(name):
  if name == "jnp":
    return None
  return dispatch.decode_policy(B, window=W, interpret=True)


@pytest.mark.parametrize("policy_name", ["jnp", "pallas"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_window_parity_grid_float(arch, policy_name):
  _assert_window_parity(arch, policy_name, quantized=False)


@pytest.mark.slow
@pytest.mark.parametrize("policy_name", ["jnp", "pallas"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_window_parity_grid_quantized(arch, policy_name):
  """PTQ column: int8 storage decodes through the same two window
  programs — the w8a8 arithmetic is policy-invariant, so the float
  contract (tokens everywhere, bits on the bitwise archs) carries."""
  _assert_window_parity(arch, policy_name, quantized=True)


def _assert_window_parity(arch, policy_name, *, quantized):
  if quantized:
    logits_bitwise, state_bitwise = PTQ_ARCHS[arch]
  else:
    logits_bitwise = state_bitwise = ARCHS[arch]
  cfg, api, params = _build(arch, quantized)
  policy = _policy(policy_name)
  state, toks, pos = _window_inputs(cfg, api, np.random.RandomState(3))

  seq_fn = jax.jit(lambda p, s, t, q: api.decode_window_sequential(
      p, s, t, q, cfg, policy=policy))
  bat_fn = jax.jit(lambda p, s, t, q: api.decode_window(
      p, s, t, q, cfg, policy=policy))
  assert api.decode_window_batched is not None   # the grid tests the
  lg_seq, st_seq = seq_fn(params, state, toks, pos)  # batched program
  lg_bat, st_bat = bat_fn(params, state, toks, pos)

  lg_seq, lg_bat = np.asarray(lg_seq), np.asarray(lg_bat)
  # the invariant acceptance rests on: identical greedy choices
  np.testing.assert_array_equal(lg_seq.argmax(-1), lg_bat.argmax(-1))
  if logits_bitwise:
    np.testing.assert_array_equal(lg_seq, lg_bat)
  else:
    np.testing.assert_allclose(lg_seq, lg_bat, rtol=1e-4, atol=1e-4)
  for a, b in zip(jax.tree.leaves(st_seq), jax.tree.leaves(st_bat)):
    if state_bitwise:
      np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
      np.testing.assert_allclose(np.asarray(a, np.float32),
                                 np.asarray(b, np.float32),
                                 rtol=1e-4, atol=1e-4)


def test_window_streaming_split_matches_one_shot():
  """Two chained windows (W then W at positions W..2W-1) equal one 2W
  window: the batched program composes over its own output state, not
  just over sequential-step state."""
  cfg, api, params = _build("qwen3-4b", False)
  rng = np.random.RandomState(5)
  state = api.init_decode_state(cfg, B, 16)
  toks = jnp.asarray(rng.randint(1, cfg.vocab_size, size=(B, 2 * W)),
                     jnp.int32)
  pos = jnp.zeros((B,), jnp.int32)
  win = jax.jit(lambda p, s, t, q: api.decode_window(p, s, t, q, cfg))

  lg_a, st = win(params, state, toks[:, :W], pos)
  lg_b, st = win(params, st, toks[:, W:], pos + W)
  lg_full, st_full = win(params, state, toks, pos)
  np.testing.assert_array_equal(
      np.concatenate([np.asarray(lg_a), np.asarray(lg_b)], 1),
      np.asarray(lg_full))
  for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_full)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Rejection sampling == vanilla sampling, in distribution. (The
# hypothesis-drawn generalization lives in test_speculative_properties,
# gated like the repo's other property modules; this one is deterministic
# so the distribution identity is always pinned, hypothesis or not.)
# ---------------------------------------------------------------------------

VOCAB = 5
# chi-square upper critical value at alpha = 1e-3 for df = VOCAB - 1
# (seeds are fixed, so this is a pass/fail line, not a flake rate)
CHI2_CRIT_DF4 = 18.47


def _norm(w):
  w = np.asarray(w, np.float64) + 0.25    # bounded away from 0 so every
  return w / w.sum()                      # expected cell count is ~N/20+


@pytest.mark.parametrize("k,seed", [(1, 0), (2, 1), (3, 2)])
def test_accept_sampled_first_token_marginal_is_target(k, seed):
  """The core rejection-sampling identity: whatever the draft proposes
  and whatever q it proposes from, the FIRST emitted token's marginal is
  exactly p_1 — q(d)·min(1, p/q) + P(reject)·residual = p. Monte Carlo
  over n rounds with draft tokens drawn from q on a shared rng,
  chi-square of the emitted-token counts against n·p_1."""
  dist_rng = np.random.default_rng(100 + seed)
  q = np.stack([_norm(dist_rng.random(VOCAB)) for _ in range(k)])[None]
  p = np.stack([_norm(dist_rng.random(VOCAB))
                for _ in range(k + 1)])[None]

  rng = np.random.default_rng(seed)
  n = 2500
  counts = np.zeros(VOCAB)
  for _ in range(n):
    draft = np.array([[rng.choice(VOCAB, p=q[0, j]) for j in range(k)]],
                     np.int32)
    _, out, _ = accept_sampled(draft, q, p, rng)
    counts[out[0, 0]] += 1
  expected = n * p[0, 0]
  chi2 = ((counts - expected) ** 2 / expected).sum()
  assert chi2 < CHI2_CRIT_DF4, (chi2, counts, expected)


def test_accept_sampled_contract():
  """Shape/validation contract mirrors accept_longest_prefix; a draft
  the target fully agrees with is always accepted (p == q -> the accept
  probability min(1, p/q) is 1 for every token)."""
  rng = np.random.default_rng(0)
  with pytest.raises(ValueError, match="draft"):
    accept_sampled(np.zeros((3,)), np.zeros((1, 3, 4)),
                   np.zeros((1, 4, 4)), rng)
  with pytest.raises(ValueError, match="target_probs"):
    accept_sampled(np.zeros((1, 3), np.int32), np.zeros((1, 3, 4)),
                   np.zeros((1, 3, 4)), rng)
  k, v = 3, 4
  p = _norm(np.arange(v))[None, None].repeat(k + 1, 1)    # (1, k+1, v)
  draft = np.array([[rng.choice(v, p=p[0, j]) for j in range(k)]])
  accept, out, out_len = accept_sampled(draft, p[:, :k], p, rng)
  assert accept[0] == k and out_len[0] == k + 1
  np.testing.assert_array_equal(out[0, :k], draft[0])


def test_accept_sampled_zero_q_mass_rejects_to_residual():
  """A draft token with q ≈ 0 but p > 0 accepts with prob p/q clamped
  to 1... and the reverse (p = 0) always rejects into the residual,
  which can never re-emit a zero-p token."""
  k, v = 1, 4
  q = np.array([[[0.0, 1.0, 0.0, 0.0]]])        # draft always says 1
  p = np.array([[[0.5, 0.0, 0.5, 0.0]]] * 2).reshape(1, 2, v)
  rng = np.random.default_rng(1)
  for _ in range(50):
    accept, out, out_len = accept_sampled(
        np.array([[1]], np.int32), q, p, rng)
    assert accept[0] == 0 and out_len[0] == 1
    assert out[0, 0] in (0, 2)                  # residual ∝ max(0, p-q)


# ---------------------------------------------------------------------------
# Sampled-path rewind: committed state == never-drafted state.
# ---------------------------------------------------------------------------


def test_sampled_rewind_continues_like_never_drafted():
  """Temperature > 0 speculative decode on a carry family (zamba: SSM
  snapshot/replay) with a weak draft (rejections every few windows),
  then a greedy follow-up turn over prompt+answer: the follow-up must
  equal a cold vanilla engine token-for-token — the sampled run's
  rewinds left exactly the never-drafted state behind."""
  from repro.serving import make_draft_params
  cfg = configs.get_smoke("zamba2-7b").with_(dtype=jnp.float32,
                                             vocab_size=48)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompt = np.arange(1, 7)

  spec = LMEngine(cfg, params, batch_size=1, max_len=64, speculate=3,
                  draft_params=make_draft_params(params, rank=8))
  spec.submit(prompt, max_new_tokens=9)
  turn1 = spec.run(temperature=0.9, rng=jax.random.PRNGKey(5))[0].tokens
  assert spec.accept_rate is not None and spec.accept_rate < 1.0
  follow = np.concatenate([prompt, turn1])

  spec.submit(follow, max_new_tokens=8)
  got = spec.run()[0].tokens                      # greedy follow-up
  van = LMEngine(cfg, params, batch_size=1, max_len=64)
  van.submit(follow, max_new_tokens=8)
  want = van.run()[0].tokens
  np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_full_accept_retirement_publishes_prefix(temperature):
  """Per-slot publish validity, the regression this PR fixes: a slot
  retiring on its window's LAST token (full accept, commit == k+1) has
  carries that ARE the committed state, so under publish_on_retire its
  prefix must publish and the follow-up turn must HIT the cache — the
  old all-or-nothing flush dropped every carry-family retirement
  publish whenever the full-accept fast path skipped the replay."""
  cfg = configs.get_smoke("zamba2-7b").with_(dtype=jnp.float32,
                                             vocab_size=48)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompt = np.arange(1, 7)
  k = 2
  # emissions: 1 (prefill) + m windows x (k+1) -> budget 7 retires on a
  # fully-accepted window's bonus token (the perfect draft agrees always)
  budget = 1 + 2 * (k + 1)

  cache = PrefixCache(capacity_mb=8)
  spec = LMEngine(cfg, params, batch_size=1, max_len=64, speculate=k,
                  draft_params=params, prefix_cache=cache,
                  publish_on_retire=True)
  spec.submit(prompt, max_new_tokens=budget)
  turn1 = spec.run(temperature=temperature,
                   rng=jax.random.PRNGKey(9))[0].tokens
  assert spec.accept_rate == 1.0                  # the draft IS the target

  follow = np.concatenate([prompt, turn1])
  hits0 = cache.stats()["hits"]
  spec.submit(follow, max_new_tokens=6)
  got = spec.run()[0].tokens
  assert cache.stats()["hits"] > hits0            # the retired prefix hit

  van = LMEngine(cfg, params, batch_size=1, max_len=64)
  van.submit(follow, max_new_tokens=6)
  np.testing.assert_array_equal(got, van.run()[0].tokens)


def test_partial_accept_retirement_drops_publish():
  """The dual guard: a budget ending MID-window (commit < k+1) retires a
  slot whose carries sit at post-window values — its publish must drop
  (no replay ran: the lone slot emptied `live`), and the follow-up turn
  must stay correct through the cold path."""
  cfg = configs.get_smoke("zamba2-7b").with_(dtype=jnp.float32,
                                             vocab_size=48)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompt = np.arange(1, 7)
  k = 2
  budget = 1 + 2 * (k + 1) + 1      # one token into the third window

  cache = PrefixCache(capacity_mb=8)
  spec = LMEngine(cfg, params, batch_size=1, max_len=64, speculate=k,
                  draft_params=params, prefix_cache=cache,
                  publish_on_retire=True)
  spec.submit(prompt, max_new_tokens=budget)
  turn1 = spec.run()[0].tokens
  # the retirement publish was dropped: no entry covers prompt+answer
  # (admission's prompt-level entries remain, which is fine — they hold
  # committed prefill state); a deeper lookup stops at the prompt
  cached, _ = cache.lookup(np.concatenate([prompt, turn1[:-1]]))
  assert cached <= prompt.size

  follow = np.concatenate([prompt, turn1])
  spec.submit(follow, max_new_tokens=6)
  got = spec.run()[0].tokens
  van = LMEngine(cfg, params, batch_size=1, max_len=64)
  van.submit(follow, max_new_tokens=6)
  np.testing.assert_array_equal(got, van.run()[0].tokens)
