"""CTC loss against brute-force path enumeration."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ctc import ctc_greedy_decode, ctc_loss


def brute_force_ctc(log_probs, labels, blank=0):
  """-log sum over all alignments (exponential; tiny cases only)."""
  t, v = log_probs.shape
  target = list(labels)
  total = -np.inf
  for path in itertools.product(range(v), repeat=t):
    # collapse repeats then remove blanks
    collapsed = []
    prev = None
    for s in path:
      if s != prev:
        collapsed.append(s)
      prev = s
    decoded = [s for s in collapsed if s != blank]
    if decoded == target:
      lp = sum(log_probs[i, s] for i, s in enumerate(path))
      total = np.logaddexp(total, lp)
  return -total


@pytest.mark.parametrize("labels", [[1], [1, 2], [1, 1], [2, 1, 2]])
def test_ctc_matches_brute_force(labels):
  rng = np.random.RandomState(len(labels))
  t, v = 5, 4
  logits = rng.randn(t, v)
  log_probs = logits - np.log(np.sum(np.exp(logits), axis=-1,
                                     keepdims=True))
  want = brute_force_ctc(log_probs, labels)

  lp = jnp.asarray(log_probs)[None]
  got = float(ctc_loss(lp, jnp.array([t]),
                       jnp.array([labels + [0] * (4 - len(labels))]),
                       jnp.array([len(labels)])))
  np.testing.assert_allclose(got, want, rtol=1e-4)


def test_ctc_respects_lengths():
  """Frames past logit_lengths must not affect the loss."""
  rng = np.random.RandomState(0)
  lp_short = rng.randn(1, 4, 5)
  lp_short = lp_short - np.log(np.sum(np.exp(lp_short), -1, keepdims=True))
  lp_long = np.concatenate([lp_short, rng.randn(1, 3, 5)], axis=1)
  labels = jnp.array([[1, 2, 0]])
  lens = jnp.array([2])
  a = float(ctc_loss(jnp.asarray(lp_short), jnp.array([4]), labels, lens))
  b = float(ctc_loss(jnp.asarray(lp_long), jnp.array([4]), labels, lens))
  np.testing.assert_allclose(a, b, rtol=1e-5)


def test_greedy_decode_collapses():
  # path: blank a a blank b -> [a, b]
  lp = np.full((1, 5, 3), -10.0)
  path = [0, 1, 1, 0, 2]
  for t, s in enumerate(path):
    lp[0, t, s] = 0.0
  out = np.asarray(ctc_greedy_decode(jnp.asarray(lp), jnp.array([5])))
  decoded = out[0][out[0] >= 0].tolist()
  assert decoded == [1, 2]
