"""Multi-device tests (subprocess with forced 8-device CPU topology):
sharding rules produce valid shardings, a sharded train step runs and
matches single-device numerics, compressed psum works under shard_map,
and checkpoints reshard elastically (save sharded, load resharded)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str) -> str:
  code = textwrap.dedent("""
      import os
      os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
      import jax
      import jax.numpy as jnp
      import numpy as np
      assert len(jax.devices()) == 8
  """) + textwrap.dedent(body)
  env = dict(os.environ,
             PYTHONPATH=os.path.join(ROOT, "src"),
             XLA_FLAGS="--xla_force_host_platform_device_count=8")
  out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
  assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
  return out.stdout


def test_sharded_train_step_matches_single_device():
  run_in_subprocess("""
      from repro import configs
      from repro.dist.mesh import make_mesh
      from repro.dist.sharding import make_constraint
      from repro.data.lm import LMDataConfig, batch_at
      from repro.models.api import get_model

      cfg = configs.get_smoke("llama3-8b").with_(vocab_size=64,
                                                 dtype=jnp.float32)
      api = get_model(cfg)
      params = api.init(jax.random.PRNGKey(0), cfg)
      dc = LMDataConfig(vocab_size=64, seq_len=32, global_batch=8)
      batch = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}

      mesh = make_mesh((4, 2), ("data", "model"), devices=jax.devices())
      cs = make_constraint(mesh, cfg, 8)
      with mesh:
          sharded = jax.jit(
              lambda p, b: api.loss_fn(p, b, cfg, cs)[0])(params, batch)
      plain = jax.jit(lambda p, b: api.loss_fn(p, b, cfg)[0])(params, batch)
      np.testing.assert_allclose(float(sharded), float(plain), rtol=2e-4)
      print("sharded loss ok", float(sharded))
  """)


def test_param_shardings_cover_tree():
  run_in_subprocess("""
      from repro import configs
      from repro.dist.mesh import make_mesh
      from repro.dist.sharding import param_shardings
      from repro.models.api import get_model

      mesh = make_mesh((4, 2), ("data", "model"), devices=jax.devices())
      for arch in ["llama3-8b", "deepseek-v2-lite", "zamba2-7b",
                   "xlstm-350m", "deepspeech2-wsj"]:
          cfg = configs.get_smoke(arch)
          sds = configs.param_specs(cfg)
          sh = param_shardings(sds, mesh)
          n = len(jax.tree.leaves(sh))
          m = len(jax.tree.leaves(sds))
          assert n == m, (arch, n, m)
      print("coverage ok")
  """)


def test_compressed_psum_shard_map():
  run_in_subprocess("""
      from functools import partial
      from jax.sharding import PartitionSpec as P
      from jax.experimental.shard_map import shard_map
      from repro.dist.mesh import make_mesh
      from repro.optim.compress import compressed_psum

      mesh = make_mesh((8,), ("pod",), devices=jax.devices())
      x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 7.0
      err0 = jnp.zeros((8, 16), jnp.float32)

      @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
               out_specs=(P("pod"), P("pod")))
      def f(xs, es):
          m, e = compressed_psum(xs[0], "pod", es[0])
          return m[None], e[None]

      mean, err = f(x, err0)
      want = jnp.mean(x, axis=0)
      got = mean[0]
      rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
      assert rel < 0.02, rel
      # error feedback: residual equals what quantization dropped
      assert float(jnp.max(jnp.abs(err))) < float(jnp.max(jnp.abs(x))) / 50
      print("compressed psum ok", rel)
  """)


def test_elastic_checkpoint_reshard():
  run_in_subprocess("""
      import tempfile
      from jax.sharding import NamedSharding, PartitionSpec as P
      from repro.checkpoint import CheckpointManager
      from repro.dist.mesh import make_mesh

      d = tempfile.mkdtemp()
      mesh8 = make_mesh((8,), ("data",), devices=jax.devices())
      x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                         NamedSharding(mesh8, P("data", None)))
      mgr = CheckpointManager(d)
      mgr.save(0, {"x": x})

      # reload onto a DIFFERENT topology (4 devices, model axis)
      mesh4 = make_mesh((4,), ("model",), devices=jax.devices()[:4])
      tgt = NamedSharding(mesh4, P(None, "model"))
      restored, _ = mgr.restore({"x": x}, shardings={"x": tgt})
      np.testing.assert_allclose(np.asarray(restored["x"]), np.asarray(x))
      assert restored["x"].sharding == tgt
      print("elastic reshard ok")
  """)


def test_decode_state_shardings_long_context():
  run_in_subprocess("""
      from repro import configs
      from repro.dist.mesh import make_mesh
      from repro.dist.sharding import state_shardings
      from repro.layers.common import SHAPES

      mesh = make_mesh((4, 2), ("data", "model"), devices=jax.devices())
      cfg = configs.get_config("zamba2-7b")
      shape = SHAPES["long_500k"]
      sds = configs.decode_state_specs(cfg, shape)
      sh = state_shardings(sds, mesh, shape)
      flat = jax.tree.leaves(sh)
      # at least the KV caches must shard the 524288-long axis
      specs = [s.spec for s in flat]
      assert any(any(p is not None for p in (spec or ())) for spec in specs)
      print("state shardings ok")
  """)


def test_mini_dryrun_cell():
  """CI-sized dry-run: lower+compile one train cell and one decode cell on
  an 8-device (4, 2) mesh through the real dryrun builders, and check the
  roofline extraction produces sane terms."""
  run_in_subprocess("""
      from repro import configs
      from repro.dist import hlo_cost
      from repro.dist.mesh import make_mesh
      from repro.launch import dryrun
      from repro.layers.common import ShapeConfig

      mesh = make_mesh((4, 2), ("data", "model"), devices=jax.devices())
      cfg = configs.get_smoke("llama3-8b")
      train = ShapeConfig("train_mini", "train", 64, 8)
      fn, args, in_sh, out_sh = dryrun.build_train(cfg, train, mesh, "adamw",
                                                   microbatches=2)
      with mesh:
          compiled = jax.jit(fn, in_shardings=in_sh,
                             out_shardings=out_sh).lower(*args).compile()
      rep = hlo_cost.analyze_module(compiled.as_text(), 8)
      assert rep.flops > 0 and rep.hbm_bytes > 0
      roof = hlo_cost.roofline_from_report(rep)
      assert roof.dominant in ("compute", "memory", "collective")

      decode = ShapeConfig("decode_mini", "decode", 64, 8)
      fn, args, in_sh, out_sh = dryrun.build_decode(cfg, decode, mesh, False)
      with mesh:
          compiled = jax.jit(fn, in_shardings=in_sh,
                             out_shardings=out_sh).lower(*args).compile()
      rep2 = hlo_cost.analyze_module(compiled.as_text(), 8)
      assert rep2.flops >= 0 and rep2.hbm_bytes > 0
      print("mini dryrun ok", roof.dominant)
  """)
