"""Per-arch smoke tests: every assigned architecture instantiates at a
reduced config and runs one forward/train step on CPU with finite loss
and correct shapes (the full configs are exercised via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model

LM_ARCHS = [n for n in configs.ARCH_NAMES
            if configs.get_smoke(n).family in
            ("transformer", "zamba", "xlstm")]


def _lm_batch(cfg, b=2, s=32):
  rng = np.random.RandomState(0)
  toks = rng.randint(0, cfg.vocab_size, size=(b, s + 1))
  return {"tokens": jnp.asarray(toks[:, :-1]),
          "targets": jnp.asarray(toks[:, 1:])}


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_train_step(arch):
  # f32 on CPU: the CPU backend's DotThunk lacks bf16 x bf16 -> f32
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)

  if cfg.family == "deepspeech":
    from repro.data.speech import SpeechDataConfig, batch_at
    batch = batch_at(SpeechDataConfig(vocab_size=cfg.vocab_size,
                                      feat_dim=cfg.feat_dim,
                                      global_batch=2), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
  elif cfg.family == "whisper":
    b = _lm_batch(cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    batch = {"frames": frames, **b}
  else:
    batch = _lm_batch(cfg)

  (loss, metrics), grads = jax.value_and_grad(
      lambda p: api.loss_fn(p, batch, cfg), has_aux=True)(params)
  assert jnp.isfinite(loss), f"{arch} loss not finite"
  gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
  assert np.isfinite(gnorm) and gnorm > 0, f"{arch} grads degenerate"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_shapes(arch):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  batch = _lm_batch(cfg, b=2, s=32)
  logits, aux = api.forward(params, batch["tokens"], cfg)
  assert logits.shape == (2, 32, cfg.vocab_size)
  assert not bool(jnp.isnan(logits).any())
  # last_only narrows to one position (the serving-prefill lowering)
  last, _ = api.forward(params, batch["tokens"], cfg, last_only=True)
  assert last.shape == (2, 1, cfg.vocab_size)
  np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                             np.asarray(logits[:, -1], np.float32),
                             atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", LM_ARCHS + ["whisper-small"])
def test_smoke_decode_step(arch):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  state = api.init_decode_state(cfg, 2, 64)
  if cfg.family == "whisper":
    state["mem"] = jax.random.normal(jax.random.PRNGKey(1),
                                     (2, 16, cfg.d_model), cfg.dtype)
  tok = jnp.array([[1], [2]], jnp.int32)
  pos = jnp.zeros((2,), jnp.int32)
  logits, new_state = api.decode_step(params, state, tok, pos, cfg)
  assert logits.shape == (2, 1, cfg.vocab_size)
  assert not bool(jnp.isnan(logits).any())
  assert jax.tree.structure(state) == jax.tree.structure(new_state)


def test_full_config_param_counts():
  """Full configs hit their published scales (eval_shape, no allocation)."""
  expected = {
      "llama3-8b": (7.5e9, 9.0e9),
      "chameleon-34b": (33e9, 36e9),
      "deepseek-v3-671b": (650e9, 690e9),
      "deepseek-v2-lite": (14e9, 18e9),
      "zamba2-7b": (6.0e9, 8.0e9),
      "xlstm-350m": (0.35e9, 0.45e9),   # incl. untied 50k-vocab embeddings
      "qwen3-4b": (3.5e9, 5.0e9),
      "stablelm-3b": (2.5e9, 3.2e9),
      "glm4-9b": (9e9, 10.5e9),
      "whisper-small": (0.2e9, 0.3e9),
  }
  for arch, (lo, hi) in expected.items():
    sds = configs.param_specs(configs.get_config(arch))
    total = sum(np.prod(x.shape) for x in jax.tree.leaves(sds))
    assert lo < total < hi, f"{arch}: {total/1e9:.2f}B outside [{lo},{hi}]"
