"""Lossless self-speculative decoding.

The whole feature is pinned by parity: greedy speculative decode must be
token-for-token IDENTICAL to vanilla greedy — acceptance only changes how
many tokens an iteration yields, never their values. The grid covers an
attention family (qwen3: positional overwrite-rewind) and an SSM hybrid
(zamba: carry snapshot/replay), both kernel policies, and draft ranks
from near-full (accept -> 1) to pathologically low (accept -> 0), under
continuous batching with mixed lengths and slot refill.

Plus: the decode_window == sequential-steps parity the acceptance rests
on (bitwise where the backend delivers it, token-for-token everywhere —
the full grid lives in test_spec_window_parity), the decode_state_carry
contract per family, accept-rate accounting (the acceptance criterion),
retirement boundaries (EOS / budget / max_len) inside a speculative
window, draft GEMM kernel routing, and temperature > 0 end-to-end
(rejection sampling; distribution parity lives in
test_spec_window_parity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import dispatch
from repro.models.api import get_model
from repro.serving import LMEngine, make_draft_params

# mixed prompt lengths + budgets, 2x the slots -> refill mid-run
PROMPT_LENS = (3, 7, 2, 5, 8, 4)
BUDGETS = (4, 8, 3, 6, 2, 5)

SANE_RANK = 128        # ~full rank on the 128-dim smoke GEMMs: accept -> 1
PATHOLOGICAL_RANK = 8  # random-init spectra are flat: accept -> 0


def _params_for(arch, **with_kw):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32, **with_kw)
  api = get_model(cfg)
  return cfg, api, api.init(jax.random.PRNGKey(0), cfg)


def _mixed_requests(vocab):
  rng = np.random.RandomState(7)
  return [rng.randint(1, vocab, size=(l,)) for l in PROMPT_LENS]


def _run_requests(eng, prompts, budgets):
  uids = [eng.submit(p, max_new_tokens=n)
          for p, n in zip(prompts, budgets)]
  return uids, {f.uid: f for f in eng.run()}


def _assert_parity(ref_uids, ref, got_uids, got):
  for ru, gu in zip(ref_uids, got_uids):
    np.testing.assert_array_equal(got[gu].tokens, ref[ru].tokens)
    assert got[gu].finish_reason == ref[ru].finish_reason


# ---------------------------------------------------------------------------
# The foundation: a fused window computes exactly the sequential steps.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,bitwise", [("qwen3-4b", True),
                                          ("zamba2-7b", True),
                                          ("xlstm-350m", False)])
def test_decode_window_matches_sequential_steps(arch, bitwise):
  """The batched decode_window computes what W sequential decode_steps
  compute — the invariant verification's losslessness rests on.

  For qwen3 (causal attention over the KV cache) and zamba (attention +
  elementwise SSM scan) the batched program is BIT-identical to the
  lone steps. xLSTM's batched program is mathematically the same
  operations, but XLA's CPU fusion contexts differ between the two
  program shapes, so its mLSTM C/n accumulators land within a few ulp
  (~1e-6 relative) of the sequential values — there the contract is the
  one acceptance actually needs, token-for-token argmax equality, plus
  a tight allclose. The full family x policy grid (and the same split)
  lives in test_spec_window_parity."""
  cfg, api, params = _params_for(arch, vocab_size=64)
  b, W = 3, 4
  state = api.init_decode_state(cfg, b, 16)
  toks = jnp.asarray(np.random.RandomState(0).randint(1, 64, size=(b, W)),
                     jnp.int32)
  pos = jnp.zeros((b,), jnp.int32)

  step = jax.jit(lambda p, s, t, q: api.decode_step(p, s, t, q, cfg))
  st, seq = state, []
  for t in range(W):
    lg, st = step(params, st, toks[:, t:t + 1], pos + t)
    seq.append(np.asarray(lg[:, 0], np.float32))

  lgw, stw = jax.jit(
      lambda p, s, t, q: api.decode_window(p, s, t, q, cfg))(
          params, state, toks, pos)
  seq = np.stack(seq, 1)
  lgw = np.asarray(lgw)
  if bitwise:
    np.testing.assert_array_equal(seq, lgw)
    for a, b_ in zip(jax.tree.leaves(st), jax.tree.leaves(stw)):
      np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
  else:
    np.testing.assert_array_equal(seq.argmax(-1), lgw.argmax(-1))
    np.testing.assert_allclose(seq, lgw, rtol=1e-4, atol=1e-4)
    for a, b_ in zip(jax.tree.leaves(st), jax.tree.leaves(stw)):
      np.testing.assert_allclose(np.asarray(a, np.float32),
                                 np.asarray(b_, np.float32),
                                 rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite",
                                  "zamba2-7b", "xlstm-350m",
                                  "whisper-small", "deepspeech2-wsj"])
def test_decode_state_carry_contract(arch):
  """decode_state_carry mirrors the decode-state structure (like the
  batch-axes contract) and classifies every attention-KV leaf as
  positionally rewindable."""
  cfg = configs.get_smoke(arch)
  api = get_model(cfg)
  axes = api.decode_state_batch_axes(cfg)
  carry = api.decode_state_carry(cfg)
  assert jax.tree.structure(axes) == jax.tree.structure(carry)
  assert all(isinstance(x, bool) for x in jax.tree.leaves(carry))
  flat, _ = jax.tree_util.tree_flatten_with_path(carry)
  for path, is_carry in flat:
    leaf_name = path[-1].key if path else ""
    if leaf_name in ("k", "v", "c_kv", "k_rope", "mem"):
      assert not is_carry, (arch, path)


# ---------------------------------------------------------------------------
# The acceptance grid: speculative greedy == vanilla greedy.
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("policy", [None, "pallas"])
@pytest.mark.parametrize("rank", [SANE_RANK, PATHOLOGICAL_RANK])
@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
def test_speculative_matches_vanilla_greedy(arch, rank, policy):
  """Token-for-token parity across family x kernel policy x draft rank,
  6 mixed-length requests through 3 slots (refill mid-run)."""
  cfg, _, params = _params_for(arch, vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)
  kw = dict(batch_size=3, max_len=32, kernel_policy=policy)

  van = LMEngine(cfg, params, **kw)
  ref_uids, ref = _run_requests(van, prompts, BUDGETS)
  assert van.decode_steps * 3 > van.busy_slot_steps > 0   # refill happened

  spec = LMEngine(cfg, params, speculate=2,
                  draft_params=make_draft_params(params, rank=rank), **kw)
  got_uids, got = _run_requests(spec, prompts, BUDGETS)
  _assert_parity(ref_uids, ref, got_uids, got)
  if rank == SANE_RANK:
    assert spec.accept_rate > 0.5       # the acceptance criterion
  else:
    assert spec.accept_rate < 0.5       # ...and parity held regardless


@pytest.mark.slow
@pytest.mark.parametrize("k", [1, 3, 4])
def test_speculative_k_sweep(k):
  """Parity is independent of the window length."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)
  draft = make_draft_params(params, rank=SANE_RANK)
  van = LMEngine(cfg, params, batch_size=3, max_len=32)
  ref_uids, ref = _run_requests(van, prompts, BUDGETS)
  spec = LMEngine(cfg, params, batch_size=3, max_len=32, speculate=k,
                  draft_params=draft)
  got_uids, got = _run_requests(spec, prompts, BUDGETS)
  _assert_parity(ref_uids, ref, got_uids, got)
  # high acceptance must actually shrink the target's weight passes
  assert spec.decode_steps < van.decode_steps


def test_speculative_xlstm_family():
  """Fast-tier coverage of the all-carry family (every state leaf
  snapshot/replayed)."""
  cfg, _, params = _params_for("xlstm-350m", vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)[:4]
  budgets = BUDGETS[:4]
  van = LMEngine(cfg, params, batch_size=2, max_len=32)
  ref_uids, ref = _run_requests(van, prompts, budgets)
  spec = LMEngine(cfg, params, batch_size=2, max_len=32, speculate=2,
                  draft_params=make_draft_params(params, rank=SANE_RANK))
  got_uids, got = _run_requests(spec, prompts, budgets)
  _assert_parity(ref_uids, ref, got_uids, got)


# ---------------------------------------------------------------------------
# Retirement boundaries inside a speculative window.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_speculative_eos_mid_window():
  """EOS inside an accepted window retires at exactly the vanilla step."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)
  draft = make_draft_params(params, rank=SANE_RANK)

  probe = LMEngine(cfg, params, batch_size=1, max_len=32)
  probe.submit(prompts[1], max_new_tokens=8)
  eos_id = int(probe.run()[0].tokens[2])

  van = LMEngine(cfg, params, batch_size=2, max_len=32, eos_id=eos_id)
  ref_uids, ref = _run_requests(van, prompts, BUDGETS)
  spec = LMEngine(cfg, params, batch_size=2, max_len=32, eos_id=eos_id,
                  speculate=3, draft_params=draft)
  got_uids, got = _run_requests(spec, prompts, BUDGETS)
  _assert_parity(ref_uids, ref, got_uids, got)
  assert "eos" in {ref[u].finish_reason for u in ref_uids}


def test_speculative_max_len_boundary():
  """A window overrunning the cache must not corrupt it: out-of-bounds
  draft writes fall off (JAX scatter drops them) and the slot retires at
  the same "max_len" step as vanilla, with identical tokens."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  draft = make_draft_params(params, rank=SANE_RANK)
  prompt = np.array([1, 2, 3, 4])

  van = LMEngine(cfg, params, batch_size=1, max_len=8)
  van.submit(prompt, max_new_tokens=100)
  want = van.run()[0]
  assert want.finish_reason == "max_len"

  spec = LMEngine(cfg, params, batch_size=1, max_len=8, speculate=4,
                  draft_params=draft)
  spec.submit(prompt, max_new_tokens=100)
  got = spec.run()[0]
  assert got.finish_reason == "max_len"
  np.testing.assert_array_equal(got.tokens, want.tokens)


# ---------------------------------------------------------------------------
# Accounting, routing, guards, construction.
# ---------------------------------------------------------------------------


def test_generation_result_accept_rate():
  """generate() reports the measured accept rate; near-full-rank drafts
  clear the > 0.5 acceptance criterion. Both accept-rate surfaces agree
  that None means "nothing drafted" — a vanilla engine and a freshly
  built speculative engine report None, never a fake 0.0."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  spec = LMEngine(cfg, params, batch_size=2, max_len=32, speculate=2,
                  draft_params=make_draft_params(params, rank=SANE_RANK))
  assert spec.accept_rate is None          # no data yet, not 0.0
  out = spec.generate(prompts, steps=8)
  assert out.accept_rate is not None and out.accept_rate > 0.5
  assert spec.accept_rate == out.accept_rate
  assert spec.accepted_tokens <= spec.drafted_tokens

  van = LMEngine(cfg, params, batch_size=2, max_len=32)
  assert van.generate(prompts, steps=4).accept_rate is None
  assert van.accept_rate is None


def test_accept_accounting_caps_at_commit():
  """accepted_tokens counts REALIZED acceptance: min(accept, commit) per
  slot per window — a mid-window retirement (here: token budget 1 with
  an agreeing draft) must not count drafts the window agreed on but the
  slot never emitted, so accepted <= emitted tokens always holds."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  spec = LMEngine(cfg, params, batch_size=1, max_len=32, speculate=4,
                  draft_params=make_draft_params(params, rank=SANE_RANK))
  # prefill emits token 1; the single decode window then emits exactly 1
  # more (budget 2), even though the near-full-rank draft accepts ~all 4
  spec.submit(np.array([1, 2, 3]), max_new_tokens=2)
  out = spec.run()[0]
  assert len(out.tokens) == 2
  emitted_in_windows = len(out.tokens) - 1   # first token is prefill's
  assert spec.drafted_tokens == 4
  assert spec.accepted_tokens <= emitted_in_windows


def test_draft_gemms_route_through_lowrank_kernel():
  """Under the pallas policy the draft's factored GEMMs classify as
  lowrank_gemm while the target's dense steps stay decode_matvec."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  draft = make_draft_params(params, rank=SANE_RANK)
  with dispatch.record_dispatch() as log:
    spec = LMEngine(cfg, params, batch_size=2, max_len=32,
                    kernel_policy="pallas", speculate=2,
                    draft_params=draft)
    spec.generate(np.array([[1, 2], [3, 4]]), steps=6)
  regimes = {r for _, r in log}
  assert "lowrank_gemm" in regimes      # draft
  assert "decode_matvec" in regimes     # target window + steps


def test_speculative_samples_at_temperature():
  """speculate=k at temperature > 0 runs end-to-end (rejection sampling
  retired the old greedy-only guard), reports a measured accept rate,
  and reproduces exactly under the same rng."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=32, speculate=2,
                 draft_params=make_draft_params(params, rank=SANE_RANK))
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  a = eng.generate(prompts, steps=8, temperature=0.8,
                   rng=jax.random.PRNGKey(11))
  assert a.accept_rate is not None
  assert (a.lengths == 8).all()
  eng.reset()
  b = eng.generate(prompts, steps=8, temperature=0.8,
                   rng=jax.random.PRNGKey(11))
  np.testing.assert_array_equal(a.tokens, b.tokens)
  assert a.accept_rate == b.accept_rate


def test_rank_controller_walks_toward_band():
  """An unreachable accept-rate band keeps raising the rank (clamped at
  max_rank), rebuilding the draft in place — the verify window program
  must never re-trace across a rank change."""
  from repro.serving import RankController
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  rc = RankController(band=(0.99, 1.0), step=32, interval=2, min_rank=8,
                      max_rank=80)
  eng = LMEngine(cfg, params, batch_size=2, max_len=64, speculate=2,
                 draft_rank=16, rank_controller=rc)
  for _ in range(4):
    eng.submit(np.arange(1, 10), max_new_tokens=16)
  eng.run()
  assert eng.rank_history                     # it adjusted at least once
  assert eng.draft_rank == 80                 # walked up, hit the clamp
  ranks = [old for _, old, _ in eng.rank_history] + [eng.draft_rank]
  assert ranks == sorted(ranks)               # monotone walk upward
  assert eng.compile_stats()["window"] == 1   # verify never re-jitted


def test_rank_controller_construction_guards():
  from repro.serving import RankController
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  with pytest.raises(ValueError, match="speculate"):
    LMEngine(cfg, params, batch_size=1, max_len=16,
             rank_controller=RankController())
  with pytest.raises(ValueError, match="draft_rank"):
    LMEngine(cfg, params, batch_size=1, max_len=16, speculate=2,
             rank_controller=RankController())
  with pytest.raises(ValueError, match="band"):
    RankController(band=(0.9, 0.5))


def test_make_draft_params_requires_a_match():
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  from repro.core.compress import FactorizationPlan
  with pytest.raises(ValueError, match="matched no GEMM leaf"):
    make_draft_params(params,
                      plan=FactorizationPlan(include=("no-such-gemm",)))


def test_speculative_engine_reset_reproduces():
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=32, speculate=2,
                 draft_params=make_draft_params(params, rank=SANE_RANK))
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  a = eng.generate(prompts, steps=6)
  eng.reset()
  b = eng.generate(prompts, steps=6)
  np.testing.assert_array_equal(a.tokens, b.tokens)
  assert a.accept_rate == b.accept_rate
