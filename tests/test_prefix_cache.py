"""Prefix-cache tests: trie semantics, byte-accounted LRU eviction, the
snapshot/splice contract per model family, and the serving guarantee —
cached-splice greedy output is token-for-token identical to cold serving
across families x kernel policies x float/PTQ weights, including under
eviction churn.

`match_longest_prefix` also carries a hypothesis property (maximality +
insert/lookup round-trip) against a dict-of-prefixes oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.serving import LMEngine, PrefixCache
from repro.serving.prefix_cache import _TOKEN_OVERHEAD_BYTES, snapshot_bytes


def _payload(nbytes: int):
  return {"x": np.zeros((nbytes,), np.uint8)}


# ---------------------------------------------------------------------------
# Trie semantics.
# ---------------------------------------------------------------------------


def test_match_longest_prefix_maximality():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2], "ab")
  c.insert([1, 2, 3, 4], "abcd")
  c.insert([5], "e")
  # the deepest inserted entry prefixing the query wins
  assert c.match_longest_prefix([1, 2, 3, 4, 9]) == (4, "abcd")
  # a partial edge match cannot host an entry
  assert c.match_longest_prefix([1, 2, 3, 9]) == (2, "ab")
  assert c.match_longest_prefix([1, 9]) == (0, None)
  assert c.match_longest_prefix([5, 5]) == (1, "e")
  assert c.match_longest_prefix([]) == (0, None)
  # pure: no counters moved
  assert c.hits == c.misses == 0


def test_edge_split_on_divergent_insert():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2, 3, 4], "deep")
  c.insert([1, 2, 9], "fork")      # splits the (1,2,3,4) edge at depth 2
  assert c.match_longest_prefix([1, 2, 3, 4]) == (4, "deep")
  assert c.match_longest_prefix([1, 2, 9, 7]) == (3, "fork")
  c.insert([1, 2], "mid")          # entry lands exactly on the split node
  assert c.match_longest_prefix([1, 2, 8]) == (2, "mid")


def test_common_prefix_len_sees_partial_edges():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2, 3, 4, 5, 6], "a")
  # no entry prefixes the query, but the trie has observed 4 shared
  # tokens — the fork-materialization signal
  assert c.match_longest_prefix([1, 2, 3, 4, 9, 9]) == (0, None)
  assert c.common_prefix_len([1, 2, 3, 4, 9, 9]) == 4
  assert c.common_prefix_len([7, 8]) == 0
  assert c.common_prefix_len([1, 2, 3, 4, 5, 6, 7]) == 6


def test_lookup_counts_and_refreshes_recency():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2], _payload(100))
  assert c.lookup([1, 2, 3])[0] == 2
  assert c.lookup([9])[0] == 0
  s = c.stats()
  assert (s["hits"], s["misses"]) == (1, 1)
  assert s["hit_rate"] == 0.5


# ---------------------------------------------------------------------------
# Byte accounting + LRU eviction.
# ---------------------------------------------------------------------------


def test_bytes_accounting_and_lru_eviction():
  kib = 1 << 10
  cap_entries = 3
  # each entry: 1 KiB payload + key overhead for a 2-token key
  per = kib + 2 * _TOKEN_OVERHEAD_BYTES
  c = PrefixCache(capacity_mb=cap_entries * per / (1 << 20))
  for i in range(cap_entries):
    assert c.insert([i, i], _payload(kib))
  assert c.bytes == cap_entries * per
  # touch entry 0 so entry 1 is now LRU
  assert c.lookup([0, 0])[0] == 2
  assert c.insert([7, 7], _payload(kib))
  s = c.stats()
  assert s["evictions"] == 1 and s["entries"] == cap_entries
  assert c.match_longest_prefix([1, 1])[0] == 0      # the LRU went
  assert c.match_longest_prefix([0, 0])[0] == 2      # the touched stayed
  assert c.bytes == cap_entries * per


def test_oversize_rejected_not_admitted():
  c = PrefixCache(capacity_mb=0.001)   # ~1 KiB
  assert not c.insert([1], _payload(1 << 20))
  assert c.stats()["rejected_oversize"] == 1
  assert len(c) == 0 and c.bytes == 0


def test_reinsert_replaces_payload_and_bytes():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2], _payload(100))
  b0 = c.bytes
  c.insert([1, 2], _payload(300))
  assert c.match_longest_prefix([1, 2])[1]["x"].size == 300
  assert c.bytes == b0 + 200
  assert len(c) == 1


def test_eviction_prunes_and_remerges_trie():
  c = PrefixCache(capacity_mb=1)
  c.insert([1, 2, 3, 4], "deep")
  c.insert([1, 2, 9], "fork")
  # evict everything via clear-less path: insert huge entries that force
  # LRU eviction of both, then verify lookups are clean and re-insert works
  per = snapshot_bytes(_payload(1 << 19))
  cap = c.capacity_bytes
  n_fit = cap // (per + _TOKEN_OVERHEAD_BYTES)
  for i in range(int(n_fit) + 1):
    c.insert([100 + i], _payload(1 << 19))
  assert c.match_longest_prefix([1, 2, 3, 4])[0] == 0
  assert c.match_longest_prefix([1, 2, 9])[0] == 0
  c.insert([1, 2, 3, 4], "again")
  assert c.match_longest_prefix([1, 2, 3, 4]) == (4, "again")


def test_invalid_args():
  with pytest.raises(ValueError):
    PrefixCache(capacity_mb=0)
  with pytest.raises(ValueError):
    PrefixCache(capacity_mb=1, fork_min_tokens=0)
  c = PrefixCache(capacity_mb=1)
  with pytest.raises(ValueError):
    c.insert([], "empty")
  with pytest.raises(ValueError):
    c.insert(np.zeros((2, 2), np.int32), "2d")


# ---------------------------------------------------------------------------
# hypothesis property: trie == dict-of-prefixes oracle.
# ---------------------------------------------------------------------------


def test_match_longest_prefix_property():
  hyp = pytest.importorskip("hypothesis")
  st = pytest.importorskip("hypothesis.strategies")

  keys = st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=6)
                  .map(tuple), min_size=0, max_size=12, unique=True)
  query = st.lists(st.integers(0, 3), min_size=0, max_size=8)

  @hyp.given(keys=keys, q=query)
  @hyp.settings(max_examples=200, deadline=None)
  def prop(keys, q):
    c = PrefixCache(capacity_mb=64)
    oracle = {}
    for k in keys:
      c.insert(list(k), ("payload", k))
      oracle[k] = ("payload", k)
    # round-trip: every inserted key matches itself exactly
    for k in keys:
      assert c.match_longest_prefix(list(k)) == (len(k), oracle[k])
    # maximality vs the oracle
    best = max((k for k in oracle if tuple(q[:len(k)]) == k),
               key=len, default=None)
    m, payload = c.match_longest_prefix(q)
    if best is None:
      assert (m, payload) == (0, None)
    else:
      assert m == len(best) and payload == oracle[best]

  prop()


# ---------------------------------------------------------------------------
# Snapshot/splice contract per family.
# ---------------------------------------------------------------------------

FAMILIES_FAST = ["qwen3-4b", "zamba2-7b"]
FAMILIES_SLOW = ["xlstm-350m", "deepseek-v2-lite"]


def _roundtrip(arch):
  """Decode t tokens, snapshot the prefix, splice into a fresh state:
  the spliced state must equal the decoded state bit-for-bit (rows past
  t are zeros in both — init state is zeros and the scatter only wrote
  [0, t))."""
  cfg = configs.get_smoke(arch).with_(vocab_size=64, dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  t, max_len = 5, 16
  state = api.init_decode_state(cfg, 1, max_len)
  toks = np.random.RandomState(0).randint(1, 64, size=(t,))
  for i in range(t):
    _, state = api.decode_step(params, state,
                               jnp.asarray([[toks[i]]], jnp.int32),
                               jnp.asarray([i], jnp.int32), cfg)
  snap = api.prefix_view(cfg, state, t)
  fresh = api.init_decode_state(cfg, 1, max_len)
  spliced = api.splice_prefix(cfg, fresh, snap)
  for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(spliced)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
  # and snapshot bytes are the accounting unit the cache charges
  assert snapshot_bytes(snap) > 0


@pytest.mark.parametrize("arch", FAMILIES_FAST)
def test_prefix_view_splice_roundtrip(arch):
  _roundtrip(arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILIES_SLOW)
def test_prefix_view_splice_roundtrip_slow(arch):
  _roundtrip(arch)


# ---------------------------------------------------------------------------
# Engine parity: cached-splice == cold, token-for-token.
# ---------------------------------------------------------------------------


def _shared_prefix_prompts(vocab=60, n_shared=4, share=6, suffix=4):
  rng = np.random.RandomState(0)
  shared = rng.randint(1, vocab, size=(share,))
  out = [np.concatenate([shared, rng.randint(1, vocab, size=(suffix,))])
         for _ in range(n_shared)]
  out.append(rng.randint(1, vocab, size=(5,)))   # one unrelated request
  return out


def _serve(cfg, params, prompts, cache, *, policy=None, budget=6):
  eng = LMEngine(cfg, params, batch_size=2, max_len=32,
                 kernel_policy=policy, prefix_cache=cache)
  for p in prompts:
    eng.submit(p, max_new_tokens=budget)
  return {f.uid: tuple(f.tokens) for f in eng.run()}, eng


def test_engine_cached_splice_parity_and_hits():
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = _shared_prefix_prompts()

  cold, ceng = _serve(cfg, params, prompts, None)
  warm, weng = _serve(cfg, params, prompts, PrefixCache(capacity_mb=64))
  assert warm == cold
  cs = weng.cache_stats()
  # fork materialization: the 2nd shared request publishes the template,
  # the 3rd onward splice it — hits, not just inserts
  assert cs["hits"] >= 2 and cs["inserts"] >= len(prompts)
  assert 0.0 < cs["hit_rate"] < 1.0
  # compile contract survives the splice path
  stats = weng.compile_stats()
  assert stats["step"] in (1, -1)
  if stats["step"] > 0:
    assert stats["prefill"] == len(stats["prefill_buckets"])
  # per-bucket invocation counts: every prefill call is attributed
  assert sum(stats["prefill_calls"].values()) >= len(prompts)
  assert set(stats["prefill_calls"]) == {
      f"{b}x{p}" for b, p in stats["prefill_buckets"]}
  # a cache-less engine exposes the same zeroed surface
  z = ceng.cache_stats()
  assert set(z) == set(cs) and z["hits"] == 0 and z["hit_rate"] == 0.0


def test_engine_parity_under_eviction_churn():
  """A capacity that holds ~2 entries forces eviction mid-serve; parity
  must be indifferent to WHAT the cache remembers."""
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = _shared_prefix_prompts()

  probe = PrefixCache(capacity_mb=64)
  _serve(cfg, params, prompts[:1], probe)
  per_entry = probe.bytes          # one published full-prompt snapshot

  tiny = PrefixCache(capacity_mb=2.5 * per_entry / (1 << 20))
  cold, _ = _serve(cfg, params, prompts, None)
  warm, _ = _serve(cfg, params, prompts, tiny)
  assert warm == cold
  assert tiny.stats()["evictions"] > 0
  assert tiny.bytes <= tiny.capacity_bytes


def test_publish_on_retire_multiturn_hit():
  """Turn 2 = turn-1 prompt + generated tokens + new user tokens: with
  publish_on_retire the whole served conversation is a cached prefix."""
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  rng = np.random.RandomState(1)
  cache = PrefixCache(capacity_mb=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=32,
                 prefix_cache=cache, publish_on_retire=True)
  eng.submit(rng.randint(1, 64, size=(6,)), max_new_tokens=4)
  f1 = eng.run()[0]
  assert f1.ttft_s is not None and f1.ttft_s > 0

  turn2 = np.concatenate([f1.prompt, f1.tokens,
                          rng.randint(1, 64, size=(2,))])
  h0 = cache.hits
  eng.submit(turn2, max_new_tokens=4)
  wf = eng.run()[0]
  assert cache.hits > h0

  ceng = LMEngine(cfg, params, batch_size=2, max_len=32)
  ceng.submit(turn2, max_new_tokens=4)
  np.testing.assert_array_equal(wf.tokens, ceng.run()[0].tokens)


@pytest.mark.slow
@pytest.mark.parametrize("quant", [False, True], ids=["float", "int8"])
@pytest.mark.parametrize("policy", [None, "pallas"])
@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
def test_cached_splice_parity_grid(arch, policy, quant):
  """The acceptance grid: cached-splice == cold token-for-token across
  an attention family and an SSM-hybrid family, jnp and Pallas kernel
  policies, float and PTQ'd weights, mixed prefix-share lengths."""
  cfg = configs.get_smoke(arch).with_(vocab_size=64, dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  if quant:
    from repro.quant import quantize_params
    params = quantize_params(params)
  rng = np.random.RandomState(2)
  shared = rng.randint(1, 64, size=(8,))
  # mixed prefix-share lengths, each depth occurring twice past the
  # first sighting: request 2 forks at depth 8 and publishes it, request
  # 3 hits it; request 4 forks at depth 5, request 5 hits that
  prompts = [np.concatenate([shared[:k], rng.randint(1, 64, size=(3,))])
             for k in (8, 8, 8, 5, 5)]
  prompts.append(rng.randint(1, 64, size=(4,)))

  cold, _ = _serve(cfg, params, prompts, None, policy=policy, budget=5)
  cache = PrefixCache(capacity_mb=64)
  warm, _ = _serve(cfg, params, prompts, cache, policy=policy, budget=5)
  assert warm == cold
  assert cache.stats()["hits"] >= 2
