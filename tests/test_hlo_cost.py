"""HLO cost parser: exact FLOPs through scan trip counts, collective
accounting, roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import hlo_cost


def test_scan_flops_exact():
  """5-iteration scan of one matmul: the parser multiplies through the
  while trip count (XLA's own cost_analysis counts the body once)."""
  def step(w, x):
    def body(h, _):
      return h @ w, None
    h, _ = jax.lax.scan(body, x, None, length=5)
    return jnp.sum(h)
  compiled = jax.jit(step).lower(
      jax.ShapeDtypeStruct((64, 64), jnp.float32),
      jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
  rep = hlo_cost.analyze_module(compiled.as_text(), 1)
  expected = 5 * 2 * 8 * 64 * 64
  assert abs(rep.flops - expected) / expected < 0.05, rep.flops


def test_dot_flops_shapes():
  def f(a, b):
    return a @ b
  compiled = jax.jit(f).lower(
      jax.ShapeDtypeStruct((32, 128), jnp.float32),
      jax.ShapeDtypeStruct((128, 16), jnp.float32)).compile()
  rep = hlo_cost.analyze_module(compiled.as_text(), 1)
  np.testing.assert_allclose(rep.flops, 2 * 32 * 128 * 16, rtol=0.01)


def test_shape_bytes():
  assert hlo_cost._shape_bytes("bf16[4,8]{1,0}") == 64
  assert hlo_cost._shape_bytes("f32[]") == 4
  assert hlo_cost._shape_bytes("(f32[2,2]{1,0}, s8[4]{0})") == 20
  assert hlo_cost._shape_bytes("pred[16]") == 16


def test_wire_factors():
  assert hlo_cost._wire_factor("all-reduce", 4) == 1.5
  assert hlo_cost._wire_factor("all-gather", 4) == 0.75
  assert hlo_cost._wire_factor("collective-permute", 8) == 1.0
  assert hlo_cost._wire_factor("all-reduce", 1) == 0.0


def test_roofline_dominance():
  rep = hlo_cost.CostReport(flops=197e12, hbm_bytes=819e9 * 2,
                            collective_wire_bytes=0.0)
  roof = hlo_cost.roofline_from_report(rep)
  assert roof.dominant == "memory"
  assert abs(roof.compute_s - 1.0) < 1e-6
  assert abs(roof.memory_s - 2.0) < 1e-6


def test_trip_count_regex_on_real_format():
  line = ('  %while.7 = (s32[], f32[2]{0}) while(%t), condition=%c, '
          'body=%b, backend_config={"known_trip_count":{"n":"12"},'
          '"known_init_step":{"init":"0","step":"1"}}')
  m = hlo_cost._TRIP_RE.search(line)
  assert m and m.group(1) == "12"
