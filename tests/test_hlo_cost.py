"""HLO cost parser: exact FLOPs through scan trip counts, collective
accounting, roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import hlo_cost


def test_scan_flops_exact():
  """5-iteration scan of one matmul: the parser multiplies through the
  while trip count (XLA's own cost_analysis counts the body once)."""
  def step(w, x):
    def body(h, _):
      return h @ w, None
    h, _ = jax.lax.scan(body, x, None, length=5)
    return jnp.sum(h)
  compiled = jax.jit(step).lower(
      jax.ShapeDtypeStruct((64, 64), jnp.float32),
      jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
  rep = hlo_cost.analyze_module(compiled.as_text(), 1)
  expected = 5 * 2 * 8 * 64 * 64
  assert abs(rep.flops - expected) / expected < 0.05, rep.flops


def test_dot_flops_shapes():
  def f(a, b):
    return a @ b
  compiled = jax.jit(f).lower(
      jax.ShapeDtypeStruct((32, 128), jnp.float32),
      jax.ShapeDtypeStruct((128, 16), jnp.float32)).compile()
  rep = hlo_cost.analyze_module(compiled.as_text(), 1)
  np.testing.assert_allclose(rep.flops, 2 * 32 * 128 * 16, rtol=0.01)


def test_shape_bytes():
  assert hlo_cost._shape_bytes("bf16[4,8]{1,0}") == 64
  assert hlo_cost._shape_bytes("f32[]") == 4
  assert hlo_cost._shape_bytes("(f32[2,2]{1,0}, s8[4]{0})") == 20
  assert hlo_cost._shape_bytes("pred[16]") == 16


def test_shape_bytes_subbyte_integral():
  """s4/u4 are 4-bit: byte totals round UP per array, never fractional."""
  assert hlo_cost._shape_bytes("s4[4,8]{1,0}") == 16
  assert hlo_cost._shape_bytes("s4[5]{0}") == 3      # 20 bits -> 3 bytes
  assert hlo_cost._shape_bytes("u4[3]{0}") == 2
  # rounding happens per array: two s4[5] are 3+3, not ceil(40/8)=5
  assert hlo_cost._shape_bytes("(s4[5]{0}, s4[5]{0})") == 6
  assert isinstance(hlo_cost._shape_bytes("(bf16[3]{0}, s4[7]{0})"), int)


def test_s4_module_bytes_are_integral():
  hlo = """
HloModule m

ENTRY %main (p: s4[5]) -> s4[5] {
  %p = s4[5]{0} parameter(0)
  ROOT %n = s4[5]{0} negate(s4[5]{0} %p)
}
"""
  rep = hlo_cost.analyze_module(hlo)
  assert rep.hbm_bytes == 6          # 3 result + 3 operand, whole bytes
  assert rep.unknown_ops == {}


def test_wire_factors():
  assert hlo_cost._wire_factor("all-reduce", 4) == 1.5
  assert hlo_cost._wire_factor("all-gather", 4) == 0.75
  assert hlo_cost._wire_factor("collective-permute", 8) == 1.0
  assert hlo_cost._wire_factor("all-reduce", 1) == 0.0


def test_roofline_dominance():
  rep = hlo_cost.CostReport(flops=197e12, hbm_bytes=819e9 * 2,
                            collective_wire_bytes=0.0)
  roof = hlo_cost.roofline_from_report(rep)
  assert roof.dominant == "memory"
  assert abs(roof.compute_s - 1.0) < 1e-6
  assert abs(roof.memory_s - 2.0) < 1e-6


def test_trip_count_regex_on_real_format():
  line = ('  %while.7 = (s32[], f32[2]{0}) while(%t), condition=%c, '
          'body=%b, backend_config={"known_trip_count":{"n":"12"},'
          '"known_init_step":{"init":"0","step":"1"}}')
  m = hlo_cost._TRIP_RE.search(line)
  assert m and m.group(1) == "12"


def test_conv_dim_labels_flops():
  """dim_labels place the output-feature dim inside the kernel shape:
  3x3x3->4 NHWC conv over a 2x8x8 image is 2*out_elems*(k_elems/o)."""
  hlo = """
HloModule m

ENTRY %main (x: f32[2,8,8,3], k: f32[3,3,3,4]) -> f32[2,8,8,4] {
  %x = f32[2,8,8,3]{3,2,1,0} parameter(0)
  %k = f32[3,3,3,4]{3,2,1,0} parameter(1)
  ROOT %conv = f32[2,8,8,4]{3,2,1,0} convolution(f32[2,8,8,3]{3,2,1,0} %x, f32[3,3,3,4]{3,2,1,0} %k), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
"""
  rep = hlo_cost.analyze_module(hlo)
  assert rep.flops == 2 * (2 * 8 * 8 * 4) * (3 * 3 * 3)
  assert rep.dot_flops == 0.0        # convs are compute, not GEMM volume
  assert rep.unknown_ops == {}


def test_nested_while_trip_counts_multiply():
  """known_trip_count composes through nesting: a dot inside an inner
  trip-5 while inside an outer trip-3 while counts 15 times."""
  hlo = """
HloModule m

%inner_body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,4]{1,0}) %p), index=0
  %h = f32[4,4]{1,0} get-tuple-element((s32[], f32[4,4]{1,0}) %p), index=1
  %d = f32[4,4]{1,0} dot(f32[4,4]{1,0} %h, f32[4,4]{1,0} %h), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(s32[] %i, f32[4,4]{1,0} %d)
}

%inner_cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,4]{1,0}) %p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

%outer_body (q: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %q = (s32[], f32[4,4]{1,0}) parameter(0)
  ROOT %w = (s32[], f32[4,4]{1,0}) while((s32[], f32[4,4]{1,0}) %q), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"5"}}
}

%outer_cond (q: (s32[], f32[4,4])) -> pred[] {
  %q = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4,4]{1,0}) %q), index=0
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[4,4]{1,0}) tuple(s32[] %z, f32[4,4]{1,0} %x)
  %loop = (s32[], f32[4,4]{1,0}) while((s32[], f32[4,4]{1,0}) %init), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %out = f32[4,4]{1,0} get-tuple-element((s32[], f32[4,4]{1,0}) %loop), index=1
}
"""
  rep = hlo_cost.analyze_module(hlo)
  assert rep.flops == 3 * 5 * (2 * 4 * 4 * 4)
  assert rep.dot_flops == rep.flops


def test_nested_scan_flops_real():
  """Same property through real XLA output: scan-of-scan lowers to
  nested whiles whose trip counts must multiply."""
  def step(w, x):
    def outer(h, _):
      def inner(h2, _):
        return h2 @ w, None
      h2, _ = jax.lax.scan(inner, h, None, length=4)
      return h2, None
    h, _ = jax.lax.scan(outer, x, None, length=3)
    return jnp.sum(h)
  compiled = jax.jit(step).lower(
      jax.ShapeDtypeStruct((64, 64), jnp.float32),
      jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
  rep = hlo_cost.analyze_module(compiled.as_text(), 1)
  expected = 3 * 4 * 2 * 8 * 64 * 64
  assert abs(rep.flops - expected) / expected < 0.05, rep.flops


def test_unparsed_lines_count_as_generic_traffic():
  """A line the splitter rejects still lands in the ledger: every shape
  token on it becomes generic HBM traffic plus an unknown_ops entry."""
  hlo = """
HloModule m

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %bad = f32[8]{0} mystery op with f32[16]{0} and no operand parens
  ROOT %n = f32[8]{0} negate(f32[8]{0} %p)
}
"""
  rep = hlo_cost.analyze_module(hlo)
  assert rep.unknown_ops == {"<unparsed>": 1}
  # 32 + 64 from the rejected line's tokens, 32 + 32 from the negate
  assert rep.hbm_bytes == (32 + 64) + (32 + 32)
