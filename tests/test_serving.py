"""Serving-layer tests.

Decode-path consistency: step-by-step cached decoding must reproduce the
full-sequence forward logits (catches every KV/SSM-cache bug class).

Continuous batching: the engine's mixed-length, EOS-retiring, slot-refilling
schedule must be invisible — every request's tokens match a dedicated
batch-1 engine token-for-token, under both jnp and Pallas kernel policies.

Plus the serving-correctness regressions: cache_dtype scoped to KV leaves,
max_len as a hard boundary, chunked streaming == full-utterance forward,
and slot-surgery round-trips per model family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.serving import LMEngine

DECODABLE = ["llama3-8b", "qwen3-4b", "glm4-9b", "stablelm-3b",
             "chameleon-34b", "deepseek-v2-lite", "zamba2-7b", "xlstm-350m"]


def _params_for(arch, **with_kw):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32, **with_kw)
  api = get_model(cfg)
  return cfg, api, api.init(jax.random.PRNGKey(0), cfg)


@pytest.mark.slow
@pytest.mark.parametrize("arch", DECODABLE)
def test_decode_matches_forward(arch):
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  if cfg.moe is not None:
    # ample capacity: capacity-based MoE drops tokens at train-time batch
    # statistics but never at decode batch=1 — a known train/serve
    # asymmetry, excluded from this numerical-consistency check
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  b, s = 2, 16
  toks = np.random.RandomState(0).randint(1, cfg.vocab_size, size=(b, s))
  toks = jnp.asarray(toks, jnp.int32)

  full_logits, _ = api.forward(params, toks, cfg)

  state = api.init_decode_state(cfg, b, s + 4)
  step_logits = []
  pos = jnp.zeros((b,), jnp.int32)
  for t in range(s):
    lg, state = api.decode_step(params, state, toks[:, t:t + 1], pos, cfg)
    step_logits.append(lg[:, 0])
    pos = pos + 1
  got = jnp.stack(step_logits, axis=1)

  lo = np.asarray(full_logits, np.float32)
  hi = np.asarray(got, np.float32)
  # compare softmax-normalized outputs (mlstm chunked vs stepwise and MLA
  # absorbed vs unabsorbed paths differ only by fp reassociation)
  pl = jax.nn.log_softmax(lo, -1)
  ph = jax.nn.log_softmax(hi, -1)
  np.testing.assert_allclose(ph, pl, atol=2e-2, rtol=2e-2)


def test_engine_greedy_deterministic():
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  eng = LMEngine(cfg, params, batch_size=2, max_len=32)
  a = eng.generate(prompts, steps=5).tokens
  eng.reset()
  b = eng.generate(prompts, steps=5).tokens
  np.testing.assert_array_equal(a, b)


def test_seeded_sampling_deterministic():
  """Sampled (temperature > 0) decoding is reproducible: an explicit key
  threaded through run()/generate() pins the stream, reset() restores
  the constructor key (regression: the RNG used to advance irreversibly,
  so no two runs — even after reset — could ever be compared)."""
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  key = jax.random.PRNGKey(7)

  eng = LMEngine(cfg, params, batch_size=2, max_len=32)
  a = eng.generate(prompts, steps=6, temperature=0.7, rng=key).tokens
  eng.reset()
  b = eng.generate(prompts, steps=6, temperature=0.7, rng=key).tokens
  np.testing.assert_array_equal(a, b)

  # two engines with the same explicit key agree too
  other = LMEngine(cfg, params, batch_size=2, max_len=32)
  c = other.generate(prompts, steps=6, temperature=0.7, rng=key).tokens
  np.testing.assert_array_equal(a, c)

  # reset() restores the constructor key: back-to-back sampled runs
  # with no explicit key are also reproducible now
  seeded = LMEngine(cfg, params, batch_size=2, max_len=32,
                    rng=jax.random.PRNGKey(3))
  d = seeded.generate(prompts, steps=6, temperature=0.7).tokens
  seeded.reset()
  e = seeded.generate(prompts, steps=6, temperature=0.7).tokens
  np.testing.assert_array_equal(d, e)


def test_engine_int8_kv_cache_runs():
  cfg = configs.get_smoke("llama3-8b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  eng = LMEngine(cfg, params, batch_size=2, max_len=32,
                 cache_dtype=jnp.float16)
  out = eng.generate(np.array([[1, 2], [3, 4]]), steps=3)
  assert out.tokens.shape == (2, 3)


# ---------------------------------------------------------------------------
# Continuous batching.
# ---------------------------------------------------------------------------

# mixed prompt lengths + budgets, 2x the slots -> refill mid-run
# (lengths stay <= 8 so every engine shares the same prefill buckets)
PROMPT_LENS = (3, 7, 2, 5, 8, 4)
BUDGETS = (4, 8, 3, 6, 2, 5)


def _mixed_requests(vocab):
  rng = np.random.RandomState(7)
  return [rng.randint(1, vocab, size=(l,)) for l in PROMPT_LENS]


def _reference_runs(cfg, params, prompts, budgets, *, policy=None,
                    eos_id=None):
  """Each request decoded alone in a dedicated batch-1 engine."""
  out = []
  for p, n in zip(prompts, budgets):
    eng = LMEngine(cfg, params, batch_size=1, max_len=32,
                   kernel_policy=policy, eos_id=eos_id)
    eng.submit(p, max_new_tokens=n)
    out.append(eng.run()[0])
  return out


@pytest.mark.slow
@pytest.mark.parametrize("policy", [None, "pallas"])
@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
def test_continuous_batching_parity(arch, policy):
  """Token-for-token parity with per-request decoding across an attention
  family and an SSM-hybrid family, jnp and Pallas kernel policies."""
  cfg, _, params = _params_for(arch, vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)

  eng = LMEngine(cfg, params, batch_size=3, max_len=32,
                 kernel_policy=policy)
  uids = [eng.submit(p, max_new_tokens=n)
          for p, n in zip(prompts, BUDGETS)]
  finished = {f.uid: f for f in eng.run()}
  assert sorted(finished) == sorted(uids)
  # 6 requests through 3 slots: refill happened and slots stayed busy
  assert eng.decode_steps * 3 > eng.busy_slot_steps > 0

  for uid, ref in zip(uids, _reference_runs(cfg, params, prompts, BUDGETS,
                                            policy=policy)):
    np.testing.assert_array_equal(finished[uid].tokens, ref.tokens)
    assert finished[uid].finish_reason == ref.finish_reason


@pytest.mark.slow
def test_eos_retirement_and_slot_refill():
  """EOS retires a slot mid-run at different steps per request; the freed
  slot is refilled from the queue; outputs still match batch-1 decoding."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompts = _mixed_requests(cfg.vocab_size)

  # pick an EOS id that actually occurs: the 2nd token of the longest run
  probe = _reference_runs(cfg, params, prompts, BUDGETS)
  eos_id = int(probe[1].tokens[1])

  eng = LMEngine(cfg, params, batch_size=2, max_len=32, eos_id=eos_id)
  uids = [eng.submit(p, max_new_tokens=n)
          for p, n in zip(prompts, BUDGETS)]
  finished = {f.uid: f for f in eng.run()}
  refs = _reference_runs(cfg, params, prompts, BUDGETS, eos_id=eos_id)

  reasons = set()
  for uid, ref in zip(uids, refs):
    np.testing.assert_array_equal(finished[uid].tokens, ref.tokens)
    assert finished[uid].finish_reason == ref.finish_reason
    reasons.add(finished[uid].finish_reason)
  assert "eos" in reasons          # at least one request hit EOS...
  assert "length" in reasons       # ...and at least one ran to budget
  lens = {len(finished[u].tokens) for u in uids
          if finished[u].finish_reason == "eos"}
  assert lens, "no EOS retirement happened"


def test_generate_queues_beyond_batch():
  """The static-batch wrapper accepts more rows than slots (extras queue)."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompts = np.random.RandomState(3).randint(1, 64, size=(5, 4))
  big = LMEngine(cfg, params, batch_size=5, max_len=32)
  small = LMEngine(cfg, params, batch_size=2, max_len=32)
  a = big.generate(prompts, steps=4).tokens
  b = small.generate(prompts, steps=4).tokens
  np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Regression: cache_dtype is scoped to attention KV leaves.
# ---------------------------------------------------------------------------


def test_cache_dtype_spares_ssm_state():
  """On an SSM-hybrid config, cache_dtype touches only the shared KV
  cache; Mamba2 carries keep full precision (regression: the old blanket
  cast downcast every float leaf of decode state)."""
  cfg, _, params = _params_for("zamba2-7b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=16,
                 cache_dtype=jnp.float16)
  assert eng.state["shared_kv"]["k"].dtype == jnp.float16
  assert eng.state["shared_kv"]["v"].dtype == jnp.float16
  # SSM recurrent carry must stay float32, the conv tail at cfg.dtype
  assert eng.state["main_ssm"]["ssm"].dtype == jnp.float32
  assert eng.state["main_ssm"]["conv"].dtype == cfg.dtype
  assert eng.state["tail_ssm"]["ssm"].dtype == jnp.float32
  # and the engine still decodes
  out = eng.generate(np.array([[1, 2], [3, 4]]), steps=3)
  assert out.tokens.shape == (2, 3)


def test_cache_dtype_casts_attention_cache():
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=16,
                 cache_dtype=jnp.float16)
  assert eng.state["dense"]["k"].dtype == jnp.float16
  assert eng.state["dense"]["v"].dtype == jnp.float16


# ---------------------------------------------------------------------------
# Regression: max_len is a hard boundary.
# ---------------------------------------------------------------------------


def test_max_len_retires_instead_of_wrapping():
  """A slot whose cache is full retires with reason "max_len"; its tokens
  are a clean prefix of an uncapped run (no scatter wraparound corrupting
  the cache and the logits)."""
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  prompt = np.array([1, 2, 3, 4])

  capped = LMEngine(cfg, params, batch_size=1, max_len=8)
  capped.submit(prompt, max_new_tokens=100)
  got = capped.run()[0]
  assert got.finish_reason == "max_len"
  # prefill fills 4 rows; 1 token from prefill logits + 4 decode writes
  assert len(got.tokens) == 5

  roomy = LMEngine(cfg, params, batch_size=1, max_len=32)
  roomy.submit(prompt, max_new_tokens=100)
  want = roomy.run()[0]
  np.testing.assert_array_equal(got.tokens, want.tokens[:len(got.tokens)])


def test_max_len_rejects_oversized_prompt():
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=1, max_len=8)
  with pytest.raises(ValueError, match="max_len"):
    eng.submit(np.arange(1, 10))
  with pytest.raises(ValueError, match="max_len"):
    eng.prefill(np.arange(1, 10)[None, :])


def test_generate_pads_rows_retired_at_max_len():
  cfg, _, params = _params_for("qwen3-4b", vocab_size=64)
  eng = LMEngine(cfg, params, batch_size=2, max_len=8)
  out = eng.generate(np.array([[1, 2, 3, 4], [5, 6, 7, 8]]), steps=10)
  assert out.tokens.shape == (2, 10)
  np.testing.assert_array_equal(out.lengths, [5, 5])
  assert (out.tokens[:, 5:] == 0).all()


# ---------------------------------------------------------------------------
# Slot surgery (ModelApi insert/extract/reset_slot).
# ---------------------------------------------------------------------------

SLOTTED = ["qwen3-4b", "deepseek-v2-lite", "zamba2-7b", "xlstm-350m",
           "whisper-small", "deepspeech2-wsj"]


@pytest.mark.parametrize("arch", SLOTTED)
def test_decode_state_batch_axes_contract(arch):
  """Every family's declared batch axes match the axis that actually
  varies with the batch argument of init_decode_state."""
  cfg = configs.get_smoke(arch)
  api = get_model(cfg)
  s2 = jax.eval_shape(lambda: api.init_decode_state(cfg, 2, 16))
  s3 = jax.eval_shape(lambda: api.init_decode_state(cfg, 3, 16))
  def axis(a, b):
    d = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
    assert len(d) == 1, (a.shape, b.shape)
    return d[0]
  assert jax.tree.map(axis, s2, s3) == api.decode_state_batch_axes(cfg)


@pytest.mark.parametrize("arch", SLOTTED)
def test_slot_surgery_roundtrip(arch):
  """insert_slot(extract_slot(state, i), j) moves one request's rows and
  nothing else; reset_slot restores a slot to its init values."""
  cfg = configs.get_smoke(arch)
  api = get_model(cfg)
  key = iter(jax.random.split(jax.random.PRNGKey(0), 64))
  randomize = lambda x: jax.random.normal(next(key), x.shape).astype(x.dtype)
  state = jax.tree.map(randomize, api.init_decode_state(cfg, 3, 16))
  axes = api.decode_state_batch_axes(cfg)

  slot1 = api.extract_slot(cfg, state, 1)
  moved = api.insert_slot(cfg, state, slot1, 2)
  for s, m, ax in zip(jax.tree.leaves(state), jax.tree.leaves(moved),
                      jax.tree.leaves(axes)):
    np.testing.assert_array_equal(np.take(np.asarray(m), 2, axis=ax),
                                  np.take(np.asarray(s), 1, axis=ax))
    np.testing.assert_array_equal(np.take(np.asarray(m), 0, axis=ax),
                                  np.take(np.asarray(s), 0, axis=ax))

  fresh = api.init_decode_state(cfg, 3, 16)
  wiped = api.reset_slot(cfg, state, 0, max_len=16)
  for w, f, s, ax in zip(jax.tree.leaves(wiped), jax.tree.leaves(fresh),
                         jax.tree.leaves(state), jax.tree.leaves(axes)):
    np.testing.assert_array_equal(np.take(np.asarray(w), 0, axis=ax),
                                  np.take(np.asarray(f), 0, axis=ax))
    np.testing.assert_array_equal(np.take(np.asarray(w), 1, axis=ax),
                                  np.take(np.asarray(s), 1, axis=ax))


# ---------------------------------------------------------------------------
# Streaming speech: chunked == full-utterance.
# ---------------------------------------------------------------------------


def _collapse(best_row):
  prev, out = -1, []
  for lab in best_row:
    if lab != 0 and lab != prev:
      out.append(int(lab))
    prev = lab
  return out


@pytest.mark.slow
def test_streaming_chunked_matches_full_utterance():
  """The conv frontend carries receptive-field context across chunk
  boundaries, so streamed CTC labels equal the full-utterance forward
  (regression: each chunk used to see its mel frames in isolation)."""
  from repro.models import deepspeech
  from repro.serving import StreamingSpeechServer
  cfg = configs.get_smoke("deepspeech2-wsj")
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  rng = np.random.RandomState(0)
  feats = rng.randn(2, 48, cfg.feat_dim).astype(np.float32)

  log_probs = deepspeech.forward(params, jnp.asarray(feats), cfg)
  best = np.asarray(jnp.argmax(log_probs, axis=-1))
  ref = [_collapse(best[i]) for i in range(2)]

  server = StreamingSpeechServer(cfg, params, batch_size=2)
  got = [[], []]
  # uneven chunks: context must survive arbitrary chunking
  for chunk in np.split(feats, [16, 28], axis=1):
    for i, e in enumerate(server.process_chunk(chunk)):
      got[i].extend(e)
  for i, e in enumerate(server.flush()):
    got[i].extend(e)
  assert got == ref

  # a redundant flush after finalizing must NOT re-pad the residual conv
  # buffer and emit a spurious label; new frames require reset()
  assert server.flush() == [[], []]
  with pytest.raises(RuntimeError, match="reset"):
    server.process_chunk(feats[:, :4])

  # a second utterance after reset() must not see stale context
  server.reset()
  got2 = [[], []]
  for i, e in enumerate(server.process_chunk(feats, final=True)):
    got2[i].extend(e)
  assert got2 == ref


def test_streaming_speech_server():
  from repro.data.speech import SpeechDataConfig, batch_at
  from repro.serving import StreamingSpeechServer
  cfg = configs.get_smoke("deepspeech2-wsj")
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  server = StreamingSpeechServer(cfg, params, batch_size=2)
  dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                        global_batch=2)
  chunk = batch_at(dc, 0)["feats"][:, :24]
  out = server.process_chunk(chunk)
  assert len(out) == 2           # per-stream emissions (may be empty)
