"""Decode-path consistency: step-by-step cached decoding must reproduce
the full-sequence forward logits (catches every KV/SSM-cache bug class).
Plus engine-level generation determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.serving import LMEngine

DECODABLE = ["llama3-8b", "qwen3-4b", "glm4-9b", "stablelm-3b",
             "chameleon-34b", "deepseek-v2-lite", "zamba2-7b", "xlstm-350m"]


@pytest.mark.parametrize("arch", DECODABLE)
def test_decode_matches_forward(arch):
  import dataclasses
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  if cfg.moe is not None:
    # ample capacity: capacity-based MoE drops tokens at train-time batch
    # statistics but never at decode batch=1 — a known train/serve
    # asymmetry, excluded from this numerical-consistency check
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  b, s = 2, 16
  toks = np.random.RandomState(0).randint(1, cfg.vocab_size, size=(b, s))
  toks = jnp.asarray(toks, jnp.int32)

  full_logits, _ = api.forward(params, toks, cfg)

  state = api.init_decode_state(cfg, b, s + 4)
  step_logits = []
  pos = jnp.zeros((b,), jnp.int32)
  for t in range(s):
    lg, state = api.decode_step(params, state, toks[:, t:t + 1], pos, cfg)
    step_logits.append(lg[:, 0])
    pos = pos + 1
  got = jnp.stack(step_logits, axis=1)

  lo = np.asarray(full_logits, np.float32)
  hi = np.asarray(got, np.float32)
  # compare softmax-normalized outputs (mlstm chunked vs stepwise and MLA
  # absorbed vs unabsorbed paths differ only by fp reassociation)
  pl = jax.nn.log_softmax(lo, -1)
  ph = jax.nn.log_softmax(hi, -1)
  np.testing.assert_allclose(ph, pl, atol=2e-2, rtol=2e-2)


def test_engine_greedy_deterministic():
  cfg = configs.get_smoke("qwen3-4b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts = np.array([[1, 2, 3], [4, 5, 6]])
  eng = LMEngine(cfg, params, batch_size=2, max_len=32)
  a = eng.generate(prompts, steps=5).tokens
  eng.reset()
  b = eng.generate(prompts, steps=5).tokens
  np.testing.assert_array_equal(a, b)


def test_engine_int8_kv_cache_runs():
  cfg = configs.get_smoke("llama3-8b").with_(vocab_size=64)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  eng = LMEngine(cfg, params, batch_size=2, max_len=32,
                 cache_dtype=jnp.float16)
  out = eng.generate(np.array([[1, 2], [3, 4]]), steps=3)
  assert out.tokens.shape == (2, 3)


def test_streaming_speech_server():
  from repro.data.speech import SpeechDataConfig, batch_at
  from repro.serving import StreamingSpeechServer
  cfg = configs.get_smoke("deepspeech2-wsj")
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  server = StreamingSpeechServer(cfg, params, batch_size=2)
  dc = SpeechDataConfig(vocab_size=cfg.vocab_size, feat_dim=cfg.feat_dim,
                        global_batch=2)
  chunk = batch_at(dc, 0)["feats"][:, :24]
  out = server.process_chunk(chunk)
  assert len(out) == 2           # per-stream emissions (may be empty)
