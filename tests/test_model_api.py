"""Dispatch coverage for the unified model API (models/api.get_model)."""
import dataclasses

import pytest

from repro import configs
from repro.models import (deepspeech, transformer, whisper, xlstm_model,
                          zamba)
from repro.models.api import ModelApi, get_model, identity_constraint

FAMILY_CASES = {
    # arch -> (family, implementing module)
    "llama3-8b": ("transformer", transformer),
    "zamba2-7b": ("zamba", zamba),
    "xlstm-350m": ("xlstm", xlstm_model),
    "whisper-small": ("whisper", whisper),
    "deepspeech2-wsj": ("deepspeech", deepspeech),
}


@pytest.mark.parametrize("arch", sorted(FAMILY_CASES))
def test_get_model_dispatches_all_families(arch):
  family, module = FAMILY_CASES[arch]
  api = get_model(configs.get_smoke(arch))
  assert isinstance(api, ModelApi)
  assert api.family == family
  assert api.loss_fn is module.loss_fn
  assert callable(api.init)
  assert callable(api.decode_step)


def test_moe_mla_configs_share_transformer_family():
  api = get_model(configs.get_smoke("deepseek-v2-lite"))
  assert api.family == "transformer"
  assert api.loss_fn is transformer.loss_fn


def test_decodable_property():
  for arch in FAMILY_CASES:
    assert get_model(configs.get_smoke(arch)).decodable
  # decodable is exactly "has a decode_step"
  api = ModelApi(family="stub", init=lambda k, c: {},
                 loss_fn=lambda p, b, c, cs=identity_constraint: (0.0, {}))
  assert not api.decodable
  assert dataclasses.replace(api, decode_step=lambda *a: None).decodable


def test_whisper_api_has_encoder_but_no_forward():
  api = get_model(configs.get_smoke("whisper-small"))
  assert api.encode is whisper.encode
  assert api.forward is None


def test_unknown_family_raises_value_error():
  bad = configs.get_smoke("llama3-8b").with_(family="gpt17")
  with pytest.raises(ValueError, match="gpt17"):
    get_model(bad)
