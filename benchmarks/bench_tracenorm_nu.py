"""Fig. 2 — nondimensional trace-norm coefficient nu(W) versus
regularization strength, by regularization type. The paper's headline
mechanism: trace-norm regularization drives nu down where l2 cannot
(until l2 is strong enough to destroy accuracy)."""
from __future__ import annotations

import numpy as np

from benchmarks.speech_runner import gemm_diagnostics, train_stage1

LAMBDAS = [0.0, 3e-5, 3e-4, 1e-3, 3e-3, 1e-2]


def run() -> list[dict]:
  rows = []
  for kind in ("trace", "l2"):
    for lam in LAMBDAS:
      out = train_stage1(kind, lam, lam)
      diag = gemm_diagnostics(out["params"])
      for name in ("gru2/nonrec", "gru2/rec"):      # third GRU layer
        if name in diag:
          rows.append({
              "bench": "fig2_nu_vs_lambda", "kind": kind, "lambda": lam,
              "gemm": name, "nu": diag[name]["nu"], "cer": out["cer"],
          })
      mean_nu = float(np.mean([d["nu"] for d in diag.values()]))
      rows.append({"bench": "fig2_nu_vs_lambda", "kind": kind,
                   "lambda": lam, "gemm": "<mean>", "nu": mean_nu,
                   "cer": out["cer"]})
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
