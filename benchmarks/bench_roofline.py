"""Roofline table — two surfaces:

* `run()` (the benchmarks/run.py driver): reads the dry-run artifacts
  (experiments/dryrun/*.json) and emits the three-term roofline per
  (arch x shape x mesh) with the dominant bottleneck and useful-FLOP
  fraction (EXPERIMENTS.md §Roofline).
* `--json` (CI): traces the smoke decode programs, pairs each one's
  STATIC ledger (repro.analysis.budgets.program_ledger — the exact
  numbers the budget gate pins) with a MEASURED wall-clock sample of
  the same jitted step, and writes BENCH_roofline.json. Static-vs-real
  drift is then visible per run: a static ledger that stops predicting
  the measured ranking is a parser gap or a model change the committed
  budgets haven't caught up with.
"""
from __future__ import annotations

import glob
import json
import os
import time

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_PATH = "BENCH_roofline.json"

#: the CI pairing grid: decode (the hot path) on two contrasting
#: families, both kernel policies, float + int8
PAIR_CONFIGS = ("qwen3-4b", "xlstm-350m")
STEPS = 20

#: program_ledger fields worth pairing against a wall-clock sample
STATIC_FIELDS = ("flops", "dot_flops", "hbm_bytes", "arithmetic_intensity",
                 "dominant", "roofline_fraction", "input_bytes",
                 "peak_live_bytes")


def run() -> list[dict]:
  rows = []
  for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
    with open(path) as f:
      d = json.load(f)
    rows.append({
        "bench": "roofline", "arch": d["arch"], "shape": d["shape"],
        "mesh": d["mesh"],
        "compute_s": round(d["compute_s"], 5),
        "memory_s": round(d["memory_s"], 5),
        "collective_s": round(d["collective_s"], 5),
        "dominant": d["dominant"],
        "useful_flops": round(d.get("useful_flop_fraction", 0.0), 3),
        "roofline_fraction": round(d.get("roofline_fraction", 0.0), 4),
    })
  if not rows:
    rows.append({"bench": "roofline",
                 "note": "run `python -m repro.launch.dryrun --all` first"})
  return rows


def _measure_decode(config: str, policy: str, quant: str) -> dict:
  """Wall-clock the smoke decode step at the audit geometry: jit once,
  run one warmup (compile), then average STEPS timed steps."""
  import jax
  import jax.numpy as jnp
  import numpy as np

  from repro import configs
  from repro.analysis.targets import BATCH, MAX_LEN
  from repro.kernels import dispatch
  from repro.layers.common import identity_constraint
  from repro.models.api import get_model
  from repro.quant.ptq import quantize_params

  cfg = configs.get_smoke(config)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  if quant == "int8":
    params = quantize_params(params)
  state = api.init_decode_state(cfg, BATCH, MAX_LEN)
  pol = (dispatch.JNP_ONLY if policy == "jnp"
         else dispatch.decode_policy(BATCH))
  cs = identity_constraint
  if cfg.family == "deepspeech":
    tok = jnp.asarray(np.zeros((BATCH, 1, cfg.input_dim), np.float32))
  else:
    tok = jnp.zeros((BATCH, 1), jnp.int32)
  pos = jnp.zeros((BATCH,), jnp.int32)

  @jax.jit
  def step(p, s, t, ps):
    return api.decode_step(p, s, t, ps, cfg, cs, pol)

  out, state = step(params, state, tok, pos)
  jax.block_until_ready(out)
  t0 = time.perf_counter()
  for _ in range(STEPS):
    out, state = step(params, state, tok, pos)
  jax.block_until_ready(out)
  wall = (time.perf_counter() - t0) / STEPS
  return dict(wall_s_per_step=round(wall, 6), steps=STEPS)


def paired_rows() -> list[dict]:
  """One row per (config, policy, quant): the static budget ledger of
  the traced decode program next to a measured wall-clock sample of the
  same step."""
  from repro.analysis.budgets import program_ledger
  from repro.analysis.targets import iter_targets

  rows = []
  for target in iter_targets(PAIR_CONFIGS, programs=("decode",)):
    ledger = program_ledger(target)
    static = {k: ledger[k] for k in STATIC_FIELDS if k in ledger}
    measured = _measure_decode(target.config, target.policy, target.quant)
    rows.append(dict(bench="roofline_paired", config=target.config,
                     policy=target.policy, quant=target.quant,
                     program="decode", static=static, measured=measured))
  return rows


def main() -> None:
  import argparse
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--json", action="store_true",
                  help="pair static decode ledgers with measured "
                       f"wall-clock and write {OUT_PATH}")
  args = ap.parse_args()
  if not args.json:
    for r in run():
      print(r)
    return
  rows = paired_rows()
  with open(OUT_PATH, "w") as f:
    json.dump({"rows": rows, "dryrun": run()}, f, indent=1, sort_keys=True)
    f.write("\n")
  for r in rows:
    s, m = r["static"], r["measured"]
    print(f"{r['config']}|{r['policy']}|{r['quant']}: "
          f"static {s.get('dominant', '?')}-bound "
          f"ai={s.get('arithmetic_intensity', 0)} "
          f"hbm={s.get('hbm_bytes', 0)} -> "
          f"measured {m['wall_s_per_step'] * 1e6:.0f} us/step")
  print(f"wrote {len(rows)} paired rows to {OUT_PATH}")


if __name__ == "__main__":
  main()
