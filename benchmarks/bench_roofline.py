"""Roofline table — reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the three-term roofline per (arch x shape x mesh) with the
dominant bottleneck and useful-FLOP fraction (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run() -> list[dict]:
  rows = []
  for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
    with open(path) as f:
      d = json.load(f)
    rows.append({
        "bench": "roofline", "arch": d["arch"], "shape": d["shape"],
        "mesh": d["mesh"],
        "compute_s": round(d["compute_s"], 5),
        "memory_s": round(d["memory_s"], 5),
        "collective_s": round(d["collective_s"], 5),
        "dominant": d["dominant"],
        "useful_flops": round(d.get("useful_flop_fraction", 0.0), 3),
        "roofline_fraction": round(d.get("roofline_fraction", 0.0), 4),
    })
  if not rows:
    rows.append({"bench": "roofline",
                 "note": "run `python -m repro.launch.dryrun --all` first"})
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
