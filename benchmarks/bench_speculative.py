"""Self-speculative decoding vs vanilla decoding on a mixed-length workload.

The paper's low-rank stage-2 model (§3.2) as a free draft: per spec
iteration the draft proposes k tokens, the target verifies all of them in
one fused `ModelApi.decode_window` — now a TRUE batched forward (one
weight read amortized over the k+1 window positions, the paper's §4
bandwidth economics applied to verification), not a scan of single-token
steps. At temperature 0 the engine commits the longest agreeing prefix +
one bonus token and the OUTPUT stays token-for-token vanilla greedy
(re-checked on every greedy row). At temperature > 0 the engine rejection-
samples (accept-with-prob-min(1, p/q), residual resample on reject), so
every emitted token is distributed exactly as vanilla sampling — the
distribution identity is pinned by tests/test_spec_window_parity.py; this
bench reports throughput and accept rate at T = 0.8.

Three report sections:

  verify   the verify program itself, microbenched per k: one batched
           (b x (k+1))-row `decode_window` call vs the sequential scan
           oracle `decode_window_sequential` (k+1 serial weight reads).
           This isolates the window forward from engine overhead — the
           number CI gates on (batched no slower than sequential, k=4).
  rows     full-engine greedy sweep over k x draft rank: wall-clock
           tok/s, accept rate, engine iterations, token parity vs the
           vanilla greedy baseline. Near-full rank (accept -> 1) and a
           pathologically low one (accept -> 0, the overhead floor).
  sampled  full-engine sweep at temperature 0.8, sane rank only: tok/s
           and accept rate vs a vanilla sampled baseline. No token
           parity at T > 0 (spec and vanilla consume RNG differently);
           work parity = equal token counts.

`decode_steps` counts ENGINE ITERATIONS (host round trips), which
acceptance divides by ~(accept*k + 1); with the batched window each
iteration is also a single target weight pass, so the iteration ratio IS
the weight-traffic ratio now. Timings are second-pass (first pass warms
the jit caches); CPU wall-clock is a trajectory signal, not a TPU number.

`--json` writes BENCH_speculative.json — CI runs this as a smoke step and
uploads it alongside BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import dispatch
from repro.layers.common import identity_constraint
from repro.models.api import get_model
from repro.serving import LMEngine, make_draft_params

# the same mixed-length workload as the continuous-batching bench, so
# BENCH_speculative.json and BENCH_serving.json stay comparable (run as
# `python -m benchmarks.bench_speculative`, like bench_quantization)
from benchmarks.bench_serving import make_workload


def run_engine(eng: LMEngine, prompts, budgets, *,
               temperature: float = 0.0) -> dict:
  """Warm pass (jit), then a timed pass after reset(). Sampled runs
  re-seed the same rng key per pass so warm and timed draw identically."""
  for _ in range(2):
    eng.reset()
    t0 = time.perf_counter()
    for p, n in zip(prompts, budgets):
      eng.submit(p, max_new_tokens=n)
    finished = eng.run(temperature=temperature, rng=jax.random.PRNGKey(7))
    dt = time.perf_counter() - t0
  tokens = {f.uid: f.tokens for f in finished}
  n_tok = sum(len(t) for t in tokens.values())
  return {"wall_s": dt, "tokens": n_tok, "tok_s": n_tok / dt,
          "accept_rate": eng.accept_rate, "decode_steps": eng.decode_steps,
          # engine iterations per emitted token == target weight passes
          # per token (the batched window is one weight read)
          "iters_per_token": eng.decode_steps / max(n_tok, 1),
          "by_uid": tokens}


def time_verify(cfg, api, params, kernel_policy: str, batch: int,
                ks, *, max_len: int, reps: int = 30) -> list:
  """Microbench the verify program per k: one batched decode_window call
  vs the sequential scan oracle, same inputs, median of `reps` timed
  calls after a warm/compile call."""
  rs = np.random.RandomState(0)
  state0 = api.init_decode_state(cfg, batch, max_len)
  rows = []
  for k in ks:
    w = k + 1
    pol = (None if kernel_policy == "jnp"
           else dispatch.decode_policy(batch, window=w, interpret=True))
    toks = jnp.asarray(rs.randint(1, cfg.vocab_size, size=(batch, w)),
                       jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)

    def bat(p, s, t, q, pol=pol):
      return api.decode_window(p, s, t, q, cfg, identity_constraint, pol)

    def seq(p, s, t, q, pol=pol):
      return api.decode_window_sequential(p, s, t, q, cfg,
                                          identity_constraint, pol)

    row = {"k": k}
    for name, fn in (("batched", jax.jit(bat)),
                     ("sequential", jax.jit(seq))):
      lg, _ = fn(params, state0, toks, pos)       # compile + warm
      jax.block_until_ready(lg)
      times = []
      for _ in range(reps):
        t0 = time.perf_counter()
        lg, _ = fn(params, state0, toks, pos)
        jax.block_until_ready(lg)
        times.append(time.perf_counter() - t0)
      row[f"{name}_ms"] = float(np.median(times)) * 1e3
    row["speedup"] = row["sequential_ms"] / row["batched_ms"]
    rows.append(row)
  return rows


def run(arch: str, *, batch: int, num_requests: int, max_len: int,
        kernel_policy, ks=(1, 2, 4), ranks=(128, 8),
        sample_temperature=0.8) -> dict:
  cfg = configs.get_smoke(arch).with_(vocab_size=128, dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts, budgets = make_workload(num_requests, cfg.vocab_size)
  kw = dict(batch_size=batch, max_len=max_len, kernel_policy=kernel_policy)

  verify = time_verify(cfg, api, params, kernel_policy or "jnp", batch,
                       ks, max_len=max_len)

  base = run_engine(LMEngine(cfg, params, **kw), prompts, budgets)
  ref = base.pop("by_uid")
  del base["accept_rate"]
  base_s = run_engine(LMEngine(cfg, params, **kw), prompts, budgets,
                      temperature=sample_temperature)
  del base_s["by_uid"], base_s["accept_rate"]

  rows, sampled = [], []
  for rank in ranks:
    draft = make_draft_params(params, rank=rank)
    for k in ks:
      eng = LMEngine(cfg, params, speculate=k, draft_params=draft, **kw)
      r = run_engine(eng, prompts, budgets)
      got = r.pop("by_uid")
      # greedy losslessness re-checked on every row: uids restart per
      # engine, so position i of each engine is the same request
      r["parity"] = all(
          np.array_equal(got[u2], ref[u1])
          for u1, u2 in zip(sorted(ref), sorted(got)))
      r.update(k=k, rank=rank)
      rows.append(r)
      if rank == max(ranks):
        eng = LMEngine(cfg, params, speculate=k, draft_params=draft, **kw)
        rs_ = run_engine(eng, prompts, budgets,
                         temperature=sample_temperature)
        del rs_["by_uid"]
        rs_.update(k=k, rank=rank, temperature=sample_temperature)
        sampled.append(rs_)
  return {"arch": cfg.name, "batch": batch, "num_requests": num_requests,
          "max_len": max_len, "verify": verify, "baseline": base,
          "baseline_sampled": base_s, "rows": rows, "sampled": sampled}


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="qwen3-4b")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--num-requests", type=int, default=8)
  ap.add_argument("--max-len", type=int, default=64)
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp")
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_speculative.json")
  args = ap.parse_args()

  out = run(args.arch, batch=args.batch, num_requests=args.num_requests,
            max_len=args.max_len, kernel_policy=args.kernels)
  print("  verify program (one window, batched vs sequential scan):")
  for v in out["verify"]:
    print(f"    k={v['k']}: batched {v['batched_ms']:.2f} ms vs "
          f"sequential {v['sequential_ms']:.2f} ms ({v['speedup']:.2f}x)")
  b = out["baseline"]
  print(f"  vanilla greedy: {b['tokens']} tok in {b['wall_s']:.2f}s "
        f"({b['tok_s']:.1f} tok/s, {b['decode_steps']} steps)")
  for r in out["rows"]:
    print(f"  T=0.0 k={r['k']} rank={r['rank']:>4}: {r['tok_s']:.1f} tok/s "
          f"({r['tok_s'] / b['tok_s']:.2f}x), accept {r['accept_rate']:.2f}, "
          f"{r['decode_steps']} iterations "
          f"({b['decode_steps'] / r['decode_steps']:.1f}x fewer), "
          f"parity={r['parity']}")
  bs = out["baseline_sampled"]
  print(f"  vanilla sampled: {bs['tok_s']:.1f} tok/s "
        f"({bs['decode_steps']} steps)")
  for r in out["sampled"]:
    print(f"  T={r['temperature']} k={r['k']} rank={r['rank']:>4}: "
          f"{r['tok_s']:.1f} tok/s ({r['tok_s'] / bs['tok_s']:.2f}x), "
          f"accept {r['accept_rate']:.2f}, {r['decode_steps']} iterations")
  if args.json:
    with open("BENCH_speculative.json", "w") as f:
      json.dump(out, f, indent=1)
    print("wrote BENCH_speculative.json")


if __name__ == "__main__":
  main()
