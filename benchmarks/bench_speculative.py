"""Self-speculative decoding vs vanilla greedy on a mixed-length workload.

The paper's low-rank stage-2 model (§3.2) as a free draft: per spec
iteration the draft proposes k tokens, the target verifies all of them in
one fused `ModelApi.decode_window`, and the engine commits the longest
agreeing prefix + one bonus token — so the target's sequential-step count
drops by the accept rate while the OUTPUT stays token-for-token vanilla
greedy (this bench re-checks that parity on every row).

Reports, per (k, draft rank): wall-clock tok/s, measured accept rate, and
parity against the vanilla baseline; k in {1, 2, 4} over a near-full rank
(accept -> 1) and a pathologically low one (accept -> 0, the overhead
floor). Timings are second-pass (first pass warms the jit caches). CPU
wall-clock is a trajectory signal, not a TPU number: the smoke model is
dispatch-bound, and the draft's factored GEMMs only pay off once weights
dominate step time.

Metric honesty: `decode_steps` counts ENGINE ITERATIONS (host round
trips + accept/rewind overhead amortized per window), which acceptance
divides by ~(accept*k + 1). It is NOT yet target weight traffic — the
verify window is a scan of single-token steps, so it still reads the
weights once per window position; collapsing the window into one batched
(b x (k+1))-row forward (single weight pass, where the real §4
bandwidth win appears) is a ROADMAP open item.

`--json` writes BENCH_speculative.json — CI runs this as a smoke step and
uploads it alongside BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.api import get_model
from repro.serving import LMEngine, make_draft_params

# the same mixed-length workload as the continuous-batching bench, so
# BENCH_speculative.json and BENCH_serving.json stay comparable (run as
# `python -m benchmarks.bench_speculative`, like bench_quantization)
from benchmarks.bench_serving import make_workload


def run_engine(eng: LMEngine, prompts, budgets) -> dict:
  """Warm pass (jit), then a timed pass after reset()."""
  for _ in range(2):
    eng.reset()
    t0 = time.perf_counter()
    for p, n in zip(prompts, budgets):
      eng.submit(p, max_new_tokens=n)
    finished = eng.run()
    dt = time.perf_counter() - t0
  tokens = {f.uid: f.tokens for f in finished}
  n_tok = sum(len(t) for t in tokens.values())
  return {"wall_s": dt, "tokens": n_tok, "tok_s": n_tok / dt,
          "accept_rate": eng.accept_rate, "decode_steps": eng.decode_steps,
          # engine iterations per emitted token (see module docstring:
          # iteration != weight pass until the window step is batched)
          "iters_per_token": eng.decode_steps / max(n_tok, 1),
          "by_uid": tokens}


def run(arch: str, *, batch: int, num_requests: int, max_len: int,
        kernel_policy, ks=(1, 2, 4), ranks=(128, 8)) -> dict:
  cfg = configs.get_smoke(arch).with_(vocab_size=128, dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts, budgets = make_workload(num_requests, cfg.vocab_size)
  kw = dict(batch_size=batch, max_len=max_len, kernel_policy=kernel_policy)

  base = run_engine(LMEngine(cfg, params, **kw), prompts, budgets)
  ref = base.pop("by_uid")
  del base["accept_rate"]

  rows = []
  for rank in ranks:
    draft = make_draft_params(params, rank=rank)
    for k in ks:
      eng = LMEngine(cfg, params, speculate=k, draft_params=draft, **kw)
      r = run_engine(eng, prompts, budgets)
      got = r.pop("by_uid")
      # losslessness re-checked on every row: uids restart per engine,
      # so position i of each engine is the same request
      r["parity"] = all(
          np.array_equal(got[u2], ref[u1])
          for u1, u2 in zip(sorted(ref), sorted(got)))
      r.update(k=k, rank=rank)
      rows.append(r)
  return {"arch": cfg.name, "batch": batch, "num_requests": num_requests,
          "max_len": max_len, "baseline": base, "rows": rows}


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="qwen3-4b")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--num-requests", type=int, default=8)
  ap.add_argument("--max-len", type=int, default=64)
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp")
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_speculative.json")
  args = ap.parse_args()

  out = run(args.arch, batch=args.batch, num_requests=args.num_requests,
            max_len=args.max_len, kernel_policy=args.kernels)
  b = out["baseline"]
  print(f"  vanilla: {b['tokens']} tok in {b['wall_s']:.2f}s "
        f"({b['tok_s']:.1f} tok/s, {b['decode_steps']} steps)")
  for r in out["rows"]:
    print(f"  k={r['k']} rank={r['rank']:>4}: {r['tok_s']:.1f} tok/s "
          f"({r['tok_s'] / b['tok_s']:.2f}x), accept {r['accept_rate']:.2f}, "
          f"{r['decode_steps']} iterations "
          f"({b['decode_steps'] / r['decode_steps']:.1f}x fewer), "
          f"parity={r['parity']}")
  if args.json:
    with open("BENCH_speculative.json", "w") as f:
      json.dump(out, f, indent=1)
    print("wrote BENCH_speculative.json")


if __name__ == "__main__":
  main()
