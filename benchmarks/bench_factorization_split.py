"""Table 3 — partially-joint vs completely-split factorization of the GRU
weights (Appendix B.2). Partially joint truncates each concatenated
(in, 3H) matrix as one SVD; completely split truncates the three gate
blocks independently. Same variance threshold => joint needs fewer total
parameters at matched CER."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.speech_runner import (DATA_CFG, LR, MODEL_CFG, PLAN,
                                      _cached, eval_cer, train_stage1)
from repro.core.factored import FactoredLinear, count_params, \
    map_factored_leaves
from repro.core.svd import TruncationSpec, balanced_split, \
    explained_variance_rank
from repro.data.speech import batch_at
from repro.training import TrainConfig, Trainer


def _truncate_split(leaf: FactoredLinear, threshold: float,
                    round_to: int = 8) -> FactoredLinear:
  """Completely-split truncation: SVD each of the 3 gate blocks of the
  concatenated (in, 3H) matrix separately, then re-concatenate as a
  block-diagonal-rank factorization."""
  w = np.asarray(leaf.product(), np.float32)
  m, n3 = w.shape
  h = n3 // 3
  us, vs = [], []
  for g in range(3):
    blk = w[:, g * h:(g + 1) * h]
    s = np.linalg.svd(blk, compute_uv=False)
    r = explained_variance_rank(s, threshold)
    r = max(round_to, int(np.ceil(r / round_to)) * round_to)
    u, v = balanced_split(jnp.asarray(blk), min(r, min(blk.shape)))
    us.append(np.asarray(u))
    vs.append(np.asarray(v))
  rtot = sum(u.shape[1] for u in us)
  u_cat = np.concatenate(us, axis=1)                     # (m, rtot)
  v_cat = np.zeros((rtot, n3), np.float32)               # block diagonal
  off = 0
  for g, v in enumerate(vs):
    v_cat[off:off + v.shape[0], g * h:(g + 1) * h] = v
    off += v.shape[0]
  return FactoredLinear(w=None, u=jnp.asarray(u_cat),
                        v=jnp.asarray(v_cat), name=leaf.name,
                        group=leaf.group)


def _finetune(params, tag: str, steps: int = 60) -> dict:
  spec = dict(what="table3", tag=tag, steps=steps, v=3)
  def run():
    trainer = Trainer(MODEL_CFG, TrainConfig(lr=LR))
    trainer.params = params
    trainer.opt_state = trainer._opt_init(params)
    for i in range(steps):
      trainer.train_step(batch_at(DATA_CFG, 300 + i))
    return {"cer": eval_cer(trainer.params),
            "n_params": int(count_params(trainer.params))}
  return _cached(spec, run)


def run() -> list[dict]:
  s1 = train_stage1("trace", 3e-5, 3e-5)
  rows = []
  for thr in (0.7, 0.9):
    # partially joint (the framework default)
    from repro.core.compress import to_stage2
    joint = to_stage2(s1["params"], PLAN,
                      TruncationSpec(variance_threshold=thr, round_to=8))
    rj = _finetune(joint, f"joint{thr}")
    # completely split on the GRU weights only
    def split_leaf(leaf):
      if "gru" in leaf.name and min(leaf.in_dim, leaf.out_dim) >= 48:
        return _truncate_split(leaf, thr)
      if min(leaf.in_dim, leaf.out_dim) >= 48:
        from repro.core.svd import truncate_leaf
        return truncate_leaf(leaf, TruncationSpec(variance_threshold=thr,
                                                  round_to=8))
      return leaf
    split = map_factored_leaves(split_leaf, s1["params"])
    rs = _finetune(split, f"split{thr}")
    rows.append({"bench": "table3_split", "threshold": thr,
                 "scheme": "partially_joint", "n_params": rj["n_params"],
                 "cer": rj["cer"]})
    rows.append({"bench": "table3_split", "threshold": thr,
                 "scheme": "completely_split", "n_params": rs["n_params"],
                 "cer": rs["cer"]})
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
