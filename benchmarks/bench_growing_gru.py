"""Appendix B.1 — growing recurrent layer sizes: "the sizes of the
recurrent layers closer to the input could be shrunk without affecting
accuracy much". Compares the paper's affine-growing GRU dims against a
uniform stack and a reversed (shrinking) stack at comparable parameter
counts on the synthetic speech task."""
from __future__ import annotations

import jax

from benchmarks.speech_runner import DATA_CFG, LR, MODEL_CFG, _cached, \
    eval_cer
from repro.core.factored import count_params
from repro.data.speech import batch_at
from repro.training import TrainConfig, Trainer

VARIANTS = {
    "growing (paper B.1)": (64, 80, 96),
    "uniform": (82, 82, 82),
    "shrinking": (96, 80, 64),
}
STEPS = 160


def _run(name: str, dims: tuple) -> dict:
  spec = dict(what="b1_growing", dims=list(dims), steps=STEPS, v=1)
  def run():
    cfg = MODEL_CFG.with_(gru_dims=dims, d_model=dims[-1])
    trainer = Trainer(cfg, TrainConfig(lr=LR), rng=jax.random.PRNGKey(0))
    for i in range(STEPS):
      m = trainer.train_step(batch_at(DATA_CFG, i))
    # evaluate with the variant's own config
    from benchmarks import speech_runner
    import numpy as np
    import jax.numpy as jnp
    from repro.data.speech import cer
    from repro.models import deepspeech
    from repro.models.ctc import ctc_greedy_decode
    scores = []
    for j in range(3):
      b = batch_at(DATA_CFG, 900 + j)
      lp = deepspeech.forward(trainer.params, jnp.asarray(b["feats"]), cfg)
      ol = deepspeech.output_lengths(jnp.asarray(b["feat_lengths"]), cfg)
      scores.append(cer(np.asarray(ctc_greedy_decode(lp, ol)),
                        b["labels"], b["label_lengths"]))
    return {"cer": float(np.mean(scores)),
            "n_params": int(count_params(trainer.params)),
            "loss": m["loss"]}
  return _cached(spec, run)


def run() -> list[dict]:
  rows = []
  for name, dims in VARIANTS.items():
    out = _run(name, dims)
    rows.append({"bench": "appB1_growing_gru", "variant": name,
                 "gru_dims": list(dims), "n_params": out["n_params"],
                 "cer": out["cer"]})
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
