"""Shared runner for the paper-reproduction benchmarks.

Trains the reduced DS2 model on the synthetic speech task under a given
regularization config and caches (params, metrics) on disk keyed by the
run spec — Figures 1-5 share stage-1 trainings instead of repeating them.

Scale note (EXPERIMENTS.md): WSJ is not available offline; these runs
validate the paper's *qualitative* claims on the synthetic task at CPU
scale. "CER" is task-CER on held-out synthetic batches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.compress import FactorizationPlan, to_stage2
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import (RegularizerConfig, nu_from_sigma,
                                  rank_for_variance, singular_values)
from repro.core.factored import count_params, iter_factored_leaves
from repro.data.speech import SpeechDataConfig, batch_at, cer
from repro.models import deepspeech
from repro.models.ctc import ctc_greedy_decode
from repro.training import TrainConfig, Trainer

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "cache")

MODEL_CFG = configs.get_smoke("deepspeech2-wsj").with_(dtype=jnp.float32)
DATA_CFG = SpeechDataConfig(vocab_size=MODEL_CFG.vocab_size,
                            feat_dim=MODEL_CFG.feat_dim, global_batch=8,
                            max_label_len=12, noise=0.2)
PLAN = FactorizationPlan(min_dim=48)
STAGE1_STEPS = 160
LR = 1e-3


def eval_cer(params, n_batches: int = 3, start: int = 900) -> float:
  total = []
  for j in range(n_batches):
    b = batch_at(DATA_CFG, start + j)
    lp = deepspeech.forward(params, jnp.asarray(b["feats"]), MODEL_CFG)
    ol = deepspeech.output_lengths(jnp.asarray(b["feat_lengths"]),
                                   MODEL_CFG)
    total.append(cer(np.asarray(ctc_greedy_decode(lp, ol)), b["labels"],
                     b["label_lengths"]))
  return float(np.mean(total))


def _key(spec: dict) -> str:
  return hashlib.md5(json.dumps(spec, sort_keys=True).encode()).hexdigest()


def _cached(spec: dict, fn):
  os.makedirs(CACHE, exist_ok=True)
  path = os.path.join(CACHE, _key(spec) + ".pkl")
  if os.path.exists(path):
    with open(path, "rb") as f:
      return pickle.load(f)
  out = fn()
  with open(path, "wb") as f:
    pickle.dump(out, f)
  return out


def train_stage1(kind: str, lam_rec: float, lam_nonrec: float,
                 steps: int = STAGE1_STEPS, seed: int = 0):
  """Stage-1 training (factored+trace, factored+<none>, or unfactored l2).

  Returns {params, cer, step_time_s}. Cached on disk.
  """
  spec = dict(what="stage1", kind=kind, lr=lam_rec, lnr=lam_nonrec,
              steps=steps, seed=seed, v=3)
  def run():
    reg = RegularizerConfig(kind=kind, lambda_rec=lam_rec,
                            lambda_nonrec=lam_nonrec)
    # trace-norm runs train the factored form; l2/none train unfactored
    sched = TwoStageSchedule(
        total_steps=steps * 2, transition_step=steps * 2 + 1,   # never
        regularizer=reg,
        truncation=TruncationSpec()) if kind == "trace" else None
    tcfg = TrainConfig(lr=LR, regularizer=reg if sched is None else
                       RegularizerConfig())
    trainer = Trainer(MODEL_CFG, tcfg, schedule=sched, plan=PLAN,
                      rng=jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    for i in range(steps):
      m = trainer.train_step(batch_at(DATA_CFG, i))
    dt = (time.perf_counter() - t0) / steps
    return {"params": jax.device_get(trainer.params),
            "cer": eval_cer(trainer.params), "loss": m["loss"],
            "step_time_s": dt}
  return _cached(spec, run)


def finetune_stage2(stage1_params, threshold: float, steps: int = 60,
                    spec_extra: Optional[dict] = None, round_to: int = 8):
  """Warmstart from truncated SVD and fine-tune without regularization."""
  spec = dict(what="stage2", thr=threshold, steps=steps, round_to=round_to,
              v=3, **(spec_extra or {}))
  def run():
    tspec = TruncationSpec(variance_threshold=threshold, round_to=round_to)
    params = to_stage2(stage1_params, PLAN, tspec)
    trainer = Trainer(MODEL_CFG, TrainConfig(lr=LR))
    trainer.params = params
    trainer.opt_state = trainer._opt_init(params)
    for i in range(steps):
      m = trainer.train_step(batch_at(DATA_CFG, 200 + i))
    return {"params": jax.device_get(trainer.params),
            "cer": eval_cer(trainer.params),
            "n_params": int(count_params(trainer.params))}
  return _cached(spec, run)


def gemm_diagnostics(params) -> dict:
  """Per-GEMM {nu, rank90, shape} for Figures 2-3."""
  out = {}
  for leaf in iter_factored_leaves(params):
    w = leaf.product()
    if w.ndim != 2:
      continue
    s = singular_values(w)
    out[leaf.name] = {
        "nu": float(nu_from_sigma(s)),
        "rank90": int(rank_for_variance(s, 0.90)),
        "shape": [int(leaf.in_dim), int(leaf.out_dim)],
        "group": leaf.group,
    }
  return out
