"""Fig. 4 — parameters vs CER of stage-2 models, by the stage-1
regularization type (trace / l2 / unregularized). Varying the explained-
variance threshold traces each curve."""
from __future__ import annotations

from benchmarks.speech_runner import finetune_stage2, train_stage1

THRESHOLDS = [0.7, 0.9, 0.98]
SOURCES = [("trace", 3e-5), ("trace", 3e-3), ("l2", 3e-5), ("none", 0.0)]


def run() -> list[dict]:
  rows = []
  for kind, lam in SOURCES:
    s1 = train_stage1(kind, lam, lam)
    for thr in THRESHOLDS:
      s2 = finetune_stage2(s1["params"], thr,
                           spec_extra=dict(src=kind, lam=lam))
      rows.append({
          "bench": "fig4_stage2_tradeoff", "stage1_kind": kind,
          "lambda": lam, "threshold": thr,
          "n_params": s2["n_params"], "cer": s2["cer"],
          "stage1_cer": s1["cer"],
      })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
