"""Fig. 5 — CER versus stage-1 -> stage-2 transition step at a fixed
total training budget (the paper's training-time reduction result: early
transitions don't hurt the final CER, and the LR schedule continues
across the transition)."""
from __future__ import annotations

import hashlib
import json
import os
import pickle

import jax

from benchmarks.speech_runner import (CACHE, DATA_CFG, LR, MODEL_CFG, PLAN,
                                      eval_cer, _cached)
from repro.core.schedule import TwoStageSchedule
from repro.core.svd import TruncationSpec
from repro.core.tracenorm import RegularizerConfig
from repro.data.speech import batch_at
from repro.training import TrainConfig, Trainer

TOTAL = 200
TRANSITIONS = [40, 100, 160]


def _run_one(kind: str, transition: int) -> dict:
  spec = dict(what="fig5", kind=kind, transition=transition, total=TOTAL,
              v=3)
  def run():
    sched = TwoStageSchedule(
        total_steps=TOTAL, transition_step=transition,
        regularizer=RegularizerConfig(kind=kind, lambda_rec=3e-5,
                                      lambda_nonrec=3e-5),
        truncation=TruncationSpec(variance_threshold=0.9, round_to=8),
        lr_policy="continue")
    trainer = Trainer(MODEL_CFG, TrainConfig(lr=LR), schedule=sched,
                      plan=PLAN)
    curve = []
    for i in range(TOTAL):
      m = trainer.train_step(batch_at(DATA_CFG, i))
      if i % 20 == 19:
        curve.append((i, m["loss"]))
    return {"cer": eval_cer(trainer.params), "curve": curve}
  return _cached(spec, run)


def run() -> list[dict]:
  rows = []
  for kind in ("trace", "l2"):
    for tr in TRANSITIONS:
      out = _run_one(kind, tr)
      rows.append({
          "bench": "fig5_transition", "kind": kind,
          "transition_step": tr, "total_steps": TOTAL, "cer": out["cer"],
      })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
