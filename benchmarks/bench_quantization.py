"""Paper §4 quantization claim — "low-precision 8-bit representation ...
only introducing 2% to 4% relative increase in WER".

Takes the trained stage-2 DS2 model, applies symmetric per-channel int8
weight quantization (the kernels/int8_gemm format) in simulated-quant
form (quantize -> dequantize, so the CPU runs the exact arithmetic the
int8 kernel's dequantized output represents), and compares task-CER
against the bf16/f32 model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.speech_runner import eval_cer, finetune_stage2, train_stage1
from repro.core.factored import FactoredLinear, map_factored_leaves
from repro.kernels import ref


def _simulate_int8(arr: jax.Array) -> jax.Array:
  """Per-column symmetric int8 quantize->dequantize of a 2D weight."""
  q, s = ref.quantize_colwise(arr)
  return (q.astype(jnp.float32) * s[None, :]).astype(arr.dtype)


def quantize_tree(params):
  def f(leaf: FactoredLinear) -> FactoredLinear:
    if leaf.is_factored:
      return FactoredLinear(w=None, u=_simulate_int8(leaf.u),
                            v=_simulate_int8(leaf.v), name=leaf.name,
                            group=leaf.group)
    if leaf.w.ndim == 2:
      return FactoredLinear(w=_simulate_int8(leaf.w), u=None, v=None,
                            name=leaf.name, group=leaf.group)
    return leaf
  return map_factored_leaves(f, params)


def run() -> list[dict]:
  s1 = train_stage1("trace", 3e-5, 3e-5)
  s2 = finetune_stage2(s1["params"], 0.9,
                       spec_extra=dict(src="trace", lam=3e-5))
  cer_fp = eval_cer(s2["params"])
  cer_q = eval_cer(quantize_tree(s2["params"]))
  rel = 100.0 * (cer_q - cer_fp) / max(cer_fp, 1e-9)
  return [{
      "bench": "sec4_quantization", "cer_fp": cer_fp, "cer_int8": cer_q,
      "rel_cer_increase_pct": rel,
      "paper_claim": "2-4% relative increase",
  }]


if __name__ == "__main__":
  for r in run():
    print(r)
