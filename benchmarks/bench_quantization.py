"""Paper §4 quantization claim + end-to-end quantized serving.

Two measurements, one JSON (`--json` -> BENCH_quantization.json):

  cer     — "low-precision 8-bit representation ... only introducing 2%
            to 4% relative increase in WER": task-CER of the trained
            stage-2 DS2 model before/after `repro.quant.quantize_params`
            (real int8 storage, w8a8 arithmetic — the exact math the
            int8_gemm kernel runs, not a simulate-quant copy).
  serving — continuous-batching LMEngine tok/s on the same request
            workload, f32 params vs PTQ'd params, both policies; CPU
            wall-clock is a trajectory signal, not a TPU number.

`--smoke` skips the (cached, but minutes-long) stage-1/stage-2 training
and uses random-init params — CI's slow tier runs this to keep the
quantized-serving path and the JSON schema exercised on every push.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.api import get_model
from repro.quant import quantize_params
from repro.serving import LMEngine


def eval_cer_pair(train: bool) -> dict:
  """CER of the DS2 model f32 vs PTQ'd (trained unless --smoke)."""
  from benchmarks.speech_runner import (eval_cer, finetune_stage2,
                                        train_stage1)
  if train:
    s1 = train_stage1("trace", 3e-5, 3e-5)
    s2 = finetune_stage2(s1["params"], 0.9,
                         spec_extra=dict(src="trace", lam=3e-5))
    params = s2["params"]
  else:
    from benchmarks.speech_runner import MODEL_CFG
    params = get_model(MODEL_CFG).init(jax.random.PRNGKey(0), MODEL_CFG)
  cer_fp = eval_cer(params)
  cer_q = eval_cer(quantize_params(params))
  rel = 100.0 * (cer_q - cer_fp) / max(cer_fp, 1e-9)
  return {"cer_fp": cer_fp, "cer_int8": cer_q,
          "rel_cer_increase_pct": rel, "trained": train,
          "paper_claim": "2-4% relative increase"}


def _serve(cfg, params, prompts, budgets, *, kernel_policy,
           batch: int, max_len: int) -> dict:
  eng = LMEngine(cfg, params, batch_size=batch, max_len=max_len,
                 kernel_policy=kernel_policy)
  for p, n in zip(prompts, budgets):
    eng.submit(p, max_new_tokens=n)
  eng.run()                                    # jit warmup pass
  eng.reset()
  for p, n in zip(prompts, budgets):
    eng.submit(p, max_new_tokens=n)
  t0 = time.perf_counter()
  finished = eng.run()
  dt = time.perf_counter() - t0
  tokens = sum(len(f.tokens) for f in finished)
  return {"wall_s": dt, "tokens": tokens, "tok_s": tokens / dt,
          "occupancy": eng.occupancy}


def serving_pair(arch: str, *, batch: int = 2, num_requests: int = 6,
                 max_len: int = 48) -> dict:
  """Same workload, f32-jnp vs PTQ-pallas vs PTQ-jnp engines; records
  tok/s plus greedy-token parity between the two quantized policies."""
  cfg = configs.get_smoke(arch).with_(vocab_size=128, dtype=jnp.float32)
  params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
  qparams = quantize_params(params)
  rng = np.random.RandomState(0)
  prompts = [rng.randint(1, cfg.vocab_size, size=(int(rng.randint(2, 7)),))
             for _ in range(num_requests)]
  budgets = [int(rng.randint(2, 13)) for _ in range(num_requests)]
  kw = dict(batch=batch, max_len=max_len)
  out = {
      "arch": cfg.name, "batch": batch, "num_requests": num_requests,
      "f32_jnp": _serve(cfg, params, prompts, budgets,
                        kernel_policy="jnp", **kw),
      "int8_jnp": _serve(cfg, qparams, prompts, budgets,
                         kernel_policy="jnp", **kw),
      "int8_pallas": _serve(cfg, qparams, prompts, budgets,
                            kernel_policy="pallas", **kw),
  }
  out["int8_vs_f32_tok_s_ratio"] = (
      out["int8_jnp"]["tok_s"] / out["f32_jnp"]["tok_s"])
  # greedy parity: the quantized engine must decode the same tokens
  # under either policy (same w8a8 arithmetic, kernel or oracle)
  e1 = LMEngine(cfg, qparams, batch_size=batch, max_len=max_len)
  e2 = LMEngine(cfg, qparams, batch_size=batch, max_len=max_len,
                kernel_policy="pallas")
  pr = np.stack([p[:2] for p in prompts[:batch]])
  out["policy_parity"] = bool(np.array_equal(
      e1.generate(pr, steps=8).tokens, e2.generate(pr, steps=8).tokens))
  return out


def run(*, smoke: bool = False) -> list[dict]:
  """Row list (the benchmarks/run.py driver contract): row 0 is the §4
  CER claim, row 1 the quantized-serving comparison."""
  return [
      {"bench": "sec4_quantization", **eval_cer_pair(train=not smoke)},
      {"bench": "quantized_serving", **serving_pair("qwen3-4b")},
  ]


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--smoke", action="store_true",
                  help="skip stage-1/2 training (random-init CER pair)")
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_quantization.json")
  args = ap.parse_args()
  rows = run(smoke=args.smoke)
  c, s = rows[0], rows[1]
  print(f"CER f32 {c['cer_fp']:.4f} -> int8 {c['cer_int8']:.4f} "
        f"({c['rel_cer_increase_pct']:+.1f}% rel; trained={c['trained']})")
  for k in ("f32_jnp", "int8_jnp", "int8_pallas"):
    r = s[k]
    print(f"{k:12s} {r['tok_s']:8.1f} tok/s  (occ {r['occupancy']:.2f})")
  print(f"policy parity (int8 jnp == int8 pallas tokens): "
        f"{s['policy_parity']}")
  if args.json:
    with open("BENCH_quantization.json", "w") as f:
      json.dump({"rows": rows}, f, indent=2)
    print("wrote BENCH_quantization.json")


if __name__ == "__main__":
  main()
