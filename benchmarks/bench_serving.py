"""Continuous batching vs static batching on a mixed-length workload.

The paper's serving regime (§4) is a handful of concurrent streams
amortizing each weight load — which makes every idle slot-step a direct
waste of the memory bandwidth the whole factorization exists to save.
This bench drives the same request set through

  continuous — LMEngine's queue: admit / prefill / decode / retire on
               budget, refill the slot from the queue mid-run;
  static     — groups of `batch` requests padded to the group's longest
               prompt, every slot stepping until the group's largest
               token budget is exhausted (the old fixed-batch engine).

and reports wall-clock throughput over *useful* tokens plus slot
occupancy (busy slot-steps / total slot-steps). Timings are second-pass
(first pass warms the jit caches). CPU wall-clock: a trajectory signal,
not a TPU number.

A third section benchmarks the radix-trie prefix cache on fleet-shaped
traffic: `--shared-prefix-frac` of requests open with one long shared
template (a system prompt), the rest are unrelated. Cold serving
re-prefills the template per request; cached serving splices the cached
snapshot and prefills only the unique suffix, so time-to-first-token
drops by roughly the template/suffix prefill ratio while greedy output
stays token-for-token identical (asserted, reported as `parity`).

`--json` writes BENCH_serving.json — CI runs this as a smoke step and
uploads it alongside BENCH_kernels.json.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.api import get_model
from repro.serving import LMEngine, PrefixCache


def make_workload(num_requests: int, vocab: int, seed: int = 0):
  """Mixed prompt lengths and token budgets — the shape continuous
  batching exists for."""
  rng = np.random.RandomState(seed)
  prompts = [rng.randint(1, vocab, size=(int(rng.randint(2, 9)),))
             for _ in range(num_requests)]
  budgets = [int(rng.randint(2, 21)) for _ in range(num_requests)]
  return prompts, budgets


def make_shared_workload(num_requests: int, vocab: int,
                         shared_frac: float, seed: int = 0,
                         shared_len: int = 22):
  """Fleet-shaped traffic: `shared_frac` of requests open with one long
  template (a system prompt) plus a short unique suffix; the rest are
  unrelated mid-length prompts. The template dominates prefill cost, so
  this is the workload where prefix caching pays."""
  rng = np.random.RandomState(seed)
  shared = rng.randint(1, vocab, size=(shared_len,))
  prompts, budgets = [], []
  for _ in range(num_requests):
    if rng.rand() < shared_frac:
      sfx = rng.randint(1, vocab, size=(int(rng.randint(4, 7)),))
      prompts.append(np.concatenate([shared, sfx]))
    else:
      prompts.append(rng.randint(1, vocab,
                                 size=(int(rng.randint(6, 13)),)))
    budgets.append(int(rng.randint(2, 9)))
  return prompts, budgets


def run_continuous(cfg, params, prompts, budgets, *, batch, max_len,
                   kernel_policy):
  eng = LMEngine(cfg, params, batch_size=batch, max_len=max_len,
                 kernel_policy=kernel_policy)
  t0 = time.perf_counter()
  for p, n in zip(prompts, budgets):
    eng.submit(p, max_new_tokens=n)
  finished = eng.run()
  dt = time.perf_counter() - t0
  tokens = sum(len(f.tokens) for f in finished)
  return {"wall_s": dt, "tokens": tokens, "tok_s": tokens / dt,
          "occupancy": eng.occupancy, "decode_steps": eng.decode_steps}


def run_static(cfg, params, prompts, budgets, *, batch, max_len,
               kernel_policy):
  """Fixed-batch baseline: groups in arrival order, prompts padded to the
  group max, every slot runs the group's largest budget."""
  wall = 0.0
  useful = busy = total = steps = 0
  for g in range(0, len(prompts), batch):
    gp, gb = prompts[g:g + batch], budgets[g:g + batch]
    plen = max(p.size for p in gp)
    padded = np.ones((len(gp), plen), np.int32)
    for r, p in enumerate(gp):
      padded[r, :p.size] = p
    eng = LMEngine(cfg, params, batch_size=len(gp), max_len=max_len,
                   kernel_policy=kernel_policy)
    t0 = time.perf_counter()
    eng.generate(padded, steps=max(gb))
    wall += time.perf_counter() - t0
    useful += sum(gb)
    busy += sum(gb)                       # slot-steps doing requested work
    total += len(gp) * max(gb)            # slot-steps actually executed
    steps += max(gb)
  return {"wall_s": wall, "tokens": useful, "tok_s": useful / wall,
          "occupancy": busy / total, "decode_steps": steps}


def _ttft_ms(finished, q: float) -> float:
  ts = sorted(f.ttft_s for f in finished if f.ttft_s is not None)
  return ts[min(len(ts) - 1, int(q * len(ts)))] * 1e3


def run_prefix_cache(cfg, params, *, batch, max_len, kernel_policy,
                     num_requests, shared_frac, capacity_mb) -> dict:
  """Cold vs cached serving on the shared-template workload. The cache
  starts empty each pass and warms in-flight: the first template sighting
  is a cold prefill, the second materializes the fork, the third onward
  splice it — so reported hit rate and TTFT include the warmup misses."""
  prompts, budgets = make_shared_workload(num_requests, cfg.vocab_size,
                                          shared_frac)

  def serve(cache):
    eng = LMEngine(cfg, params, batch_size=batch, max_len=max_len,
                   kernel_policy=kernel_policy, prefix_cache=cache)
    for p, n in zip(prompts, budgets):
      eng.submit(p, max_new_tokens=n)
    t0 = time.perf_counter()
    finished = eng.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(f.tokens) for f in finished)
    stats = {"wall_s": dt, "tokens": tokens, "tok_s": tokens / dt,
             "ttft_p50_ms": _ttft_ms(finished, 0.50),
             "ttft_p95_ms": _ttft_ms(finished, 0.95),
             "hit_rate": eng.cache_stats()["hit_rate"]}
    return stats, {f.uid: tuple(int(t) for t in f.tokens)
                   for f in finished}

  serve(PrefixCache(capacity_mb=capacity_mb))   # jit warmup, both paths
  serve(None)
  cold, cold_toks = serve(None)
  warm, warm_toks = serve(PrefixCache(capacity_mb=capacity_mb))
  return {
      "shared_prefix_frac": shared_frac, "num_requests": num_requests,
      "capacity_mb": capacity_mb, "cold": cold, "warm": warm,
      "ttft_speedup": cold["ttft_p50_ms"] / warm["ttft_p50_ms"],
      "parity": cold_toks == warm_toks,
  }


def run(arch: str, *, batch: int, num_requests: int, max_len: int,
        kernel_policy, shared_prefix_frac: float = 0.8,
        prefix_cache_mb: float = 64.0) -> dict:
  cfg = configs.get_smoke(arch).with_(vocab_size=128, dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  prompts, budgets = make_workload(num_requests, cfg.vocab_size)
  kw = dict(batch=batch, max_len=max_len, kernel_policy=kernel_policy)
  run_continuous(cfg, params, prompts, budgets, **kw)   # jit warmup
  run_static(cfg, params, prompts, budgets, **kw)
  cont = run_continuous(cfg, params, prompts, budgets, **kw)
  stat = run_static(cfg, params, prompts, budgets, **kw)
  return {
      "arch": cfg.name, "batch": batch, "num_requests": num_requests,
      "max_len": max_len,
      "prompt_lens": [int(p.size) for p in prompts], "budgets": budgets,
      "continuous": cont, "static": stat,
      "speedup": cont["tok_s"] / stat["tok_s"],
      "prefix_cache": run_prefix_cache(
          cfg, params, num_requests=num_requests,
          shared_frac=shared_prefix_frac,
          capacity_mb=prefix_cache_mb, **kw),
  }


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="qwen3-4b")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--num-requests", type=int, default=12)
  ap.add_argument("--max-len", type=int, default=64)
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp")
  ap.add_argument("--shared-prefix-frac", type=float, default=0.8,
                  help="fraction of requests opening with the shared "
                       "template in the prefix-cache section")
  ap.add_argument("--prefix-cache-mb", type=float, default=64.0)
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_serving.json")
  args = ap.parse_args()

  out = run(args.arch, batch=args.batch, num_requests=args.num_requests,
            max_len=args.max_len, kernel_policy=args.kernels,
            shared_prefix_frac=args.shared_prefix_frac,
            prefix_cache_mb=args.prefix_cache_mb)
  for mode in ("continuous", "static"):
    r = out[mode]
    print(f"{mode:>10}: {r['tokens']} tok in {r['wall_s']:.2f}s "
          f"({r['tok_s']:.1f} tok/s), occupancy {r['occupancy']:.2f}, "
          f"{r['decode_steps']} decode steps")
  print(f"   speedup: {out['speedup']:.2f}x "
        f"({args.num_requests} requests, {args.batch} slots)")
  pc = out["prefix_cache"]
  for mode in ("cold", "warm"):
    r = pc[mode]
    print(f"{mode:>10}: TTFT p50 {r['ttft_p50_ms']:.1f} ms / p95 "
          f"{r['ttft_p95_ms']:.1f} ms, {r['tok_s']:.1f} tok/s, "
          f"hit rate {r['hit_rate']:.2f}")
  print(f"   prefix cache: TTFT speedup {pc['ttft_speedup']:.2f}x at "
        f"{pc['shared_prefix_frac']:.0%} shared "
        f"(parity {'OK' if pc['parity'] else 'BROKEN'})")
  if args.json:
    with open("BENCH_serving.json", "w") as f:
      json.dump(out, f, indent=1)
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
  main()
