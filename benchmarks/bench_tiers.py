"""Tables 1 & 2 — compression tiers: baseline vs three shrunken acoustic
models, with parameters, task-CER, relative accuracy change, and the
roofline-model speedup of the factored+int8 inference path on the TPU
target (the Table-2 'speedup' axis; wall-clock ARM numbers don't exist on
this container, so the bandwidth model supplies the derived speedup —
weights streamed per decode step dominate the low-batch regime)."""
from __future__ import annotations

import numpy as np

from benchmarks.speech_runner import (count_params, finetune_stage2,
                                      train_stage1)
from repro.core.factored import iter_factored_leaves

HBM_BW = 819e9          # bytes/s, v5e


def _decode_weight_bytes(params, bytes_per_el: float) -> float:
  """Weight traffic of one streaming decode step (all GEMMs read once)."""
  total = 0.0
  for leaf in iter_factored_leaves(params):
    total += leaf.num_params * bytes_per_el
  return total


def run() -> list[dict]:
  s1 = train_stage1("trace", 3e-5, 3e-5)
  base_params = s1["params"]
  base_bytes = _decode_weight_bytes(base_params, 2.0)       # bf16 dense
  base_cer = s1["cer"]

  rows = [{
      "bench": "table12_tiers", "tier": "baseline",
      "n_params": int(count_params(base_params)), "cer": base_cer,
      "rel_cer_pct": 0.0, "weight_mb": base_bytes / 1e6,
      "roofline_speedup": 1.0,
  }]
  tiers = [("tier-1", 0.98, 2.0), ("tier-2", 0.9, 2.0),
           ("tier-3", 0.9, 1.0)]      # tier-3: int8 (1 byte/el) + same rank
  for name, thr, bpe in tiers:
    s2 = finetune_stage2(base_params, thr,
                         spec_extra=dict(src="trace", lam=3e-5))
    wbytes = _decode_weight_bytes(s2["params"], bpe)
    rows.append({
        "bench": "table12_tiers", "tier": name,
        "n_params": s2["n_params"], "cer": s2["cer"],
        "rel_cer_pct": 100.0 * (base_cer - s2["cer"]) / max(base_cer, 1e-9),
        "weight_mb": wbytes / 1e6,
        "roofline_speedup": base_bytes / wbytes,
    })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
