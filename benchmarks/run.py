"""Benchmark driver — one function per paper table/figure.

Prints `name,us_per_call,derived` CSV rows: us_per_call is the unit wall
time of the bench's measured operation (training step or kernel call) and
derived is the bench's headline metric. Full row dumps land in
experiments/bench/<bench>.json.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig2]
"""
from __future__ import annotations

import argparse
import json
import os
import time

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench")


def _derived(bench: str, rows: list[dict]) -> str:
  try:
    if bench == "bench_stage1_reg":
      best = min(r["cer"] for r in rows)
      return f"best_cer={best:.3f}"
    if bench == "bench_tracenorm_nu":
      tr = [r for r in rows if r["kind"] == "trace" and r["gemm"] == "<mean>"]
      l2 = [r for r in rows if r["kind"] == "l2" and r["gemm"] == "<mean>"]
      return (f"nu_trace={min(r['nu'] for r in tr):.3f}"
              f"|nu_l2={min(r['nu'] for r in l2):.3f}")
    if bench == "bench_rank_variance":
      tr = min(r["rank90"] for r in rows if r["kind"] == "trace"
               and r["lambda"] > 0)
      un = min(r["rank90"] for r in rows if r["kind"] == "none")
      return f"rank90_trace={tr}|rank90_unreg={un}"
    if bench == "bench_stage2_tradeoff":
      tr = min(r["cer"] for r in rows if r["stage1_kind"] == "trace")
      nn = min(r["cer"] for r in rows if r["stage1_kind"] == "none")
      return f"cer_trace={tr:.3f}|cer_unreg={nn:.3f}"
    if bench == "bench_transition":
      return "|".join(f"t{r['transition_step']}={r['cer']:.3f}"
                      for r in rows if r["kind"] == "trace")
    if bench == "bench_tiers":
      t3 = [r for r in rows if r["tier"] == "tier-3"][0]
      return (f"tier3_params={t3['n_params']}"
              f"|speedup={t3['roofline_speedup']:.1f}x")
    if bench == "bench_lowbatch_gemm":
      b1 = {r["format"]: r["roofline_gops"] for r in rows
            if r["batch"] == 1}
      return (f"b1_int8={b1['int8']}GOPs"
              f"|b1_lowrank={b1['lowrank128_bf16']}GOPs")
    if bench == "bench_factorization_split":
      j = [r for r in rows if r["scheme"] == "partially_joint"]
      s = [r for r in rows if r["scheme"] == "completely_split"]
      return (f"joint_params={min(r['n_params'] for r in j)}"
              f"|split_params={min(r['n_params'] for r in s)}")
    if bench == "bench_quantization":
      r = rows[0]
      return (f"rel_cer_increase={r['rel_cer_increase_pct']:.1f}pct"
              f"|fp={r['cer_fp']:.3f}|int8={r['cer_int8']:.3f}")
    if bench == "bench_growing_gru":
      return "|".join(f"{r['variant'].split()[0]}={r['cer']:.3f}"
                      for r in rows)
    if bench == "bench_roofline":
      doms = [r.get("dominant") for r in rows if "dominant" in r]
      if not doms:
        return "no_dryrun_artifacts"
      from collections import Counter
      c = Counter(doms)
      return "|".join(f"{k}={v}" for k, v in sorted(c.items()))
  except Exception as e:            # keep the driver robust
    return f"derived_error={type(e).__name__}"
  return f"rows={len(rows)}"


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--only", default=None)
  args = ap.parse_args()

  from benchmarks import (bench_factorization_split, bench_growing_gru,
                          bench_lowbatch_gemm, bench_quantization,
                          bench_rank_variance, bench_roofline,
                          bench_stage1_reg, bench_stage2_tradeoff,
                          bench_tiers, bench_tracenorm_nu,
                          bench_transition)
  benches = {
      "bench_stage1_reg": bench_stage1_reg.run,          # Fig 1
      "bench_tracenorm_nu": bench_tracenorm_nu.run,      # Fig 2
      "bench_rank_variance": bench_rank_variance.run,    # Fig 3
      "bench_stage2_tradeoff": bench_stage2_tradeoff.run,  # Fig 4
      "bench_transition": bench_transition.run,          # Fig 5
      "bench_tiers": bench_tiers.run,                    # Tables 1-2
      "bench_lowbatch_gemm": bench_lowbatch_gemm.run,    # Fig 6
      "bench_factorization_split": bench_factorization_split.run,  # Table 3
      "bench_quantization": bench_quantization.run,      # §4 int8 claim
      "bench_growing_gru": bench_growing_gru.run,        # Appendix B.1
      "bench_roofline": bench_roofline.run,              # brief §Roofline
  }
  os.makedirs(BENCH_DIR, exist_ok=True)
  print("name,us_per_call,derived")
  for name, fn in benches.items():
    if args.only and args.only not in name:
      continue
    t0 = time.perf_counter()
    rows = fn()
    wall = time.perf_counter() - t0
    # us_per_call: per measured unit (training step / kernel call / cell)
    us = 1e6 * wall / max(len(rows), 1)
    with open(os.path.join(BENCH_DIR, f"{name}.json"), "w") as f:
      json.dump(rows, f, indent=1)
    print(f"{name},{us:.0f},{_derived(name, rows)}")


if __name__ == "__main__":
  main()
