"""§Perf hillclimb harness: lower a cell under named optimization variants
and report the three roofline terms side by side.

Each variant is a (description, overrides) pair; overrides mutate the
ModelConfig / step-builder knobs (attention blocking, wedge scheduling,
remat policy, microbatch count, serving parallelism, collective dtype).
The harness records hypothesis -> before -> after rows which EXPERIMENTS.md
§Perf quotes directly.

Usage:
  XLA_FLAGS must NOT be set here — run through launch/dryrun's env:
  PYTHONPATH=src python -m benchmarks.perf_iterate --cell llama3_train
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax

from repro import configs
from repro.dist import hlo_cost
from repro.layers.common import SHAPES

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")


def factored_param_specs(cfg, rank_frac=None, min_dim=512):
  """ShapeDtypeStruct tree with every large GEMM in factored W = UV form:
  rank_frac=None gives the stage-1 full-rank form (paper eq. 3 training);
  rank_frac=0.25 models a stage-2 model truncated at 1/4 rank."""
  from repro.core.factored import FactoredLinear, map_factored_leaves
  sds = configs.param_specs(cfg)
  def f(leaf):
    if leaf.is_factored:
      return leaf
    shape = leaf.w.shape
    m, n = shape[-2], shape[-1]
    if min(m, n) < min_dim:
      return leaf
    r = min(m, n) if rank_frac is None else \
        max(128, int(min(m, n) * rank_frac) // 128 * 128)
    stack = shape[:-2]
    return FactoredLinear(
        w=None,
        u=jax.ShapeDtypeStruct(stack + (m, r), leaf.w.dtype),
        v=jax.ShapeDtypeStruct(stack + (r, n), leaf.w.dtype),
        name=leaf.name, group=leaf.group)
  return map_factored_leaves(f, sds)


def lower_cell(arch, shape_name, mesh, *, cfg_patch=None, optimizer=None,
               microbatches=8, builder_patch=None,
               sharding_overrides=None, rule_overrides=None,
               params_sds_override=None):
  from repro.launch import dryrun
  cfg = configs.get_config(arch)
  if cfg_patch:
    cfg = cfg.with_(**cfg_patch)
  shape = SHAPES[shape_name]
  cfg = dryrun._with_groups(cfg, mesh)
  opt = optimizer or dryrun.pick_optimizer(arch)
  if shape.kind == "train":
    fn, args, in_sh, out_sh = dryrun.build_train(
        cfg, shape, mesh, opt, microbatches=microbatches,
        sharding_overrides=sharding_overrides,
        rule_overrides=rule_overrides,
        params_sds_override=params_sds_override)
  elif shape.kind == "prefill":
    params_sds = configs.param_specs(cfg)
    fsdp = dryrun.needs_fsdp_serving(cfg, params_sds, mesh)
    fn, args, in_sh, out_sh = dryrun.build_prefill(cfg, shape, mesh, fsdp)
  else:
    params_sds = configs.param_specs(cfg)
    fsdp = dryrun.needs_fsdp_serving(cfg, params_sds, mesh)
    if builder_patch == "no_fsdp":
      fsdp = False
    fn, args, in_sh, out_sh = dryrun.build_decode(
        cfg, shape, mesh, fsdp, sharding_overrides=sharding_overrides,
        rule_overrides=rule_overrides,
        params_sds_override=params_sds_override)
  with mesh:
    compiled = jax.jit(fn, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
  import numpy as np
  n_dev = int(np.prod(list(mesh.shape.values())))
  rep = hlo_cost.analyze_module(compiled.as_text(), n_dev)
  mf = dryrun.model_flops(cfg, shape) / n_dev
  roof = hlo_cost.roofline_from_report(rep, model_flops=mf)
  mem = {}
  try:
    ma = compiled.memory_analysis()
    mem = {"temp_gb": getattr(ma, "temp_size_in_bytes", 0) / 1e9,
           "arg_gb": getattr(ma, "argument_size_in_bytes", 0) / 1e9}
  except Exception:
    pass
  return rep, roof, mem


def attention_tile_bytes(rep) -> float:
  """Measured HBM bytes attributable to attention score/probability tiles
  — the traffic the Pallas flash kernel (kernels/flash_attention.py) keeps
  in VMEM scratch. Tiles are identified from the per-shape traffic table:
  rank>=4 f32 tensors with small leading (batch, heads) dims and a tile
  face of >= 128x128 — the (b, h, q, k) score/prob/mask family that only
  exists because the XLA path materializes the online-softmax chain. The
  kernel substitution removes exactly these classes (qkv reads and the
  output write are shared by both paths and stay counted)."""
  total = 0.0
  for shape_str, b in rep.hbm_by_shape.items():
    dims = hlo_cost._first_array_dims(shape_str) or []
    if (len(dims) >= 4 and shape_str.startswith("f32")
        and dims[-1] >= 128 and dims[-2] >= 128
        and dims[0] * dims[1] <= 4096):
      total += b
  return total


def report(tag, rep, roof, mem, extra=""):
  print(f"{tag:34s} compute={roof.compute_s:8.4f}s "
        f"memory={roof.memory_s:8.4f}s coll={roof.collective_s:8.4f}s "
        f"dom={roof.dominant:10s} temp={mem.get('temp_gb', 0):6.2f}GB "
        f"ncoll={rep.n_collectives} {extra}")
  return {"tag": tag, "compute_s": roof.compute_s,
          "memory_s": roof.memory_s, "collective_s": roof.collective_s,
          "dominant": roof.dominant, "useful": roof.useful_flop_fraction,
          "n_collectives": rep.n_collectives, **mem, "extra": extra}


# ---------------------------------------------------------------------------
# CLI: replay the recorded §Perf iterations (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def _cell_llama3(results):
  from repro.dist.mesh import make_mesh
  from repro.launch import dryrun
  mesh = dryrun.production_meshes(multi_pod=False)["single"]
  wedge = {"causal_wedge": True, "attn_block_q": 1024, "attn_block_kv": 1024}
  rep, roof, mem = lower_cell("llama3-8b", "train_4k", mesh)
  results.append(report("A0 baseline", rep, roof, mem))
  rep, roof, mem = lower_cell("llama3-8b", "train_4k", mesh,
                              cfg_patch={"causal_wedge": True})
  results.append(report("A2 causal wedge", rep, roof, mem))
  rep, roof, mem = lower_cell("llama3-8b", "train_4k", mesh, cfg_patch=wedge)
  t = attention_tile_bytes(rep)
  results.append(report("A3/A4 wedge+1024 (+flash adj)", rep, roof, mem,
                        extra=f"adj_memory={roof.memory_s - t/819e9:.3f}s"))
  m128 = make_mesh((128, 2), ("data", "model"), devices=jax.devices()[:256])
  rep, roof, mem = lower_cell("llama3-8b", "train_4k", m128, cfg_patch=wedge)
  t = attention_tile_bytes(rep)
  results.append(report("A7 +mesh(128,2)", rep, roof, mem,
                        extra=f"adj_memory={roof.memory_s - t/819e9:.3f}s"))
  cfg = configs.get_config("llama3-8b").with_(**wedge)
  for tag, frac in (("A8 stage1 full-rank", None),
                    ("A9 stage2 quarter-rank", 0.25)):
    sds = factored_param_specs(cfg, rank_frac=frac)
    rep, roof, mem = lower_cell("llama3-8b", "train_4k", m128,
                                cfg_patch=wedge, params_sds_override=sds)
    t = attention_tile_bytes(rep)
    results.append(report(tag, rep, roof, mem,
                          extra=f"adj_memory={roof.memory_s - t/819e9:.3f}s"))


def _cell_dsv3(results):
  from repro.launch import dryrun
  mesh = dryrun.production_meshes(multi_pod=False)["single"]
  # the 2D-EP serving layout is the shipped default; both states lowerable
  rep, roof, mem = lower_cell("deepseek-v3-671b", "decode_32k", mesh)
  results.append(report("B2 2D-EP default", rep, roof, mem))


def _cell_ds2(results):
  from repro.dist.mesh import make_mesh
  from repro.launch import dryrun
  mesh = dryrun.production_meshes(multi_pod=False)["single"]
  rep, roof, mem = lower_cell("deepspeech2-wsj", "train_4k", mesh)
  results.append(report("C0 baseline TP=16", rep, roof, mem))
  dp = make_mesh((256, 1), ("data", "model"), devices=jax.devices()[:256])
  rep, roof, mem = lower_cell("deepspeech2-wsj", "train_4k", dp)
  results.append(report("C2 pure-DP (256,1)", rep, roof, mem))


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--cell", default="all",
                  choices=["all", "llama3", "dsv3", "ds2"])
  args = ap.parse_args()
  results = []
  if args.cell in ("all", "llama3"):
    _cell_llama3(results)
  if args.cell in ("all", "dsv3"):
    _cell_dsv3(results)
  if args.cell in ("all", "ds2"):
    _cell_ds2(results)
  os.makedirs(OUT, exist_ok=True)
  with open(os.path.join(OUT, f"replay_{args.cell}.json"), "w") as f:
    json.dump(results, f, indent=1)


if __name__ == "__main__":
  main()
