"""Fig. 1 — CER dependence on lambda_rec / lambda_nonrec for trace-norm
vs l2 regularization (stage-1 models on the synthetic speech task)."""
from __future__ import annotations

from benchmarks.speech_runner import train_stage1

LAMBDAS = [0.0, 3e-5, 3e-4]


def run() -> list[dict]:
  rows = []
  for kind in ("trace", "l2"):
    for lam_nr in LAMBDAS:
      for lam_r in (0.0, lam_nr):
        if lam_nr == 0.0 and lam_r != 0.0:
          continue
        out = train_stage1(kind, lam_r, lam_nr)
        rows.append({
            "bench": "fig1_stage1_reg", "kind": kind,
            "lambda_rec": lam_r, "lambda_nonrec": lam_nr,
            "cer": out["cer"], "step_time_s": out["step_time_s"],
        })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
