"""Fig. 6 — low-batch GEMM throughput, batch 1..16, on the paper's
6144 x 320 benchmark matrix.

The paper measures ARM wall-clock (farm vs gemmlowp). Here the TPU-target
numbers come from the bandwidth roofline (low-batch GEMM is memory-bound:
time = weight bytes / HBM bw; GOP/s = 2mn*batch / time), for three weight
formats the framework actually serves: bf16 dense, int8 dense
(kernels/int8_gemm), and bf16 rank-128 factored (kernels/lowrank_gemm —
rank 128 = the MXU lane width, the smallest rank the Pallas kernel
accepts without falling back to the reference; smaller ranks take the
jnp path by design).
The kernels' numerical behavior is validated in tests/test_kernels.py;
this bench also times each dispatch regime's kernel (interpret mode on
CPU) against its jnp reference to prove the code path runs and record the
perf trajectory (us columns; NOT a TPU wall-clock).

`--json` writes BENCH_kernels.json (kernel vs reference latency per
regime) — CI runs this as a smoke step on every push.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

M, N = 320, 6144        # paper: A (6144 x 320), x (320 x batch) -> y = Ax
RANK = 128              # = ops.LANE: below this lowrank_gemm falls back
                        # to ref, and the bench must time the real kernel
PEAK_GOPS = 197e3       # v5e bf16, GOP/s
HBM_BW = 819e9


def roofline_gops(batch: int, weight_bytes: float) -> float:
  flops = 2.0 * M * N * batch
  t_mem = weight_bytes / HBM_BW
  t_compute = flops / (PEAK_GOPS * 1e9)
  return flops / max(t_mem, t_compute) / 1e9


def _time(fn, *args, reps: int = 3) -> float:
  """Best-of-reps wall-clock (seconds); blocks on the result."""
  best = float("inf")
  for _ in range(reps):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    best = min(best, time.perf_counter() - t0)
  return best


def run() -> list[dict]:
  rows = []
  w = jax.random.normal(jax.random.PRNGKey(0), (M, N), jnp.float32) * 0.05
  wq, ws = ref.quantize_colwise(w)
  u = jax.random.normal(jax.random.PRNGKey(1), (M, RANK)) * 0.1
  v = jax.random.normal(jax.random.PRNGKey(2), (RANK, N)) * 0.1
  # jit the references ONCE: building the wrapper inside the batch loop
  # would retrace every call and charge compile time to the smoke step
  ref_decode = jax.jit(ref.decode_matvec)
  ref_int8 = jax.jit(ref.int8_gemm)
  ref_lowrank = jax.jit(ref.lowrank_gemm)
  formats = {
      "dense_bf16": 2.0 * M * N,
      "int8": 1.0 * M * N,
      "lowrank128_bf16": 2.0 * RANK * (M + N),
  }
  for batch in (1, 2, 4, 8, 16):
    x = jax.random.normal(jax.random.PRNGKey(batch), (batch, M))
    xq, xs = ref.quantize_rowwise(x)
    # per-regime kernel vs reference timing (interpret mode on CPU: a
    # code-path proof + relative trend, not TPU wall-clock)
    regime_us = {
        "decode_matvec": {
            "kernel": _time(ops.decode_matvec, x, w),
            "ref": _time(ref_decode, x, w),
        },
        "int8_gemm": {
            "kernel": _time(ops.int8_gemm, xq, wq, xs, ws),
            "ref": _time(ref_int8, xq, wq, xs, ws),
        },
        "lowrank_gemm": {
            "kernel": _time(ops.lowrank_gemm, x, u, v),
            "ref": _time(ref_lowrank, x, u, v),
        },
    }
    fmt_regime = {"dense_bf16": "decode_matvec", "int8": "int8_gemm",
                  "lowrank128_bf16": "lowrank_gemm"}
    for fmt, wbytes in formats.items():
      regime = fmt_regime[fmt]
      rows.append({
          "bench": "fig6_lowbatch_gemm", "batch": batch, "format": fmt,
          "regime": regime,
          "weight_bytes": wbytes,
          "roofline_gops": round(roofline_gops(batch, wbytes), 2),
          "kernel_us": round(1e6 * regime_us[regime]["kernel"], 1),
          "ref_us": round(1e6 * regime_us[regime]["ref"], 1),
      })
  return rows


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_kernels.json instead of printing rows")
  ap.add_argument("--out", default="BENCH_kernels.json")
  args = ap.parse_args()
  rows = run()
  if args.json:
    payload = {
        "bench": "fig6_lowbatch_gemm",
        "backend": jax.default_backend(),
        "note": "kernel/ref latencies are interpret-mode on non-TPU "
                "backends (code-path smoke, not TPU wall-clock)",
        "rows": rows,
    }
    with open(args.out, "w") as f:
      json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} rows)")
  else:
    for r in rows:
      print(r)


if __name__ == "__main__":
  main()
