"""Fig. 6 — low-batch GEMM throughput, batch 1..16, on the paper's
6144 x 320 benchmark matrix.

The paper measures ARM wall-clock (farm vs gemmlowp). Here the TPU-target
numbers come from the bandwidth roofline (low-batch GEMM is memory-bound:
time = weight bytes / HBM bw; GOP/s = 2mn*batch / time), for three weight
formats the framework actually serves: bf16 dense, int8 dense
(kernels/int8_gemm), and bf16 rank-64 factored (kernels/lowrank_gemm).
The kernels' numerical behavior is validated in tests/test_kernels.py;
this bench also times the interpret-mode kernels once per batch size to
prove the code path runs (us_per_call column; NOT a TPU wall-clock)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

M, N = 320, 6144        # paper: A (6144 x 320), x (320 x batch) -> y = Ax
RANK = 64
PEAK_GOPS = 197e3       # v5e bf16, GOP/s
HBM_BW = 819e9


def roofline_gops(batch: int, weight_bytes: float) -> float:
  flops = 2.0 * M * N * batch
  t_mem = weight_bytes / HBM_BW
  t_compute = flops / (PEAK_GOPS * 1e9)
  return flops / max(t_mem, t_compute) / 1e9


def run() -> list[dict]:
  rows = []
  w = jax.random.normal(jax.random.PRNGKey(0), (M, N), jnp.float32) * 0.05
  wq, ws = ref.quantize_colwise(w)
  u = jax.random.normal(jax.random.PRNGKey(1), (M, RANK)) * 0.1
  v = jax.random.normal(jax.random.PRNGKey(2), (RANK, N)) * 0.1
  formats = {
      "dense_bf16": 2.0 * M * N,
      "int8": 1.0 * M * N,
      "lowrank64_bf16": 2.0 * RANK * (M + N),
  }
  for batch in (1, 2, 4, 8, 16):
    x = jax.random.normal(jax.random.PRNGKey(batch), (batch, M))
    xq, xs = ref.quantize_rowwise(x)
    # one interpret-mode execution per kernel (code-path proof + timing)
    t0 = time.perf_counter()
    ops.int8_gemm(xq, wq, xs, ws, block_m=320, block_n=512)
    t_int8 = time.perf_counter() - t0
    t0 = time.perf_counter()
    ops.lowrank_gemm(x, u, v, block_m=320, block_n=512)
    t_lr = time.perf_counter() - t0
    for fmt, wbytes in formats.items():
      rows.append({
          "bench": "fig6_lowbatch_gemm", "batch": batch, "format": fmt,
          "weight_bytes": wbytes,
          "roofline_gops": round(roofline_gops(batch, wbytes), 2),
          "interpret_us": round(1e6 * (t_int8 if fmt == "int8" else
                                       t_lr if fmt.startswith("lowrank")
                                       else 0.0), 1),
      })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
