"""Fig. 3 — truncated-SVD rank needed to explain 90% of the variance
versus CER, by regularization type during stage-1 training."""
from __future__ import annotations

from benchmarks.speech_runner import gemm_diagnostics, train_stage1

SWEEP = [("trace", 0.0), ("trace", 3e-5), ("trace", 3e-4), ("trace", 1e-3),
         ("trace", 3e-3), ("trace", 1e-2),
         ("l2", 0.0), ("l2", 3e-5), ("l2", 3e-4), ("l2", 1e-3),
         ("l2", 3e-3), ("l2", 1e-2), ("none", 0.0)]


def run() -> list[dict]:
  rows = []
  for kind, lam in SWEEP:
    out = train_stage1(kind, lam, lam)
    diag = gemm_diagnostics(out["params"])
    for name in ("gru2/nonrec", "gru2/rec"):
      if name in diag:
        rows.append({
            "bench": "fig3_rank90_vs_cer", "kind": kind, "lambda": lam,
            "gemm": name, "rank90": diag[name]["rank90"],
            "max_rank": min(diag[name]["shape"]), "cer": out["cer"],
        })
  return rows


if __name__ == "__main__":
  for r in run():
    print(r)
