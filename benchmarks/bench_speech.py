"""Streaming speech fleet vs serial decoding + calibrated low-rank rows.

Two sections, both smoke-scale (CPU wall-clock is a trajectory signal,
not a TPU number):

fleet     — the continuous-batching `StreamingSpeechServer`: a queue of
            mixed, deliberately non-stride-multiple-length utterances
            shares `--batch` masked decode slots (admit / chunk /
            retire / refill). Baseline is the same server at batch 1 —
            the same masked program decoding each utterance alone — so
            the speedup isolates what slot sharing buys. Parity is
            asserted two ways: fleet == serial bitwise (continuous
            batching is a scheduling change, not a numerics change),
            and fleet == the full-utterance `deepspeech.forward`
            argmax-collapse on the pinned verified workload (per-frame
            decode and the batched training scan are
            differently-associated float programs, so this parity is
            pinned on seeds where the two agree — see
            tests/test_speech_fleet.py).

calibrated — LiteASR-style activation-calibrated truncation vs the
            plain weight spectrum at EQUAL rank, scored by fidelity
            CER: the truncated model's greedy-CTC emissions vs the
            float model's own emissions (label edit distance / ref
            length). Task CER against ground truth is meaningless at
            random init; fidelity to the float model isolates what
            truncation destroys. Calibration runs the float decode
            eagerly (dispatch.JNP_ONLY) so `observe_gemm_moments` sees
            every GEMM — including the recurrent ones a `lax.scan`
            would hide.

`--json` writes BENCH_speech.json — CI runs this as a smoke step,
asserts fleet >= 1.3x serial streams/s, both parities, and that the
calibrated CER beats spectrum-only at every benched rank.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compress, svd
from repro.kernels import dispatch
from repro.models import deepspeech
from repro.models.api import get_model
from repro.quant import calibrate_activation_stats
from repro.serving import StreamingSpeechServer

#: verified parity workload: on seed 0, per-frame decode and the batched
#: forward agree at every one of these lengths (stride-hostile mix —
#: most are not multiples of the 4x total time stride). Lengths are
#: long enough to amortize each admitted stream's receptive-field
#: warmup (~24 mel frames before a fresh conv stream emits its first
#: GRU frame), which is what bounds fleet occupancy; the short-length
#: edge cases live in tests/test_speech_fleet.py.
BENCH_LENS = (49, 57, 64, 71, 80, 93, 96, 101, 112, 127, 65, 81)


def make_utts(feat_dim: int, seed: int = 0) -> list:
  rng = np.random.RandomState(seed)
  return [rng.randn(t, feat_dim).astype(np.float32) for t in BENCH_LENS]


def _collapse(best_row):
  prev, out = -1, []
  for lab in best_row:
    if lab != 0 and lab != prev:
      out.append(int(lab))
    prev = lab
  return out


def _edit_distance(a, b) -> int:
  prev = list(range(len(b) + 1))
  for i, x in enumerate(a, 1):
    cur = [i]
    for j, y in enumerate(b, 1):
      cur.append(min(prev[j] + 1, cur[-1] + 1, prev[j - 1] + (x != y)))
    prev = cur
  return prev[-1]


# ---------------------------------------------------------------------------
# fleet vs serial
# ---------------------------------------------------------------------------


def _serve(server, utts, chunk_frames):
  for u in utts:
    server.submit(u)
  t0 = time.perf_counter()
  results = server.run(chunk_frames=chunk_frames)
  dt = time.perf_counter() - t0
  return results, dt


def run_fleet(cfg, params, utts, *, batch, kernel_policy,
              chunk_frames) -> tuple[dict, dict]:
  server = StreamingSpeechServer(cfg, params, batch_size=batch,
                                 kernel_policy=kernel_policy)
  _serve(server, utts, chunk_frames)            # jit + bucket warmup
  results, dt = _serve(server, utts, chunk_frames)
  frames = sum(r.frames for r in results)
  # second-wave uids start at len(utts): map back to submission order
  labels = {r.uid - len(utts): list(r.labels) for r in results}
  stats = {"wall_s": dt, "streams_s": len(results) / dt,
           "frames_s": frames / dt, "occupancy": server.occupancy,
           "compile_stats": server.compile_stats()}
  return stats, labels


def run_serving(cfg, params, *, batch, kernel_policy,
                chunk_frames) -> dict:
  utts = make_utts(cfg.feat_dim)
  fleet, fleet_labels = run_fleet(cfg, params, utts, batch=batch,
                                  kernel_policy=kernel_policy,
                                  chunk_frames=chunk_frames)
  serial, serial_labels = run_fleet(cfg, params, utts, batch=1,
                                    kernel_policy=kernel_policy,
                                    chunk_frames=chunk_frames)
  full = {}
  for i, u in enumerate(utts):
    lp = deepspeech.forward(params, jnp.asarray(u[None]), cfg)
    full[i] = _collapse(np.asarray(jnp.argmax(lp, -1))[0])
  return {
      "batch": batch, "num_utts": len(utts),
      "utt_lens": list(BENCH_LENS), "chunk_frames": chunk_frames,
      "fleet": fleet, "serial": serial,
      "speedup": fleet["streams_s"] / serial["streams_s"],
      "parity_fleet_serial": fleet_labels == serial_labels,
      "parity_full_forward": fleet_labels == full,
  }


# ---------------------------------------------------------------------------
# calibrated vs spectrum-only truncation (fidelity CER)
# ---------------------------------------------------------------------------


def _eager_decode(params, feats, cfg):
  """Per-frame decode_step loop, eager, policy threaded: the
  calibration forward. Observes every GEMM — fc/out AND the recurrent
  gru GEMMs that hide inside scans everywhere else."""
  x = deepspeech._frontend(params, jnp.asarray(feats), cfg)
  state = deepspeech.init_decode_state(cfg, feats.shape[0])
  for t in range(x.shape[1]):
    _, state = deepspeech.decode_step(params, state, x[:, t], cfg,
                                      policy=dispatch.JNP_ONLY)


def _emissions(params, feats, cfg) -> list:
  lp = deepspeech.forward(params, jnp.asarray(feats), cfg)
  best = np.asarray(jnp.argmax(lp, -1))
  return [_collapse(best[i]) for i in range(best.shape[0])]


def run_calibrated(cfg, params, *, ranks, min_dim=48) -> dict:
  rng = np.random.RandomState(1)
  cal_feats = rng.randn(2, 32, cfg.feat_dim).astype(np.float32)
  eval_feats = rng.randn(4, 40, cfg.feat_dim).astype(np.float32)
  stats = calibrate_activation_stats(
      lambda b: _eager_decode(params, b, cfg), [cal_feats])
  ref = _emissions(params, eval_feats, cfg)

  def fidelity_cer(trunc_params) -> float:
    got = _emissions(trunc_params, eval_feats, cfg)
    dist = sum(_edit_distance(r, g) for r, g in zip(ref, got))
    return dist / max(sum(len(r) for r in ref), 1)

  rows = []
  for r in ranks:
    plan = compress.FactorizationPlan(
        min_dim=min_dim,
        truncation=svd.TruncationSpec(fixed_rank=r, round_to=1))
    spectrum = compress.to_stage2(params, plan)
    calibrated = compress.to_stage2(params, plan, calib=stats)
    report = compress.compression_report(params, calibrated, calib=stats)
    rows.append({
        "rank": r,
        "cer_spectrum": fidelity_cer(spectrum),
        "cer_calibrated": fidelity_cer(calibrated),
        "params_after": report["total_params_after"],
    })
  return {"ranks": list(ranks), "min_dim": min_dim,
          "calibrated_gemms": sorted(stats.keys()), "rows": rows}


def run(arch: str, *, batch: int, kernel_policy, chunk_frames: int,
        ranks) -> dict:
  cfg = configs.get_smoke(arch).with_(dtype=jnp.float32)
  api = get_model(cfg)
  params = api.init(jax.random.PRNGKey(0), cfg)
  return {
      "arch": cfg.name,
      "serving": run_serving(cfg, params, batch=batch,
                             kernel_policy=kernel_policy,
                             chunk_frames=chunk_frames),
      "calibrated": run_calibrated(cfg, params, ranks=ranks),
  }


def main() -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="deepspeech2-wsj")
  ap.add_argument("--batch", type=int, default=6)
  ap.add_argument("--chunk-frames", type=int, default=16)
  ap.add_argument("--kernels", choices=["jnp", "pallas"], default="jnp")
  ap.add_argument("--ranks", type=lambda s: [int(x) for x in s.split(",")],
                  default=[16, 24, 32])
  ap.add_argument("--json", action="store_true",
                  help="write BENCH_speech.json")
  args = ap.parse_args()

  out = run(args.arch, batch=args.batch, kernel_policy=args.kernels,
            chunk_frames=args.chunk_frames, ranks=args.ranks)
  sv = out["serving"]
  for mode in ("fleet", "serial"):
    r = sv[mode]
    print(f"{mode:>8}: {sv['num_utts']} utts in {r['wall_s']:.2f}s "
          f"({r['streams_s']:.1f} streams/s, {r['frames_s']:.0f} "
          f"frames/s, occupancy {r['occupancy']:.2f})")
  print(f"  speedup: {sv['speedup']:.2f}x at {sv['batch']} slots, "
        f"parity fleet==serial "
        f"{'OK' if sv['parity_fleet_serial'] else 'BROKEN'}, "
        f"fleet==full-forward "
        f"{'OK' if sv['parity_full_forward'] else 'BROKEN'}, "
        f"frame_step signatures "
        f"{sv['fleet']['compile_stats']['frame_step']}")
  cal = out["calibrated"]
  for row in cal["rows"]:
    better = row["cer_calibrated"] < row["cer_spectrum"]
    print(f"  rank {row['rank']:>3}: fidelity CER spectrum "
          f"{row['cer_spectrum']:.3f} vs calibrated "
          f"{row['cer_calibrated']:.3f} "
          f"({'calibrated wins' if better else 'NO WIN'})")
  if args.json:
    with open("BENCH_speech.json", "w") as f:
      json.dump(out, f, indent=1)
    print("wrote BENCH_speech.json")


if __name__ == "__main__":
  main()
